//! Sharded-corpus scale sweep: recall@ℓ vs candidate fraction vs merge
//! overhead vs append throughput, across shard counts.
//!
//! Emits machine-readable `BENCH_shard.json` in the working directory (the
//! repo root under `cargo bench`), the fan-out companion of
//! `BENCH_phase1.json` / `BENCH_ivf.json`.
//!
//! Run: `cargo bench --bench shard_scale` (EMDPAR_BENCH_FULL=1 for the
//! bigger workload).  EMDPAR_SHARD_MIN_RECALL enforces a recall floor on
//! the best sweep point that scored at most half the corpus.

use std::io::Write;
use std::sync::Arc;

use emdpar::config::{IndexParams, ShardParams};
use emdpar::coordinator::TopL;
use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::recall_at;
use emdpar::prelude::{EngineBuilder, EngineParams, Histogram, LcEngine, Method, SearchRequest};
use emdpar::util::json::Json;
use emdpar::util::stats::timed;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n, v, m, doc_len, nq, nlist, append_n) =
        if full { (8000, 8000, 64, 60, 64, 32, 512) } else { (1500, 2000, 32, 40, 24, 16, 128) };
    let method = Method::Act { k: 2 };
    let l = 10;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Sharded corpus: recall@{l} vs candidate fraction vs merge overhead");
    println!(
        "# n={n} v={v} m={m} doc_len={doc_len} queries={nq} per-shard nlist={nlist} \
         threads={threads}\n"
    );

    let ds = Arc::new(generate_text(&TextConfig {
        n,
        vocab: v,
        dim: m,
        doc_len,
        topic_frac: 0.75,
        spread: 0.3,
        seed: 31,
        ..Default::default()
    }));
    let ep = EngineParams { threads, symmetric: false, ..Default::default() };
    let eng = LcEngine::new(Arc::clone(&ds), ep);
    let queries: Vec<Histogram> = (0..nq).map(|i| ds.histogram(i * n / nq)).collect();

    // monolithic exhaustive truth + baseline timing
    let (flat, t_exh) = timed(|| eng.distances_batch(&queries, method));
    let truth: Vec<Vec<usize>> = (0..nq)
        .map(|qi| {
            let row = &flat[qi * n..(qi + 1) * n];
            let mut top = TopL::new(l);
            top.push_slice(row, 0);
            top.into_sorted().into_iter().map(|(_, id)| id).collect()
        })
        .collect();
    let exh_qps = nq as f64 / t_exh.as_secs_f64();
    println!("monolithic exhaustive: {exh_qps:.1} queries/s ({n} docs scored per query)\n");

    let append_docs: Vec<Histogram> = (0..append_n).map(|i| ds.histogram(i % n)).collect();
    let append_labels: Vec<u16> = (0..append_n as u16).collect();

    let ixp =
        IndexParams { nlist, nprobe: 1, train_iters: 10, seed: 7, min_points_per_list: 2 };
    let mut shard_rows = Vec::new();
    let mut best_cheap_recall = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        // the serving engine: sharded corpus + per-shard IVF behind the
        // query planner (every sweep point dispatches a SearchRequest
        // through the parallel fan-out route)
        let (engine, t_build) = timed(|| {
            EngineBuilder::new()
                .dataset(Arc::clone(&ds))
                .threads(threads)
                .symmetric(false)
                .index(ixp)
                .sharded(ShardParams { shards, max_docs_per_shard: usize::MAX >> 1 })
                .build_search()
                .unwrap()
        });
        let stats = engine.shard_stats().unwrap_or_default();
        println!(
            "S={shards}: built {} shards in {:.2}s (per-shard nlist <= {nlist})",
            stats.len(),
            t_build.as_secs_f64()
        );
        println!(
            "{:>8} {:>10} {:>10} {:>10} {:>11} {:>10}",
            "nprobe", "cand_frac", "recall", "qps", "merge_frac", "speedup"
        );
        let max_np = stats.iter().filter_map(|s| s.nlist).max().unwrap_or(1);
        let mut sweep = Vec::new();
        for &nprobe in &[1usize, 2, 4, 8, 16, 32] {
            if nprobe > max_np {
                continue;
            }
            let request =
                SearchRequest::batch(queries.clone()).method(method).topl(l).nprobe(nprobe);
            let (resp, t) = timed(|| engine.execute(&request).unwrap());
            let mut recall = 0.0f64;
            for (t_ids, r) in truth.iter().zip(&resp.results) {
                let got: Vec<usize> = r.hits.iter().map(|&(_, id)| id).collect();
                recall += recall_at(t_ids, &got);
            }
            recall /= nq as f64;
            let frac = resp.stats.candidates_scored as f64 / (nq * n) as f64;
            let qps = nq as f64 / t.as_secs_f64();
            let merge_frac =
                (resp.stats.merge_us as f64 / 1e6) / t.as_secs_f64().max(1e-12);
            let speedup = t_exh.as_secs_f64() / t.as_secs_f64();
            println!(
                "{nprobe:>8} {frac:>10.3} {recall:>10.3} {qps:>10.1} {merge_frac:>11.4} {speedup:>9.2}x"
            );
            if frac <= 0.5 && recall > best_cheap_recall {
                best_cheap_recall = recall;
            }
            sweep.push(Json::obj(vec![
                ("nprobe", nprobe.into()),
                ("candidate_fraction", frac.into()),
                ("recall", recall.into()),
                ("queries_per_s", qps.into()),
                ("merge_fraction", merge_frac.into()),
                ("speedup_vs_exhaustive", speedup.into()),
            ]));
        }
        // append throughput: trained-once / assign-incrementally path
        // (synthetic dataset: nothing persisted, the append is in-memory)
        let (outcome, t_append) =
            timed(|| engine.add_docs(&append_docs, &append_labels).unwrap());
        let append_dps = append_n as f64 / t_append.as_secs_f64();
        println!(
            "append: {append_n} docs in {:.3}s ({append_dps:.0} docs/s, {} shard(s) touched)\n",
            t_append.as_secs_f64(),
            outcome.touched.len()
        );
        shard_rows.push(Json::obj(vec![
            ("shards", shards.into()),
            ("build_seconds", t_build.as_secs_f64().into()),
            ("append_docs_per_s", append_dps.into()),
            ("sweep", Json::Arr(sweep)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", "shard_scale".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n.into()),
                ("v", v.into()),
                ("m", m.into()),
                ("doc_len", doc_len.into()),
                ("queries", nq.into()),
                ("per_shard_nlist", nlist.into()),
                ("append_docs", append_n.into()),
                ("method", method.name().into()),
                ("l", l.into()),
                ("threads", threads.into()),
                ("full", full.into()),
            ]),
        ),
        ("exhaustive_queries_per_s", exh_qps.into()),
        ("shards", Json::Arr(shard_rows)),
        ("regenerate_with", "cargo bench --bench shard_scale".into()),
    ]);
    let path = "BENCH_shard.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // CI floor: a broken fan-out (zero recall or no pruning win) fails the
    // push; shared-runner timing noise does not move recall
    if let Ok(s) = std::env::var("EMDPAR_SHARD_MIN_RECALL") {
        if let Ok(min) = s.parse::<f64>() {
            if best_cheap_recall < min {
                eprintln!(
                    "FAIL: best cheap recall {best_cheap_recall:.3} below required {min:.3}"
                );
                std::process::exit(1);
            }
            println!(
                "best cheap recall {best_cheap_recall:.3} meets the required {min:.3} floor"
            );
        }
    }
}
