//! Paper Fig. 8(a): runtime-vs-accuracy scatter on the (synthetic) 20News
//! corpus — BoW, WCD, RWMD, OMR, ACT-1/3/7 all-pairs, plus the exact-WMD
//! comparator on a query subset.  Prints the scatter as a table: one row
//! per method with total runtime, pairs/s and precision@ℓ.
//!
//! Run: `cargo bench --bench fig8a_text` (EMDPAR_BENCH_FULL=1 for n=4000).

use std::time::Instant;

use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::{precision_at, render_markdown, sweep_all_pairs};
use emdpar::exact::wmd_topl_pruned;
use emdpar::prelude::{EngineParams, Method, Metric};

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let n = if full { 4000 } else { 1000 };
    // short noisy docs over a wide vocabulary: reproduces the Fig. 8(a)
    // separation (BoW < RWMD < OMR < ACT-k) instead of saturating at 1.0
    let ds = std::sync::Arc::new(generate_text(&TextConfig {
        n,
        vocab: 8000,
        dim: 64,
        doc_len: 30,
        spread: 0.5,
        topic_frac: 0.45,
        general_frac: 0.35,
        ..Default::default()
    }));
    let stats = ds.stats();
    println!(
        "# Fig. 8(a) — {} n={} avg_h={:.1} v={} m={}  (paper: 18828/78.8/69682/300)\n",
        ds.name, stats.n, stats.avg_h, stats.used_vocab, stats.dim
    );

    let ls = [1usize, 16, 128].iter().copied().filter(|&l| l < n).collect::<Vec<_>>();
    let rows = sweep_all_pairs(
        &ds,
        &[
            Method::Bow,
            Method::Wcd,
            Method::Rwmd,
            Method::Omr,
            Method::Act { k: 2 },
            Method::Act { k: 4 },
            Method::Act { k: 8 },
        ],
        &ls,
        EngineParams { threads: emdpar::util::threadpool::default_threads(), ..Default::default() },
    )
    .expect("sweep");
    println!("{}", render_markdown("runtime vs accuracy (all-pairs, symmetric)", &rows));

    // WMD comparator on a subset
    let wmd_q = if full { 20 } else { 8 };
    let db: Vec<_> = (0..ds.len()).map(|u| ds.histogram(u)).collect();
    let t0 = Instant::now();
    let mut dist = vec![f32::INFINITY; wmd_q * n];
    for uq in 0..wmd_q {
        let (top, _) = wmd_topl_pruned(&ds.embeddings, &db[uq], &db, Metric::L2, 17);
        for (d, u) in top {
            dist[uq * n + u] = d as f32;
        }
    }
    let elapsed = t0.elapsed();
    let prec = precision_at(&dist, &ds.labels[..wmd_q], &ds.labels, 16, true);
    let wmd_pairs_per_s = (wmd_q * n) as f64 / elapsed.as_secs_f64();
    println!(
        "| WMD (exact+prune) | {:?} | {:.3e} | p@16 {prec:.4} | ({} queries) |",
        elapsed, wmd_pairs_per_s, wmd_q
    );
    if let Some(act1) = rows.iter().find(|r| r.method == "ACT-1") {
        println!(
            "\n# headline: ACT-1 is {:.0}x faster than WMD at comparable precision",
            act1.throughput() / wmd_pairs_per_s
        );
    }
}
