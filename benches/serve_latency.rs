//! Serving-runtime comparison: thread-per-connection [`Server`] vs the
//! event-loop [`ReactorServer`], closed-loop clients over real sockets.
//!
//! Sweeps the connection count (1 / 8 / 64 by default) and reports
//! throughput plus per-request p50/p99 latency for both runtimes.  The
//! legacy server handles connections on a pool of `threads.max(2)` workers,
//! so past that many concurrent clients it head-of-line blocks whole
//! connections; the reactor multiplexes every connection over a fixed set
//! of event loops and keeps admitting work.
//!
//! Emits machine-readable `BENCH_serve.json` in the working directory (the
//! repo root under `cargo bench`).
//!
//! Run: `cargo bench --bench serve_latency` (EMDPAR_BENCH_FULL=1 for the
//! bigger sweep).  EMDPAR_SERVE_MIN_SPEEDUP enforces a floor on the
//! reactor/legacy throughput ratio at the highest connection count.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use emdpar::config::{Config, DatasetSpec};
use emdpar::coordinator::SearchEngine;
use emdpar::prelude::{ReactorServer, Server};
use emdpar::util::json::Json;

enum AnyServer {
    Legacy(Server),
    Reactor(ReactorServer),
}

impl AnyServer {
    fn bind(kind: &str, engine: SearchEngine) -> AnyServer {
        match kind {
            "threads" => AnyServer::Legacy(Server::bind(engine, "127.0.0.1:0").unwrap()),
            _ => AnyServer::Reactor(ReactorServer::bind(engine, "127.0.0.1:0").unwrap()),
        }
    }

    fn local_addr(&self) -> SocketAddr {
        match self {
            AnyServer::Legacy(s) => s.local_addr().unwrap(),
            AnyServer::Reactor(s) => s.local_addr().unwrap(),
        }
    }

    fn serve_n(&self, count: usize) {
        match self {
            AnyServer::Legacy(s) => s.serve_n(count).unwrap(),
            AnyServer::Reactor(s) => s.serve_n(count).unwrap(),
        }
    }
}

fn engine_config(n: usize, threads: usize) -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n, vocab: 400, dim: 16, seed: 11 },
        threads,
        linger_ms: 1,
        ..Default::default()
    }
}

/// One closed-loop client: request → response → next, recording µs each.
fn client_loop(addr: SocketAddr, n_docs: usize, reqs: usize, seed: usize) -> Vec<u64> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut lat = Vec::with_capacity(reqs);
    let mut resp = String::new();
    for i in 0..reqs {
        let id = (seed * 31 + i * 7) % n_docs;
        let line = format!("{{\"op\": \"search_id\", \"id\": {id}, \"l\": 10}}\n");
        let t0 = Instant::now();
        writer.write_all(line.as_bytes()).unwrap();
        resp.clear();
        reader.read_line(&mut resp).unwrap();
        lat.push(t0.elapsed().as_micros() as u64);
        assert!(resp.contains("\"ok\":true"), "bench request failed: {resp}");
    }
    lat
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run one sweep point: `conns` closed-loop clients against a fresh engine
/// behind `kind`; returns (queries/s, p50 µs, p99 µs).
fn run_point(kind: &str, n_docs: usize, threads: usize, conns: usize, reqs: usize) -> (f64, u64, u64) {
    let engine = SearchEngine::from_config(engine_config(n_docs, threads)).unwrap();
    let server = AnyServer::bind(kind, engine);
    let addr = server.local_addr();
    let mut lat: Vec<u64> = Vec::with_capacity(conns * reqs);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let srv = s.spawn(|| server.serve_n(conns));
        let clients: Vec<_> = (0..conns)
            .map(|c| s.spawn(move || client_loop(addr, n_docs, reqs, c)))
            .collect();
        for h in clients {
            lat.extend(h.join().unwrap());
        }
        srv.join().unwrap();
    });
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_unstable();
    ((conns * reqs) as f64 / wall, percentile(&lat, 50.0), percentile(&lat, 99.0))
}

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n_docs, reqs, sweep): (usize, usize, &[usize]) =
        if full { (2000, 80, &[1, 8, 64, 128]) } else { (600, 40, &[1, 8, 64]) };
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Serving runtimes: thread-per-connection vs event-loop reactor");
    println!("# n={n_docs} reqs/conn={reqs} threads={threads} (closed-loop clients)\n");
    println!(
        "{:>8} {:>9} {:>10} {:>10} {:>10}",
        "runtime", "conns", "qps", "p50_us", "p99_us"
    );

    let mut rows = Vec::new();
    let mut qps_at_max = [0.0f64; 2]; // [legacy, reactor] at the top sweep point
    for (k, kind) in ["threads", "reactor"].iter().enumerate() {
        for &conns in sweep {
            let (qps, p50, p99) = run_point(kind, n_docs, threads, conns, reqs);
            println!("{kind:>8} {conns:>9} {qps:>10.1} {p50:>10} {p99:>10}");
            if conns == *sweep.last().unwrap() {
                qps_at_max[k] = qps;
            }
            rows.push(Json::obj(vec![
                ("runtime", (*kind).into()),
                ("connections", conns.into()),
                ("queries_per_s", qps.into()),
                ("p50_us", (p50 as usize).into()),
                ("p99_us", (p99 as usize).into()),
            ]));
        }
    }

    let max_conns = *sweep.last().unwrap();
    let speedup = qps_at_max[1] / qps_at_max[0].max(1e-12);
    println!("\nreactor/legacy throughput at {max_conns} connections: {speedup:.2}x");

    let json = Json::obj(vec![
        ("bench", "serve_latency".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n_docs.into()),
                ("requests_per_connection", reqs.into()),
                ("threads", threads.into()),
                ("connections_sweep", Json::Arr(sweep.iter().map(|&c| c.into()).collect())),
                ("full", full.into()),
            ]),
        ),
        ("rows", Json::Arr(rows)),
        ("reactor_speedup_at_max_connections", speedup.into()),
        ("regenerate_with", "cargo bench --bench serve_latency".into()),
    ]);
    let path = "BENCH_serve.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // CI floor: the reactor must not lose throughput to the legacy runtime
    // at high connection counts (the whole point of the event loop); a
    // conservative floor absorbs shared-runner noise
    if let Ok(s) = std::env::var("EMDPAR_SERVE_MIN_SPEEDUP") {
        if let Ok(min) = s.parse::<f64>() {
            if speedup < min {
                eprintln!(
                    "FAIL: reactor speedup {speedup:.2}x at {max_conns} connections below \
                     required {min:.2}x"
                );
                std::process::exit(1);
            }
            println!("speedup {speedup:.2}x meets the required {min:.2}x floor");
        }
    }
}
