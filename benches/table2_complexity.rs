//! Paper Table 2: time complexity of brute-force RWMD — O(n h² m) — vs
//! LC-RWMD — O(vhm + nh).  Sweeps the histogram size h at fixed n, v, m and
//! prints per-query runtimes; the expected *shape* is quadratic growth for
//! the brute force and linear for LC-RWMD, with the crossover at tiny h.
//!
//! Run: `cargo bench --bench table2_complexity` (EMDPAR_BENCH_FULL=1 for
//! the full sweep).

use emdpar::approx::rwmd::rwmd_directed;
use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::{plan_query, rwmd_direction_a, PlanParams};
use emdpar::prelude::Metric;
use emdpar::util::stats::Bench;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let hs: &[usize] = if full { &[25, 50, 100, 200, 400] } else { &[25, 50, 100] };
    let n = if full { 2000 } else { 400 };
    let vocab = 4000;
    let m = 64;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Table 2 — RWMD O(nh^2m) vs LC-RWMD O(vhm + nh)");
    println!("# n={n} v={vocab} m={m} threads={threads}\n");
    println!(
        "{:<8} {:>16} {:>16} {:>10}",
        "h", "RWMD/query", "LC-RWMD/query", "speedup"
    );

    let mut bench = Bench::quick();
    for &h in hs {
        let ds = generate_text(&TextConfig {
            n,
            vocab,
            dim: m,
            doc_len: h,
            truncate: h,
            classes: 10,
            seed: 5,
            ..Default::default()
        });
        let db: Vec<_> = (0..ds.len()).map(|u| ds.histogram(u)).collect();
        let query = ds.histogram(0);

        // brute-force RWMD: per-pair cost matrices (quadratic in h)
        let brute = bench.run(&format!("rwmd-brute h={h}"), || {
            let mut acc = 0.0f64;
            // sample 32 database docs to keep the bench bounded; report /pair
            for d in db.iter().take(32) {
                acc += rwmd_directed(&ds.embeddings, d, &query, Metric::L2);
            }
            std::hint::black_box(acc);
        });
        let brute_per_query = brute.per_iter.as_secs_f64() / 32.0 * n as f64;

        // LC-RWMD: one Phase-1 plan + linear sweep
        let vn = ds.embeddings.row_sq_norms();
        let lc = bench.run(&format!("lc-rwmd    h={h}"), || {
            let plan = plan_query(
                &ds.embeddings,
                &vn,
                &query,
                PlanParams { k: 1, metric: Metric::L2, keep_d: false, threads, kernel: None },
            );
            std::hint::black_box(rwmd_direction_a(&plan, &ds.matrix, threads));
        });
        let lc_per_query = lc.per_iter.as_secs_f64();

        println!(
            "{:<8} {:>13.3} ms {:>13.3} ms {:>9.1}x",
            h,
            brute_per_query * 1e3,
            lc_per_query * 1e3,
            brute_per_query / lc_per_query
        );
    }
    println!("\n# expectation: RWMD column grows ~quadratically in h, LC-RWMD ~linearly;");
    println!("# speedup approaches the paper's O(h) factor as h grows.");
}
