//! Tracing overhead: what does the observability layer cost the query path?
//!
//! Three modes over the same engine and query stream, interleaved so drift
//! hits all of them equally:
//!
//! * `off`    — tracing disabled (the default): requests take the guard-only
//!              path, no session, no spans.  Measured twice (split into
//!              interleaved halves A/B) so the disabled-path cost can be
//!              bounded against itself: any systematic difference between
//!              two interleaved runs of identical code is the measurement
//!              noise floor, and the acceptance gate below asserts it stays
//!              under 1% (or 5µs absolute, whichever is larger).
//! * `armed`  — a slow-query threshold arms per-request sessions whose
//!              spans are recorded and discarded (never logged): the cost
//!              of having the slow-query log on.
//! * `traced` — `trace: true` requests: session + timeline in the response.
//!
//! Emits machine-readable `BENCH_trace.json`.  Run:
//! `cargo bench --bench trace_overhead` (EMDPAR_BENCH_FULL=1 for more
//! samples; EMDPAR_TRACE_OVERHEAD_PCT overrides the 1% disabled-path gate).

use std::io::Write;
use std::sync::Arc;
use std::time::Instant;

use emdpar::config::{Config, DatasetSpec, ServeParams};
use emdpar::coordinator::{SearchEngine, SearchRequest};
use emdpar::core::{Dataset, Method};
use emdpar::util::json::Json;

fn dataset(n: usize) -> Arc<Dataset> {
    Arc::new(
        Config {
            dataset: DatasetSpec::SynthText { n, vocab: 400, dim: 16, seed: 11 },
            ..Config::default()
        }
        .load_dataset()
        .unwrap(),
    )
}

fn engine(ds: &Arc<Dataset>, slow_query_us: u64) -> SearchEngine {
    SearchEngine::with_dataset(
        Config {
            threads: 2,
            serve: ServeParams { slow_query_us, ..Default::default() },
            ..Config::default()
        },
        Arc::clone(ds),
    )
    .unwrap()
}

/// Median per-request µs over `reqs` requests in one mode.
fn measure(eng: &SearchEngine, ds: &Dataset, reqs: usize, traced: bool, round: usize) -> f64 {
    let mut lat = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let q = ds.histogram((round * 31 + i * 7) % ds.len());
        let req = SearchRequest::query(q).method(Method::Rwmd).topl(10).trace(traced);
        let t0 = Instant::now();
        let resp = eng.execute(&req).unwrap();
        lat.push(t0.elapsed().as_nanos() as u64);
        assert_eq!(resp.spans.is_some(), traced, "trace flag must decide the timeline");
    }
    lat.sort_unstable();
    lat[lat.len() / 2] as f64 / 1e3
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n_docs, reqs, rounds) = if full { (1500, 60, 15) } else { (600, 40, 9) };
    let ds = dataset(n_docs);
    let eng_off = engine(&ds, 0); // tracing hardware present, disabled
    let eng_armed = engine(&ds, u64::MAX); // slow-query sessions, never logged
    assert!(!eng_off.tracer().enabled());
    assert!(eng_armed.tracer().enabled());

    println!("# Tracing overhead on the query path (n={n_docs}, reqs/round={reqs}, rounds={rounds})");

    // interleave every mode within each round so clock drift and cache
    // state hit all of them equally; off is sampled twice (A/B) to
    // establish the identical-code noise floor
    let (mut off_a, mut off_b, mut armed, mut traced) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for r in 0..rounds {
        off_a.push(measure(&eng_off, &ds, reqs, false, r));
        armed.push(measure(&eng_armed, &ds, reqs, false, r));
        traced.push(measure(&eng_off, &ds, reqs, true, r));
        off_b.push(measure(&eng_off, &ds, reqs, false, r));
    }
    let (off_a, off_b) = (median(&mut off_a), median(&mut off_b));
    let (armed, traced) = (median(&mut armed), median(&mut traced));
    let off = off_a.min(off_b);

    let disabled_delta_pct = 100.0 * (off_a - off_b).abs() / off;
    let armed_pct = 100.0 * (armed / off - 1.0);
    let traced_pct = 100.0 * (traced / off - 1.0);
    println!("{:>10} {:>12} {:>12}", "mode", "p50_us", "overhead_%");
    println!("{:>10} {:>12.1} {:>12}", "off(A)", off_a, "-");
    println!("{:>10} {:>12.1} {:>12.2}", "off(B)", off_b, disabled_delta_pct);
    println!("{:>10} {:>12.1} {:>12.2}", "armed", armed, armed_pct);
    println!("{:>10} {:>12.1} {:>12.2}", "traced", traced, traced_pct);

    let json = Json::obj(vec![
        ("bench", "trace_overhead".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n_docs.into()),
                ("requests_per_round", reqs.into()),
                ("rounds", rounds.into()),
                ("method", "rwmd".into()),
                ("full", full.into()),
            ]),
        ),
        ("off_p50_us", off.into()),
        ("armed_p50_us", armed.into()),
        ("traced_p50_us", traced.into()),
        ("disabled_delta_pct", disabled_delta_pct.into()),
        ("armed_overhead_pct", armed_pct.into()),
        ("traced_overhead_pct", traced_pct.into()),
        ("regenerate_with", "cargo bench --bench trace_overhead".into()),
    ]);
    let path = "BENCH_trace.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // acceptance: disabled tracing stays under 1% — two interleaved runs of
    // the guard-only path must be indistinguishable (an absolute 5µs floor
    // absorbs timer granularity on very fast requests; the env override
    // absorbs pathologically noisy shared runners)
    let max_pct = std::env::var("EMDPAR_TRACE_OVERHEAD_PCT")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0);
    let abs_us = (off_a - off_b).abs();
    if disabled_delta_pct > max_pct && abs_us > 5.0 {
        eprintln!(
            "FAIL: disabled-tracing delta {disabled_delta_pct:.2}% ({abs_us:.1}us) exceeds \
             {max_pct:.2}% — the off path must be free"
        );
        std::process::exit(1);
    }
    println!("disabled-tracing delta {disabled_delta_pct:.2}% within the {max_pct:.2}% gate");
    // sanity, not a gate: per-request sessions should cost little; traced
    // requests may legitimately pay for timeline assembly
    if armed_pct > 50.0 {
        eprintln!("WARN: slow-query arming costs {armed_pct:.1}% — investigate before enabling by default");
    }
}
