//! Tightness ablation (Theorem 2 quantified): how close is each lower
//! bound to the exact EMD, as a function of coordinate overlap?  This is
//! the design-space view behind Tables 5/6 — RWMD's gap explodes with
//! overlap while OMR/ACT stay tight.
//!
//! Run: `cargo bench --bench thm_chain`

use emdpar::approx::{act_symmetric, ict_symmetric, omr_symmetric, rwmd_symmetric};
use emdpar::core::{Embeddings, Histogram, Metric};
use emdpar::exact::emd;
use emdpar::util::rng::Rng;

fn random_vocab(rng: &mut Rng, v: usize, m: usize) -> Embeddings {
    Embeddings::new((0..v * m).map(|_| rng.normal() as f32).collect(), v, m)
}

fn overlapping_pair(
    rng: &mut Rng,
    v: usize,
    h: usize,
    overlap: f64,
) -> (Histogram, Histogram) {
    let idx_p = rng.sample_indices(v, h);
    let p = Histogram::from_pairs(
        idx_p.iter().map(|&i| (i as u32, rng.range_f64(0.05, 1.0) as f32)).collect(),
    )
    .normalized();
    let n_shared = (overlap * h as f64) as usize;
    let mut pairs: Vec<(u32, f32)> = idx_p
        .iter()
        .take(n_shared)
        .map(|&i| (i as u32, rng.range_f64(0.05, 1.0) as f32))
        .collect();
    while pairs.len() < h {
        let i = rng.below(v) as u32;
        if !pairs.iter().any(|&(j, _)| j == i) {
            pairs.push((i, rng.range_f64(0.05, 1.0) as f32));
        }
    }
    (p, Histogram::from_pairs(pairs).normalized())
}

fn main() {
    let samples = 40;
    let (v, h, m) = (48, 12, 4);
    println!("# Theorem-2 tightness: mean bound / EMD ratio vs coordinate overlap");
    println!("# {samples} random pairs per row; v={v} h={h} m={m}\n");
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "overlap", "RWMD", "OMR", "ACT-1", "ACT-3", "ACT-7", "ICT"
    );
    let mut rng = Rng::new(99);
    for overlap in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut sums = [0.0f64; 6];
        let mut count = 0;
        for _ in 0..samples {
            let vocab = random_vocab(&mut rng, v, m);
            let (p, q) = overlapping_pair(&mut rng, v, h, overlap);
            let ex = emd(&vocab, &p, &q, Metric::L2);
            if ex < 1e-9 {
                continue;
            }
            sums[0] += rwmd_symmetric(&vocab, &p, &q, Metric::L2) / ex;
            sums[1] += omr_symmetric(&vocab, &p, &q, Metric::L2) / ex;
            sums[2] += act_symmetric(&vocab, &p, &q, Metric::L2, 2) / ex;
            sums[3] += act_symmetric(&vocab, &p, &q, Metric::L2, 4) / ex;
            sums[4] += act_symmetric(&vocab, &p, &q, Metric::L2, 8) / ex;
            sums[5] += ict_symmetric(&vocab, &p, &q, Metric::L2) / ex;
            count += 1;
        }
        print!("{overlap:<10}");
        for s in sums {
            print!(" {:>8.4}", s / count as f64);
        }
        println!();
    }
    println!(
        "\n# expectation: every column <= 1 (lower bounds); RWMD column decays\n\
         # towards 0 as overlap grows; ACT columns increase with k towards ICT."
    );
}
