//! Tightness ablation (Theorem 2 quantified): how close is each lower
//! bound to the exact EMD, as a function of coordinate overlap?  This is
//! the design-space view behind Tables 5/6 — RWMD's gap explodes with
//! overlap while OMR/ACT stay tight.
//!
//! Run: `cargo bench --bench thm_chain`

use emdpar::prelude::{Distance, Embeddings, Histogram, Method, MethodRegistry, Metric};
use emdpar::util::rng::Rng;

fn random_vocab(rng: &mut Rng, v: usize, m: usize) -> Embeddings {
    Embeddings::new((0..v * m).map(|_| rng.normal() as f32).collect(), v, m)
}

fn overlapping_pair(
    rng: &mut Rng,
    v: usize,
    h: usize,
    overlap: f64,
) -> (Histogram, Histogram) {
    let idx_p = rng.sample_indices(v, h);
    let p = Histogram::from_pairs(
        idx_p.iter().map(|&i| (i as u32, rng.range_f64(0.05, 1.0) as f32)).collect(),
    )
    .normalized();
    let n_shared = (overlap * h as f64) as usize;
    let mut pairs: Vec<(u32, f32)> = idx_p
        .iter()
        .take(n_shared)
        .map(|&i| (i as u32, rng.range_f64(0.05, 1.0) as f32))
        .collect();
    while pairs.len() < h {
        let i = rng.below(v) as u32;
        if !pairs.iter().any(|&(j, _)| j == i) {
            pairs.push((i, rng.range_f64(0.05, 1.0) as f32));
        }
    }
    (p, Histogram::from_pairs(pairs).normalized())
}

fn main() {
    let samples = 40;
    let (v, h, m) = (48, 12, 4);
    // every bound resolved through the unified registry, not per-module fns
    let registry = MethodRegistry::new(Metric::L2);
    let chain = [
        Method::BowAdjusted,
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 4 },
        Method::Act { k: 8 },
        Method::Ict,
    ];
    let bounds: Vec<_> = chain.iter().map(|&m| registry.distance(m)).collect();
    let exact = registry.distance(Method::Exact);

    println!("# Theorem-2 tightness: mean bound / EMD ratio vs coordinate overlap");
    println!("# {samples} random pairs per row; v={v} h={h} m={m}\n");
    print!("{:<10}", "overlap");
    for b in &bounds {
        print!(" {:>8}", b.name());
    }
    println!();
    let mut rng = Rng::new(99);
    for overlap in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut sums = vec![0.0f64; bounds.len()];
        let mut count = 0;
        for _ in 0..samples {
            let vocab = random_vocab(&mut rng, v, m);
            let (p, q) = overlapping_pair(&mut rng, v, h, overlap);
            let ex = exact.distance(&vocab, &p, &q).unwrap();
            if ex < 1e-9 {
                continue;
            }
            for (slot, b) in sums.iter_mut().zip(&bounds) {
                *slot += b.distance(&vocab, &p, &q).unwrap() / ex;
            }
            count += 1;
        }
        print!("{overlap:<10}");
        for s in sums {
            print!(" {:>8.4}", s / count as f64);
        }
        println!();
    }
    println!(
        "\n# expectation: every column <= 1 (lower bounds); RWMD column decays\n\
         # towards 0 as overlap grows; ACT columns increase with k towards ICT."
    );
}
