//! Paper Table 3: LC-ACT complexity O(vhm + nhk) — runtime must be linear
//! in the iteration count k (Phase 2) on top of a fixed Phase-1 cost, and
//! linear in the database size n.
//!
//! Run: `cargo bench --bench table3_lcact`

use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::{act_direction_a, plan_query, PlanParams};
use emdpar::prelude::Metric;
use emdpar::util::stats::Bench;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let n = if full { 4000 } else { 1000 };
    let ds = generate_text(&TextConfig {
        n,
        vocab: 4000,
        dim: 64,
        doc_len: 80,
        classes: 10,
        seed: 6,
        ..Default::default()
    });
    let threads = emdpar::util::threadpool::default_threads();
    let vn = ds.embeddings.row_sq_norms();
    let query = ds.histogram(0);
    let mut bench = Bench::quick();

    println!("# Table 3 — LC-ACT O(vhm + nhk): runtime vs k (n={n})\n");
    println!("{:<8} {:>14} {:>14} {:>14}", "k", "phase1", "phase2", "total");
    for k in [1usize, 2, 4, 8, 16] {
        let p1 = bench.run(&format!("phase1 k={k}"), || {
            std::hint::black_box(plan_query(
                &ds.embeddings,
                &vn,
                &query,
                PlanParams { k, metric: Metric::L2, keep_d: false, threads, kernel: None },
            ));
        });
        let plan = plan_query(
            &ds.embeddings,
            &vn,
            &query,
            PlanParams { k, metric: Metric::L2, keep_d: false, threads, kernel: None },
        );
        let p2 = bench.run(&format!("phase2 k={k}"), || {
            std::hint::black_box(act_direction_a(&plan, &ds.matrix, threads));
        });
        println!(
            "{:<8} {:>11.3} ms {:>11.3} ms {:>11.3} ms",
            k,
            p1.per_iter.as_secs_f64() * 1e3,
            p2.per_iter.as_secs_f64() * 1e3,
            (p1.per_iter + p2.per_iter).as_secs_f64() * 1e3
        );
    }

    println!("\n# runtime vs database size n (k=2):");
    println!("{:<8} {:>14} {:>14}", "n", "phase2", "per-doc");
    for frac in [4usize, 2, 1] {
        let sub = n / frac;
        let subds = generate_text(&TextConfig {
            n: sub,
            vocab: 4000,
            dim: 64,
            doc_len: 80,
            classes: 10,
            seed: 6,
            ..Default::default()
        });
        let plan = plan_query(
            &subds.embeddings,
            &subds.embeddings.row_sq_norms(),
            &subds.histogram(0),
            PlanParams { k: 2, metric: Metric::L2, keep_d: false, threads, kernel: None },
        );
        let p2 = bench.run(&format!("phase2 n={sub}"), || {
            std::hint::black_box(act_direction_a(&plan, &subds.matrix, threads));
        });
        println!(
            "{:<8} {:>11.3} ms {:>11.3} us",
            sub,
            p2.per_iter.as_secs_f64() * 1e3,
            p2.per_iter.as_secs_f64() * 1e6 / sub as f64
        );
    }
    println!("\n# expectation: phase1 ~constant in k (top-k selection is cheap),");
    println!("# phase2 linear in k and linear in n — matching O(vhm + nhk).");
}
