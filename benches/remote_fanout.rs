//! Remote fan-out overhead: the hedged TCP fan-out (`emdpar node` shard
//! servers behind [`RemoteFleet`]) against the in-process sharded fan-out
//! on the same corpus, plus the hedge's tail-rescue behaviour with a
//! stalled primary replica.
//!
//! Emits machine-readable `BENCH_remote.json` in the working directory
//! (the repo root under `cargo bench`).  The run doubles as a correctness
//! gate: it exits non-zero when the remote results are not bit-identical
//! to the in-process merge or when the hedged query loses a shard.
//!
//! Run: `cargo bench --bench remote_fanout` (EMDPAR_BENCH_FULL=1 for the
//! bigger workload).

use std::io::Write;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::Ordering;

use emdpar::config::{RemoteParams, ShardParams};
use emdpar::data::{generate_text, TextConfig};
use emdpar::prelude::{
    spawn_node, Config, DatasetSpec, Histogram, Method, SearchEngine, SearchRequest, Topology,
};
use emdpar::util::json::Json;
use emdpar::util::stats::timed;

/// An endpoint that accepts and then never answers — the stalled primary
/// of the hedged scenario.
fn stalled_endpoint() -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { return };
            std::thread::spawn(move || {
                use std::io::Read;
                let mut buf = [0u8; 512];
                let mut r = &stream;
                while matches!(r.read(&mut buf), Ok(x) if x > 0) {}
            });
        }
    });
    addr
}

fn write_topology(path: &std::path::Path, lists: Vec<Vec<String>>) -> String {
    let topo = Topology::new(lists).unwrap();
    std::fs::write(path, topo.to_json().to_string_compact()).unwrap();
    path.to_string_lossy().into_owned()
}

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n, v, m, doc_len, nq, iters) =
        if full { (6000, 6000, 64, 60, 64, 5) } else { (1200, 1500, 32, 40, 32, 3) };
    let method = Method::Rwmd;
    let l = 10;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Remote fan-out: two emdpar nodes vs the in-process sharded merge");
    println!("# n={n} v={v} m={m} doc_len={doc_len} queries={nq} threads={threads}\n");

    let dir = std::env::temp_dir().join("emdpar_bench_remote");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("base.bin");
    let ds = generate_text(&TextConfig {
        n,
        vocab: v,
        dim: m,
        doc_len,
        topic_frac: 0.75,
        spread: 0.3,
        seed: 17,
        ..Default::default()
    });
    emdpar::data::save(&ds, &base).unwrap();

    let node_cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads,
        linger_ms: 1,
        ..Default::default()
    };
    let n0 = spawn_node(node_cfg.clone(), 0, 2, "127.0.0.1:0").unwrap();
    let n1 = spawn_node(node_cfg, 1, 2, "127.0.0.1:0").unwrap();
    let (a0, a1) = (n0.addr().unwrap().to_string(), n1.addr().unwrap().to_string());

    let topo = write_topology(&dir.join("topo.json"), vec![vec![a0.clone()], vec![a1.clone()]]);
    let hedged_topo = write_topology(
        &dir.join("topo_hedged.json"),
        vec![vec![stalled_endpoint().to_string(), a0], vec![a1]],
    );

    let mk = |remote: Option<RemoteParams>| Config {
        dataset: DatasetSpec::File(base.clone()),
        threads,
        sharded: Some(ShardParams { shards: 2, max_docs_per_shard: usize::MAX >> 1 }),
        remote,
        ..Default::default()
    };
    let local = SearchEngine::from_config(mk(None)).unwrap();
    let remote = SearchEngine::from_config(mk(Some(RemoteParams {
        topology: topo,
        shard_timeout_ms: 10_000,
        hedge_ms: 0,
        pool: 4,
        retries: 2,
    })))
    .unwrap();

    let queries: Vec<Histogram> = (0..nq).map(|i| ds.histogram(i * n / nq)).collect();
    let req = SearchRequest::batch(queries).method(method).topl(l);

    // warm both paths (page cache, connection pools), checking identity on
    // the warm-up responses
    let local_resp = local.execute(&req).unwrap();
    let remote_resp = remote.execute(&req).unwrap();
    let bit_identical = local_resp.results.iter().zip(&remote_resp.results).all(|(a, b)| {
        a.hits
            .iter()
            .map(|&(d, id)| (d.to_bits(), id))
            .eq(b.hits.iter().map(|&(d, id)| (d.to_bits(), id)))
    });
    println!(
        "bit-identical at full probe: {bit_identical} (partial: {})",
        remote_resp.stats.partial
    );

    let mut t_local = f64::MAX;
    let mut t_remote = f64::MAX;
    for _ in 0..iters {
        let (_, t) = timed(|| local.execute(&req).unwrap());
        t_local = t_local.min(t.as_secs_f64());
        let (_, t) = timed(|| remote.execute(&req).unwrap());
        t_remote = t_remote.min(t.as_secs_f64());
    }
    let local_qps = nq as f64 / t_local;
    let remote_qps = nq as f64 / t_remote;
    let overhead = t_remote / t_local;
    println!("in-process: {local_qps:>8.1} queries/s");
    println!("remote:     {remote_qps:>8.1} queries/s ({overhead:.2}x the in-process time)\n");

    // tail rescue: shard 0's primary stalls forever; the hedge must answer
    // from the replica without dropping the shard
    let hedging = SearchEngine::from_config(mk(Some(RemoteParams {
        topology: hedged_topo,
        shard_timeout_ms: 10_000,
        hedge_ms: 2,
        pool: 4,
        retries: 2,
    })))
    .unwrap();
    let (hedge_resp, t_hedge) = timed(|| hedging.execute(&req).unwrap());
    let hedges = hedging.metrics().remote_hedges.load(Ordering::Relaxed);
    let hedge_partial = hedge_resp.stats.partial;
    println!(
        "hedged (stalled primary): {:.1} queries/s, {hedges} hedge(s), partial: {hedge_partial}",
        nq as f64 / t_hedge.as_secs_f64()
    );

    let json = Json::obj(vec![
        ("bench", "remote_fanout".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n.into()),
                ("v", v.into()),
                ("m", m.into()),
                ("doc_len", doc_len.into()),
                ("queries", nq.into()),
                ("method", method.name().into()),
                ("l", l.into()),
                ("threads", threads.into()),
                ("shards", 2.into()),
                ("full", full.into()),
            ]),
        ),
        ("bit_identical_full_probe", bit_identical.into()),
        ("in_process_queries_per_s", local_qps.into()),
        ("remote_queries_per_s", remote_qps.into()),
        ("remote_overhead_x", overhead.into()),
        (
            "hedged_stalled_primary",
            Json::obj(vec![
                ("queries_per_s", (nq as f64 / t_hedge.as_secs_f64()).into()),
                ("hedges", (hedges as usize).into()),
                ("partial", hedge_partial.into()),
            ]),
        ),
        ("regenerate_with", "cargo bench --bench remote_fanout".into()),
    ]);
    let path = "BENCH_remote.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // correctness gates: a silent merge divergence or a dropped shard under
    // hedging fails the bench run outright
    if !bit_identical {
        eprintln!("FAIL: remote fan-out diverged from the in-process merge at full probe");
        std::process::exit(1);
    }
    if hedge_partial {
        eprintln!("FAIL: hedged query lost a shard despite a live replica");
        std::process::exit(1);
    }
}
