//! Paper Fig. 8(b): runtime-vs-accuracy on the MNIST subset protocol — the
//! first nq images query the full database; comparators include Sinkhorn
//! (λ=20) and exact WMD, both on the same subset.
//!
//! Run: `cargo bench --bench fig8b_mnist` (EMDPAR_BENCH_FULL=1 for the
//! larger database).

use std::time::Instant;

use emdpar::data::{generate_mnist, MnistConfig};
use emdpar::eval::{precision_at, render_markdown, sweep_subset};
use emdpar::exact::wmd_topl_pruned;
use emdpar::prelude::{Distance, EngineParams, Method, MethodRegistry, Metric};
use emdpar::util::threadpool::{parallel_for, SyncSlice};

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let n = if full { 6000 } else { 1200 };
    let nq = if full { 600 } else { 120 };
    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n, ..Default::default() }));
    let stats = ds.stats();
    println!(
        "# Fig. 8(b) — {} n={n} nq={nq} avg_h={:.1}  (paper: 60000/6000/149.9)\n",
        ds.name, stats.avg_h
    );

    let ls = [1usize, 16, 128].iter().copied().filter(|&l| l < n).collect::<Vec<_>>();
    let threads = emdpar::util::threadpool::default_threads();
    let rows = sweep_subset(
        &ds,
        nq,
        &[Method::Bow, Method::Rwmd, Method::Omr, Method::Act { k: 2 }, Method::Act { k: 8 }],
        &ls,
        EngineParams { threads, ..Default::default() },
    )
    .expect("sweep");
    println!("{}", render_markdown("subset protocol (first nq query all n)", &rows));

    // --- Sinkhorn comparator on a smaller subset (quadratic per pair),
    //     resolved through the registry like every other method -----------
    let sq = if full { 8 } else { 4 };
    let sn = if full { 600 } else { 150 };
    let db: Vec<_> = (0..sn).map(|u| ds.histogram(u)).collect();
    let sink_dist = MethodRegistry::new(Metric::L2).distance(Method::Sinkhorn);
    let sink_dist = sink_dist.as_ref();
    let t0 = Instant::now();
    let mut sink = vec![0.0f32; sq * sn];
    {
        let slots = SyncSlice::new(&mut sink);
        parallel_for(sq * sn, threads, |start, end| {
            for idx in start..end {
                let (uq, u) = (idx / sn, idx % sn);
                let d = sink_dist
                    .distance(&ds.embeddings, &db[uq], &db[u])
                    .unwrap_or(f64::INFINITY) as f32;
                unsafe { slots.write(idx, d) };
            }
        });
    }
    let sink_elapsed = t0.elapsed();
    let sink_prec = precision_at(&sink, &ds.labels[..sq], &ds.labels[..sn], 16.min(sn - 1), true);
    let sink_rate = (sq * sn) as f64 / sink_elapsed.as_secs_f64();
    println!(
        "| Sinkhorn λ=20 | {sink_elapsed:?} | {sink_rate:.3e} pairs/s | p@16 {sink_prec:.4} | ({sq}x{sn} pairs) |"
    );

    // --- WMD comparator -----------------------------------------------------
    let t0 = Instant::now();
    let mut wmd = vec![f32::INFINITY; sq * sn];
    for uq in 0..sq {
        let (top, _) = wmd_topl_pruned(&ds.embeddings, &db[uq], &db, Metric::L2, 17);
        for (d, u) in top {
            wmd[uq * sn + u] = d as f32;
        }
    }
    let wmd_elapsed = t0.elapsed();
    let wmd_prec = precision_at(&wmd, &ds.labels[..sq], &ds.labels[..sn], 16.min(sn - 1), true);
    let wmd_rate = (sq * sn) as f64 / wmd_elapsed.as_secs_f64();
    println!(
        "| WMD (exact+prune) | {wmd_elapsed:?} | {wmd_rate:.3e} pairs/s | p@16 {wmd_prec:.4} | ({sq}x{sn} pairs) |"
    );

    if let Some(act1) = rows.iter().find(|r| r.method == "ACT-1") {
        println!(
            "\n# headline: ACT-1 {:.0}x faster than Sinkhorn, {:.0}x faster than WMD \
             (paper: ~4 orders of magnitude on GPU)",
            act1.throughput() / sink_rate,
            act1.throughput() / wmd_rate
        );
    }
}
