//! Audit overhead: what does online recall auditing cost the serving path?
//!
//! Three interleaved modes over the same corpus and query stream, each
//! measured as serial request/response round trips against a live
//! [`ReactorServer`] (the layer that hosts the sampling hook):
//!
//! * `off`     — `audit_sample = 0`: the hot path takes one branch on an
//!               immutable field, no atomics.  Measured twice (interleaved
//!               halves A/B) so the off-path cost can be bounded against
//!               itself — the identical-code noise floor.
//! * `sampled` — `audit_sample = 64`: 1-in-64 served queries are cloned,
//!               queued, and replayed at full probe on the background
//!               worker while serving continues.
//!
//! The gate is on tail latency: at 1/64 sampling the p99 round trip must
//! inflate by under 2% against the off path (the background replays are
//! the realistic cost — they share the machine, never the request path).
//!
//! Emits machine-readable `BENCH_audit.json`.  Run:
//! `cargo bench --bench audit_overhead` (EMDPAR_BENCH_FULL=1 for more
//! samples; EMDPAR_AUDIT_OVERHEAD_PCT overrides the 2% p99 gate).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use emdpar::config::{Config, DatasetSpec, ServeParams};
use emdpar::coordinator::SearchEngine;
use emdpar::prelude::ReactorServer;
use emdpar::util::json::Json;

fn server(n: usize, audit_sample: u64) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let engine = SearchEngine::from_config(Config {
        dataset: DatasetSpec::SynthText { n, vocab: 400, dim: 16, seed: 11 },
        threads: 2,
        linger_ms: 1,
        serve: ServeParams { audit_sample, ..Default::default() },
        ..Config::default()
    })
    .unwrap();
    let srv = ReactorServer::bind(engine, "127.0.0.1:0").unwrap();
    let addr = srv.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let _ = srv.serve(); // runs until the process exits
    });
    (addr, handle)
}

/// One round: `reqs` serial round trips down a fresh connection; returns
/// the round's p99 in µs.
fn measure(addr: SocketAddr, reqs: usize, round: usize, n_docs: usize) -> f64 {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut lat = Vec::with_capacity(reqs);
    let mut resp = String::new();
    for i in 0..reqs {
        let id = (round * 31 + i * 7) % n_docs;
        let line = format!("{{\"op\": \"search_id\", \"id\": {id}, \"l\": 10, \"method\": \"rwmd\"}}\n");
        let t0 = Instant::now();
        w.write_all(line.as_bytes()).unwrap();
        resp.clear();
        r.read_line(&mut resp).unwrap();
        lat.push(t0.elapsed().as_nanos() as u64);
        assert!(resp.contains("hits"), "{resp}");
    }
    lat.sort_unstable();
    lat[(lat.len() * 99 / 100).min(lat.len() - 1)] as f64 / 1e3
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n_docs, reqs, rounds) = if full { (600, 400, 9) } else { (600, 150, 5) };
    let (addr_off, _h_off) = server(n_docs, 0);
    let (addr_on, _h_on) = server(n_docs, 64);

    println!(
        "# Recall-audit overhead on the serving path \
         (n={n_docs}, reqs/round={reqs}, rounds={rounds}, sample=1/64)"
    );

    // interleave the modes within each round so drift hits them equally;
    // off is sampled twice (A/B) for the identical-code noise floor
    let (mut off_a, mut off_b, mut sampled) = (Vec::new(), Vec::new(), Vec::new());
    for round in 0..rounds {
        off_a.push(measure(addr_off, reqs, round, n_docs));
        sampled.push(measure(addr_on, reqs, round, n_docs));
        off_b.push(measure(addr_off, reqs, round, n_docs));
    }
    let (off_a, off_b) = (median(&mut off_a), median(&mut off_b));
    let sampled = median(&mut sampled);
    let off = off_a.min(off_b);

    let noise_pct = 100.0 * (off_a - off_b).abs() / off;
    let inflation_pct = 100.0 * (sampled / off - 1.0);
    println!("{:>10} {:>12} {:>12}", "mode", "p99_us", "inflation_%");
    println!("{:>10} {:>12.1} {:>12}", "off(A)", off_a, "-");
    println!("{:>10} {:>12.1} {:>12.2}", "off(B)", off_b, noise_pct);
    println!("{:>10} {:>12.1} {:>12.2}", "sampled", sampled, inflation_pct);

    let json = Json::obj(vec![
        ("bench", "audit_overhead".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n_docs.into()),
                ("requests_per_round", reqs.into()),
                ("rounds", rounds.into()),
                ("method", "rwmd".into()),
                ("audit_sample", 64usize.into()),
                ("full", full.into()),
            ]),
        ),
        ("off_p99_us", off.into()),
        ("sampled_p99_us", sampled.into()),
        ("noise_pct", noise_pct.into()),
        ("p99_inflation_pct", inflation_pct.into()),
        ("regenerate_with", "cargo bench --bench audit_overhead".into()),
    ]);
    let path = "BENCH_audit.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // acceptance: 1/64 sampling must not inflate the p99 round trip by
    // more than 2% (an absolute 20µs floor absorbs timer granularity and
    // scheduler jitter on very fast requests; the env override absorbs
    // pathologically noisy shared runners)
    let max_pct = std::env::var("EMDPAR_AUDIT_OVERHEAD_PCT")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(2.0);
    let abs_us = sampled - off;
    if inflation_pct > max_pct && abs_us > 20.0 {
        eprintln!(
            "FAIL: 1/64 audit sampling inflates p99 by {inflation_pct:.2}% ({abs_us:.1}us), \
             over the {max_pct:.2}% gate"
        );
        std::process::exit(1);
    }
    println!("p99 inflation {inflation_pct:.2}% within the {max_pct:.2}% gate");
}
