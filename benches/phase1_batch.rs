//! Phase-1 roofline report: per-query vs batched planning, swept across
//! every SIMD kernel backend this host supports.
//!
//! Two axes, one workload:
//!
//! * **Batching** (the paper's data-parallel argument): one `plan_query`
//!   per query vs `BatchPlanner::plan_rows_into` blocks of B queries per
//!   vocabulary pass.  Both sides run the same outer parallel sweep the
//!   all-pairs path uses, so the ratio is the real throughput change.
//! * **SIMD dispatch** (ISSUE 7): the batched sweep re-runs with each
//!   backend `supported_backends()` reports — scalar reference, AVX2+F16C,
//!   AVX-512 — via `PlanParams::kernel`.  Every backend is bit-identical
//!   (enforced by the equivalence suite), so the per-backend plans/s,
//!   GFLOP/s and streamed bytes/plan below are pure speed, never accuracy.
//!
//! Emits a machine-readable `BENCH_phase1.json` in the working directory
//! (the repo root under `cargo bench`) so later PRs have a perf trajectory
//! to compare against.
//!
//! Run: `cargo bench --bench phase1_batch` (EMDPAR_BENCH_FULL=1 for the
//! bigger 20NG-scale workload; `RUSTFLAGS="-C target-cpu=native"` lets the
//! compiler keep up with the hand-written kernels on the scalar side).
//!
//! Enforcement knobs (both optional, both parsed as f64 floors):
//! * `EMDPAR_BENCH_MIN_SPEEDUP` — batched vs per-query plans/s;
//! * `EMDPAR_BENCH_MIN_SIMD_SPEEDUP` — best SIMD backend vs scalar
//!   (skipped with a notice when only the scalar backend is supported).

use std::io::Write;

use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::kernels::supported_backends;
use emdpar::lc::{plan_query, BatchPlanner, KernelBackend, PlanParams, PlanScratch, QueryPlan};
use emdpar::prelude::Metric;
use emdpar::util::json::Json;
use emdpar::util::stats::Bench;
use emdpar::util::threadpool::parallel_for;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    // synthetic 20NG-like workload: word-embedding-sized vocabulary so the
    // coordinate matrix far exceeds L2 cache and Phase 1 is stream-bound —
    // the regime the paper's batching argument targets
    let (v, m, h, nq) =
        if full { (30_000, 256, 80, 64) } else { (8_000, 128, 64, 32) };
    let k = 2; // ACT-1, the paper's default operating point
    let batch_block = 8;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Phase-1 roofline: batching x SIMD kernel backends");
    println!("# v={v} m={m} h={h} queries={nq} k={k} B={batch_block} threads={threads}\n");

    let ds = generate_text(&TextConfig {
        n: nq,
        classes: 4,
        vocab: v,
        dim: m,
        doc_len: h,
        seed: 20,
        ..Default::default()
    });
    let vn = ds.embeddings.row_sq_norms();
    let n = ds.len();

    // roofline model per plan (one query through Phase 1): the (v, h)
    // distance matrix costs one m-dim dot per entry — 2·v·h·m flops — and
    // streams the whole v×m coordinate matrix once per vocabulary pass, so
    // batching divides the streamed bytes by the block size B
    let flops_per_plan = 2.0 * v as f64 * h as f64 * m as f64;
    let stream_bytes_per_query = (v * m * 4) as f64;

    let mut bench = Bench::quick();

    let params = |kernel: Option<KernelBackend>| PlanParams {
        k,
        metric: Metric::L2,
        keep_d: false,
        threads: 1,
        kernel,
    };

    // ---- axis 1, baseline: one plan_query per query, parallel over
    // queries (the seed's all-pairs structure), auto-detected backend ----
    let per_query = bench.run("phase1 per-query sweep", || {
        parallel_for(n, threads, |start, end| {
            for u in start..end {
                let q = ds.histogram(u);
                std::hint::black_box(plan_query(&ds.embeddings, &vn, &q, params(None)));
            }
        });
    });

    // ---- axis 1 + 2: batched sweep, once per supported backend (scalar
    // first — it is the speedup denominator) ----
    let planner = BatchPlanner::new(&ds.embeddings, &vn);
    let mut batched_sweep = |kernel: Option<KernelBackend>, label: &str| {
        let stat = bench.run(label, || {
            parallel_for(n, threads, |start, end| {
                let mut scratch = PlanScratch::new();
                let mut plans: Vec<QueryPlan> = Vec::new();
                let mut block: Vec<(&[u32], &[f32])> = Vec::with_capacity(batch_block);
                let mut u0 = start;
                while u0 < end {
                    let u1 = (u0 + batch_block).min(end);
                    block.clear();
                    for u in u0..u1 {
                        block.push(ds.matrix.row(u));
                    }
                    planner.plan_rows_into(&block, params(kernel), &mut scratch, &mut plans);
                    std::hint::black_box(&plans);
                    u0 = u1;
                }
            });
        });
        n as f64 / stat.per_iter.as_secs_f64()
    };

    let batched_qps = batched_sweep(None, "phase1 batched sweep  ");

    let backends = supported_backends();
    let mut backend_rows: Vec<(KernelBackend, f64)> = Vec::new();
    for &b in &backends {
        let qps = batched_sweep(Some(b), &format!("phase1 batched [{b}]"));
        backend_rows.push((b, qps));
    }
    let scalar_qps = backend_rows
        .iter()
        .find(|(b, _)| *b == KernelBackend::Scalar)
        .map(|&(_, q)| q)
        .expect("scalar backend is always supported");

    let per_query_qps = n as f64 / per_query.per_iter.as_secs_f64();
    let speedup = batched_qps / per_query_qps;
    let bytes_per_plan = stream_bytes_per_query / batch_block as f64;

    println!("\nper-query  : {:>10.1} plans/s", per_query_qps);
    println!("batched    : {:>10.1} plans/s", batched_qps);
    println!("speedup    : {:>10.2}x  (target: >= 2x)\n", speedup);
    println!(
        "{:<10} {:>12} {:>10} {:>14} {:>12}",
        "backend", "plans/s", "GFLOP/s", "bytes/plan", "vs scalar"
    );
    for &(b, qps) in &backend_rows {
        println!(
            "{:<10} {:>12.1} {:>10.2} {:>14.0} {:>11.2}x",
            b.name(),
            qps,
            qps * flops_per_plan / 1e9,
            bytes_per_plan,
            qps / scalar_qps
        );
    }

    let best_simd = backend_rows
        .iter()
        .filter(|(b, _)| *b != KernelBackend::Scalar)
        .map(|&(_, q)| q / scalar_qps)
        .reduce(f64::max);

    let json = Json::obj(vec![
        ("bench", "phase1_batch".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("v", v.into()),
                ("m", m.into()),
                ("h", h.into()),
                ("queries", nq.into()),
                ("k", k.into()),
                ("batch_block", batch_block.into()),
                ("threads", threads.into()),
                ("full", full.into()),
            ]),
        ),
        (
            "roofline",
            Json::obj(vec![
                ("flops_per_plan", flops_per_plan.into()),
                ("stream_bytes_per_plan", bytes_per_plan.into()),
            ]),
        ),
        ("per_query_plans_per_s", per_query_qps.into()),
        ("batched_plans_per_s", batched_qps.into()),
        ("speedup", speedup.into()),
        (
            "backends",
            Json::Arr(
                backend_rows
                    .iter()
                    .map(|&(b, qps)| {
                        Json::obj(vec![
                            ("name", b.name().into()),
                            ("plans_per_s", qps.into()),
                            ("gflops", (qps * flops_per_plan / 1e9).into()),
                            ("bytes_per_plan", bytes_per_plan.into()),
                            ("speedup_vs_scalar", (qps / scalar_qps).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("simd_speedup_vs_scalar", best_simd.map(Json::from).unwrap_or(Json::Null)),
        ("regenerate_with", "cargo bench --bench phase1_batch".into()),
    ]);
    let path = "BENCH_phase1.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    // Optional enforcement: EMDPAR_BENCH_MIN_SPEEDUP=<x> fails the run if
    // the batched kernel does not beat the per-query baseline by x — CI
    // uses 1.0 as a can't-regress floor (the 2x acceptance target is judged
    // on dedicated hardware, not shared runners).
    if let Ok(s) = std::env::var("EMDPAR_BENCH_MIN_SPEEDUP") {
        if let Ok(min) = s.parse::<f64>() {
            if speedup < min {
                eprintln!("FAIL: speedup {speedup:.2}x below required {min:.2}x");
                std::process::exit(1);
            }
            println!("speedup {speedup:.2}x meets the required {min:.2}x floor");
        }
    }

    // EMDPAR_BENCH_MIN_SIMD_SPEEDUP=<x>: the best SIMD backend must beat
    // the scalar reference by x.  Skipped (with a notice) on hosts where
    // only the scalar backend is supported — CI's kernel-matrix job keys
    // the same way off /proc/cpuinfo.
    if let Ok(s) = std::env::var("EMDPAR_BENCH_MIN_SIMD_SPEEDUP") {
        if let Ok(min) = s.parse::<f64>() {
            match best_simd {
                None => println!(
                    "NOTICE: no SIMD backend supported on this host; skipping the \
                     {min:.2}x SIMD floor"
                ),
                Some(simd) if simd < min => {
                    eprintln!("FAIL: SIMD speedup {simd:.2}x below required {min:.2}x");
                    std::process::exit(1);
                }
                Some(simd) => {
                    println!("SIMD speedup {simd:.2}x meets the required {min:.2}x floor")
                }
            }
        }
    }
}
