//! Phase-1 micro-benchmark: per-query planning (`plan_query`, the seed's
//! all-pairs inner loop) vs the batched multi-query kernel
//! (`BatchPlanner::plan_rows_into`, blocks of B queries per vocabulary
//! pass).  Both sides run the same outer data-parallel sweep the all-pairs
//! path uses (parallel over queries / query blocks, serial inside), so the
//! ratio is the real Phase-1 throughput change an all-pairs sweep sees.
//!
//! Emits a machine-readable `BENCH_phase1.json` in the working directory
//! (the repo root under `cargo bench`) so later PRs have a perf trajectory
//! to compare against.
//!
//! Run: `cargo bench --bench phase1_batch` (EMDPAR_BENCH_FULL=1 for the
//! bigger 20NG-scale workload).

use std::io::Write;

use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::{plan_query, BatchPlanner, PlanParams, PlanScratch, QueryPlan};
use emdpar::prelude::Metric;
use emdpar::util::json::Json;
use emdpar::util::stats::Bench;
use emdpar::util::threadpool::parallel_for;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    // synthetic 20NG-like workload: word-embedding-sized vocabulary so the
    // coordinate matrix far exceeds L2 cache and Phase 1 is stream-bound —
    // the regime the paper's batching argument targets
    let (v, m, h, nq) =
        if full { (30_000, 256, 80, 64) } else { (8_000, 128, 64, 32) };
    let k = 2; // ACT-1, the paper's default operating point
    let batch_block = 8;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# Phase-1 batching: per-query vs multi-query kernel");
    println!("# v={v} m={m} h={h} queries={nq} k={k} B={batch_block} threads={threads}\n");

    let ds = generate_text(&TextConfig {
        n: nq,
        classes: 4,
        vocab: v,
        dim: m,
        doc_len: h,
        seed: 20,
        ..Default::default()
    });
    let vn = ds.embeddings.row_sq_norms();
    let params = PlanParams { k, metric: Metric::L2, keep_d: false, threads: 1 };
    let n = ds.len();

    let mut bench = Bench::quick();

    // ---- baseline: one plan_query per query, parallel over queries (the
    // seed's all-pairs structure) ----
    let per_query = bench.run("phase1 per-query sweep", || {
        parallel_for(n, threads, |start, end| {
            for u in start..end {
                let q = ds.histogram(u);
                std::hint::black_box(plan_query(&ds.embeddings, &vn, &q, params));
            }
        });
    });

    // ---- batched: blocks of B queries per vocabulary pass, parallel over
    // blocks, one scratch arena per worker chunk ----
    let planner = BatchPlanner::new(&ds.embeddings, &vn);
    let batched = bench.run("phase1 batched sweep  ", || {
        parallel_for(n, threads, |start, end| {
            let mut scratch = PlanScratch::new();
            let mut plans: Vec<QueryPlan> = Vec::new();
            let mut block: Vec<(&[u32], &[f32])> = Vec::with_capacity(batch_block);
            let mut u0 = start;
            while u0 < end {
                let u1 = (u0 + batch_block).min(end);
                block.clear();
                for u in u0..u1 {
                    block.push(ds.matrix.row(u));
                }
                planner.plan_rows_into(&block, params, &mut scratch, &mut plans);
                std::hint::black_box(&plans);
                u0 = u1;
            }
        });
    });

    let per_query_qps = n as f64 / per_query.per_iter.as_secs_f64();
    let batched_qps = n as f64 / batched.per_iter.as_secs_f64();
    let speedup = batched_qps / per_query_qps;

    println!("\nper-query  : {:>10.1} plans/s", per_query_qps);
    println!("batched    : {:>10.1} plans/s", batched_qps);
    println!("speedup    : {:>10.2}x  (target: >= 2x)", speedup);

    let json = Json::obj(vec![
        ("bench", "phase1_batch".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("v", v.into()),
                ("m", m.into()),
                ("h", h.into()),
                ("queries", nq.into()),
                ("k", k.into()),
                ("batch_block", batch_block.into()),
                ("threads", threads.into()),
                ("full", full.into()),
            ]),
        ),
        ("per_query_plans_per_s", per_query_qps.into()),
        ("batched_plans_per_s", batched_qps.into()),
        ("speedup", speedup.into()),
        ("regenerate_with", "cargo bench --bench phase1_batch".into()),
    ]);
    let path = "BENCH_phase1.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    // Optional enforcement: EMDPAR_BENCH_MIN_SPEEDUP=<x> fails the run if
    // the batched kernel does not beat the per-query baseline by x — CI
    // uses 1.0 as a can't-regress floor (the 2x acceptance target is judged
    // on dedicated hardware, not shared runners).
    if let Ok(s) = std::env::var("EMDPAR_BENCH_MIN_SPEEDUP") {
        if let Ok(min) = s.parse::<f64>() {
            if speedup < min {
                eprintln!("FAIL: speedup {speedup:.2}x below required {min:.2}x");
                std::process::exit(1);
            }
            println!("speedup {speedup:.2}x meets the required {min:.2}x floor");
        }
    }
}
