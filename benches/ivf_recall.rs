//! IVF pruning sweep: candidates-scored fraction vs recall@ℓ vs speedup
//! over exhaustive batched search, across `nprobe`.
//!
//! Emits machine-readable `BENCH_ivf.json` in the working directory (the
//! repo root under `cargo bench`), the pruning companion of
//! `BENCH_phase1.json`.
//!
//! Run: `cargo bench --bench ivf_recall` (EMDPAR_BENCH_FULL=1 for the
//! bigger workload).

use std::io::Write;
use std::sync::Arc;

use emdpar::config::IndexParams;
use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::recall_at;
use emdpar::prelude::{EngineBuilder, Histogram, Method, SearchRequest};
use emdpar::util::json::Json;
use emdpar::util::stats::timed;

fn main() {
    let full = std::env::var("EMDPAR_BENCH_FULL").is_ok();
    let (n, v, m, doc_len, nq, nlist) =
        if full { (8000, 8000, 64, 60, 64, 64) } else { (1500, 2000, 32, 40, 24, 32) };
    let method = Method::Act { k: 2 };
    let l = 10;
    let threads = emdpar::util::threadpool::default_threads();

    println!("# IVF pruning: recall@{l} vs candidate fraction vs speedup");
    println!("# n={n} v={v} m={m} doc_len={doc_len} queries={nq} nlist={nlist} threads={threads}\n");

    let ds = Arc::new(generate_text(&TextConfig {
        n,
        vocab: v,
        dim: m,
        doc_len,
        // clustered regime (the workload an IVF index serves): topic words
        // dominate, so centroids separate and the sweep shows a clean
        // recall-vs-fraction frontier
        topic_frac: 0.75,
        spread: 0.3,
        seed: 31,
        ..Default::default()
    }));
    // the serving engine: dataset + trained IVF index behind the query
    // planner (every sweep point below dispatches a SearchRequest)
    let (engine, t_train) = timed(|| {
        EngineBuilder::new()
            .dataset(Arc::clone(&ds))
            .threads(threads)
            .symmetric(false)
            .index(IndexParams {
                nlist,
                nprobe: 1,
                train_iters: 10,
                seed: 7,
                min_points_per_list: 2,
            })
            .build_search()
            .unwrap()
    });
    let trained_nlist = engine.index().map(|ix| ix.nlist()).unwrap_or(0);
    println!(
        "trained {trained_nlist} lists over {n} docs in {:.2}s (engine build included)\n",
        t_train.as_secs_f64()
    );

    let queries: Vec<Histogram> = (0..nq).map(|i| ds.histogram(i * n / nq)).collect();

    // exhaustive truth + baseline timing (the planner's own scoring engine)
    let native = engine.native();
    let (flat, t_exh) = timed(|| native.distances_batch(&queries, method));
    let truth: Vec<Vec<usize>> = (0..nq)
        .map(|qi| {
            let row = &flat[qi * n..(qi + 1) * n];
            let mut top = emdpar::coordinator::TopL::new(l);
            top.push_slice(row, 0);
            top.into_sorted().into_iter().map(|(_, id)| id).collect()
        })
        .collect();
    println!(
        "exhaustive: {:.1} queries/s ({} docs scored per query)",
        nq as f64 / t_exh.as_secs_f64(),
        n
    );
    println!(
        "\n{:>6} {:>10} {:>10} {:>10} {:>10}",
        "nprobe", "cand_frac", "recall", "qps", "speedup"
    );

    let mut rows = Vec::new();
    for &nprobe in &[1usize, 2, 4, 8, 16, 32, 64] {
        if nprobe > trained_nlist {
            continue;
        }
        let request =
            SearchRequest::batch(queries.clone()).method(method).topl(l).nprobe(nprobe);
        let (resp, t) = timed(|| engine.execute(&request).unwrap());
        let mut recall = 0.0f64;
        for (t_ids, res) in truth.iter().zip(&resp.results) {
            let got: Vec<usize> = res.hits.iter().map(|&(_, id)| id).collect();
            recall += recall_at(t_ids, &got);
        }
        recall /= nq as f64;
        let frac = resp.stats.candidates_scored as f64 / (nq * n) as f64;
        let qps = nq as f64 / t.as_secs_f64();
        let speedup = t_exh.as_secs_f64() / t.as_secs_f64();
        println!("{nprobe:>6} {frac:>10.3} {recall:>10.3} {qps:>10.1} {speedup:>9.2}x");
        rows.push(Json::obj(vec![
            ("nprobe", nprobe.into()),
            ("candidate_fraction", frac.into()),
            ("recall", recall.into()),
            ("queries_per_s", qps.into()),
            ("speedup_vs_exhaustive", speedup.into()),
        ]));
    }

    let best_cheap_recall = rows_best_recall(&rows);
    let json = Json::obj(vec![
        ("bench", "ivf_recall".into()),
        ("status", "measured".into()),
        (
            "workload",
            Json::obj(vec![
                ("n", n.into()),
                ("v", v.into()),
                ("m", m.into()),
                ("doc_len", doc_len.into()),
                ("queries", nq.into()),
                ("nlist", trained_nlist.into()),
                ("method", method.name().into()),
                ("l", l.into()),
                ("threads", threads.into()),
                ("full", full.into()),
            ]),
        ),
        ("train_seconds", t_train.as_secs_f64().into()),
        ("exhaustive_queries_per_s", (nq as f64 / t_exh.as_secs_f64()).into()),
        ("sweep", Json::Arr(rows)),
        ("regenerate_with", "cargo bench --bench ivf_recall".into()),
    ]);
    let path = "BENCH_ivf.json";
    match std::fs::File::create(path)
        .and_then(|mut f| writeln!(f, "{}", json.to_string_pretty()))
    {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    // Optional enforcement: CI uses a modest floor so a broken index (zero
    // recall or no pruning win) fails the push while shared-runner noise
    // does not.  EMDPAR_IVF_MIN_RECALL applies to the highest-recall sweep
    // point with candidate_fraction <= 0.5.
    if let Ok(s) = std::env::var("EMDPAR_IVF_MIN_RECALL") {
        if let Ok(min) = s.parse::<f64>() {
            if best_cheap_recall < min {
                eprintln!(
                    "FAIL: best cheap recall {best_cheap_recall:.3} below required {min:.3}"
                );
                std::process::exit(1);
            }
            println!(
                "best cheap recall {best_cheap_recall:.3} meets the required {min:.3} floor"
            );
        }
    }
}

/// Best recall among sweep points that scored at most half the database.
fn rows_best_recall(rows: &[Json]) -> f64 {
    rows.iter()
        .filter(|r| {
            r.get("candidate_fraction").and_then(Json::as_f64).unwrap_or(1.0) <= 0.5
        })
        .filter_map(|r| r.get("recall").and_then(Json::as_f64))
        .fold(0.0, f64::max)
}
