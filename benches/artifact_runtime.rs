//! PJRT artifact-path overhead: per-phase runtime of the AOT-compiled
//! JAX/Pallas pipeline vs the native Rust engine on identical shapes —
//! quantifies what the HLO round-trip costs on this CPU testbed (on TPU
//! the artifact path is the fast one; here it validates composition).
//!
//! Run: `cargo bench --bench artifact_runtime` (needs `make artifacts`).

use std::path::Path;

use emdpar::data::{generate_text, TextConfig};
use emdpar::prelude::{EngineParams, LcEngine, Method, Metric};
use emdpar::runtime::{ArtifactEngine, Executor};
use emdpar::util::stats::Bench;

fn main() {
    let dir = Path::new("artifacts");
    let exec = match Executor::new(dir) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("# PJRT platform: {}\n", exec.platform());

    for profile in ["dev", "text"] {
        let Some(spec) = exec
            .manifest()
            .artifacts
            .values()
            .find(|a| a.profile == profile && a.entry == emdpar::runtime::Entry::Fused)
        else {
            continue;
        };
        let ds = generate_text(&TextConfig {
            n: spec.n * 2, // two tiles
            classes: 4,
            vocab: spec.v,
            dim: spec.m,
            doc_len: (spec.h / 2).max(5),
            seed: 17,
            ..Default::default()
        });
        let art = ArtifactEngine::new(&exec, &ds, profile).unwrap();
        let native = LcEngine::new(
            std::sync::Arc::new(ds.clone()),
            EngineParams { metric: Metric::L2, threads: emdpar::util::threadpool::default_threads(), symmetric: false, ..Default::default() },
        );
        let q = ds.histogram(0);
        let k = 2;
        // warm the compilation cache before timing
        art.distances(&q, k, false).unwrap();

        let mut bench = Bench::quick();
        let a = bench.run(&format!("{profile}: artifact ACT-1 query"), || {
            std::hint::black_box(art.distances(&q, k, false).unwrap());
        });
        let b = bench.run(&format!("{profile}: native   ACT-1 query"), || {
            std::hint::black_box(native.distances(&q, Method::Act { k }));
        });
        println!(
            "{profile}: v={} h={} n_tile={} tiles={} -> artifact {:.3} ms vs native {:.3} ms ({:.1}x)\n",
            spec.v,
            spec.h,
            spec.n,
            art.num_tiles(),
            a.per_iter.as_secs_f64() * 1e3,
            b.per_iter.as_secs_f64() * 1e3,
            a.per_iter.as_secs_f64() / b.per_iter.as_secs_f64()
        );
    }
    println!("# note: CPU-interpret artifacts exist to prove composition & numerics;");
    println!("# DESIGN.md §Hardware-Adaptation estimates the TPU tile performance.");
}
