//! IVF pruning-index integration tests (ISSUE 3 acceptance):
//!
//! * pruned search with `nprobe = nlist` is **bit-identical** to exhaustive
//!   `search_batch` for every LC method;
//! * on the synthetic text workload some swept `nprobe` reaches
//!   recall@ℓ >= 0.95 while scoring <= 25% of the database;
//! * `EMDX` persistence round-trips bit-exactly and a stale dataset
//!   fingerprint is rejected at load.

// the legacy SearchEngine shims are exercised deliberately: their
// bit-identity to the planner is part of what this suite pins down
#![allow(deprecated)]

use std::sync::Arc;

use emdpar::config::{Config, DatasetSpec, IndexParams};
use emdpar::coordinator::SearchEngine;
use emdpar::core::{Dataset, Method};
use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::recall_at;
use emdpar::index::{
    dataset_fingerprint, load_index, load_index_for, pruned_search, pruned_search_batch,
    save_index, IvfIndex,
};
use emdpar::lc::{EngineParams, LcEngine};

const THREADS: usize = 2;

fn dataset() -> Arc<Dataset> {
    Arc::new(generate_text(&TextConfig {
        n: 240,
        classes: 4,
        vocab: 600,
        dim: 16,
        doc_len: 40,
        seed: 77,
        ..Default::default()
    }))
}

fn lc_engine(ds: &Arc<Dataset>) -> LcEngine {
    LcEngine::new(Arc::clone(ds), EngineParams { threads: THREADS, ..Default::default() })
}

fn train(eng: &LcEngine, nlist: usize) -> IvfIndex {
    IvfIndex::train(
        eng.wcd_centroids(),
        eng.dataset().embeddings.dim(),
        &IndexParams { nlist, nprobe: 1, train_iters: 8, seed: 5, min_points_per_list: 1 },
        THREADS,
        dataset_fingerprint(eng.dataset()),
    )
    .unwrap()
}

fn search_engine(ds: &Arc<Dataset>) -> SearchEngine {
    // config dataset spec is ignored by with_dataset; params must match
    // lc_engine's (same threads, default symmetric/batch_block) so the two
    // paths are comparable bit-for-bit
    let config = Config { threads: THREADS, ..Default::default() };
    SearchEngine::with_dataset(config, Arc::clone(ds)).unwrap()
}

#[test]
fn full_probe_is_bit_identical_to_exhaustive_search_batch() {
    let ds = dataset();
    let eng = lc_engine(&ds);
    let se = search_engine(&ds);
    let queries: Vec<_> = [0usize, 17, 101, 239].iter().map(|&u| ds.histogram(u)).collect();
    for nlist in [8usize, 16] {
        let ix = train(&eng, nlist);
        let methods = [
            Method::Rwmd,
            Method::Omr,
            Method::Act { k: 2 },
            Method::Act { k: 4 },
            Method::Bow,
            Method::Wcd,
        ];
        for method in methods {
            let exhaustive = se.search_batch(&queries, method, 10).unwrap();
            let pruned =
                pruned_search_batch(&eng, &ix, &queries, method, 10, ix.nlist()).unwrap();
            for (ex, pr) in exhaustive.iter().zip(&pruned) {
                assert_eq!(ex.hits, pr.hits, "nlist {nlist} {method}");
                assert_eq!(pr.candidates, ds.len(), "full probe must scan everything");
            }
        }
    }
}

#[test]
fn recall_sweep_meets_target_at_low_candidate_fraction() {
    // a strongly clustered corpus — the regime an IVF index exists for:
    // documents are dominated by their own topic's words, so WCD centroids
    // cluster tightly by class and the exhaustive top-ℓ is class-local
    let ds = Arc::new(generate_text(&TextConfig {
        n: 240,
        classes: 6,
        vocab: 600,
        dim: 16,
        doc_len: 60,
        topic_frac: 0.8,
        general_frac: 0.1,
        spread: 0.25,
        seed: 131,
        ..Default::default()
    }));
    let n = ds.len();
    let eng = lc_engine(&ds);
    let se = search_engine(&ds);
    let method = Method::Act { k: 2 };
    let l = 10;
    // step 11 is coprime with 6 classes (labels are i % classes), so the
    // query set covers every class
    let queries: Vec<_> = (0..21).map(|i| ds.histogram(i * 11)).collect();
    let truth: Vec<Vec<usize>> = se
        .search_batch(&queries, method, l)
        .unwrap()
        .into_iter()
        .map(|r| r.hits.into_iter().map(|(_, id)| id).collect())
        .collect();

    let mut best_cheap_recall = 0.0f64; // best recall among <= 25% sweeps
    let mut swept = Vec::new();
    for nlist in [8usize, 12, 16, 24] {
        let ix = train(&eng, nlist);
        for &nprobe in &[1usize, 2, 3, 4, 6, 8, 12, 16, 24] {
            if nprobe > ix.nlist() {
                continue;
            }
            let pruned =
                pruned_search_batch(&eng, &ix, &queries, method, l, nprobe).unwrap();
            let mut recall = 0.0f64;
            let mut frac = 0.0f64;
            for (t, pr) in truth.iter().zip(&pruned) {
                let got: Vec<usize> = pr.hits.iter().map(|&(_, id)| id).collect();
                recall += recall_at(t, &got);
                frac += pr.candidates as f64 / n as f64;
            }
            recall /= queries.len() as f64;
            frac /= queries.len() as f64;
            swept.push((nlist, nprobe, frac, recall));
            if nprobe == ix.nlist() {
                assert!(
                    (recall - 1.0).abs() < 1e-12,
                    "nprobe = nlist must be exhaustive (nlist {nlist}: recall {recall})"
                );
            }
            if frac <= 0.25 && recall > best_cheap_recall {
                best_cheap_recall = recall;
            }
        }
    }
    assert!(
        best_cheap_recall >= 0.95,
        "no swept (nlist, nprobe) reached recall@{l} >= 0.95 at <= 25% candidates: {swept:?}"
    );
}

#[test]
fn batch_pruned_search_equals_single_query() {
    let ds = dataset();
    let eng = lc_engine(&ds);
    let ix = train(&eng, 12);
    let queries: Vec<_> = [3usize, 50, 51, 200].iter().map(|&u| ds.histogram(u)).collect();
    for method in [Method::Rwmd, Method::Act { k: 3 }] {
        let batch = pruned_search_batch(&eng, &ix, &queries, method, 6, 3).unwrap();
        for (q, got) in queries.iter().zip(&batch) {
            let single = pruned_search(&eng, &ix, q, method, 6, 3).unwrap();
            assert_eq!(got.hits, single.hits, "{method}");
            assert_eq!(got.candidates, single.candidates, "{method}");
        }
    }
}

#[test]
fn persistence_roundtrip_bit_exact_and_stale_rejected() {
    let ds = dataset();
    let eng = lc_engine(&ds);
    let ix = train(&eng, 10);
    let dir = std::env::temp_dir().join("emdpar_index_pruning_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("text240.emdx");
    save_index(&ix, &path).unwrap();

    // bit-exact round trip
    let back = load_index(&path).unwrap();
    assert_eq!(back, ix);

    // the loaded index routes queries identically
    let q = ds.histogram(9);
    let a = pruned_search(&eng, &ix, &q, Method::Rwmd, 5, 3).unwrap();
    let b = pruned_search(&eng, &back, &q, Method::Rwmd, 5, 3).unwrap();
    assert_eq!(a.hits, b.hits);

    // matching fingerprint loads; any other dataset is rejected as stale
    let fp = dataset_fingerprint(&ds);
    assert!(load_index_for(&path, fp).is_ok());
    let err = load_index_for(&path, fp.wrapping_add(1)).unwrap_err();
    assert!(err.to_string().contains("stale index"), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn emdx_truncated_tail_and_wrong_version_rejected_before_allocation() {
    let ds = dataset();
    let eng = lc_engine(&ds);
    let ix = train(&eng, 10);
    let dir = std::env::temp_dir().join("emdpar_index_pruning_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("hardening.emdx");
    save_index(&ix, &path).unwrap();
    let full = std::fs::read(&path).unwrap();

    // truncated tail: every cut point fails cleanly (no panic, no abort),
    // including cuts inside the header that drive allocation sizes
    for cut in [full.len() - 1, full.len() - 9, 60, 20, 9] {
        std::fs::write(&path, &full[..cut]).unwrap();
        assert!(load_index(&path).is_err(), "cut at {cut} must be rejected");
    }

    // wrong version: rejected right after the 8-byte preamble, before any
    // header field can size an allocation
    for bad_version in [0u32, 3, 99] {
        let mut bytes = full.clone();
        bytes[4..8].copy_from_slice(&bad_version.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err();
        assert!(
            err.to_string().contains("unsupported EMDX version"),
            "version {bad_version}: {err}"
        );
    }

    // version-2 sidecars are the shard manifest: the v1 loader rejects
    // them cleanly, and the v2 loader rejects v1 files symmetrically, so a
    // config switch between the monolithic index and the sharded corpus
    // falls back to a rebuild instead of misreading the file
    let mut v2 = full.clone();
    v2[4..8].copy_from_slice(&2u32.to_le_bytes());
    std::fs::write(&path, &v2).unwrap();
    let err = load_index(&path).unwrap_err();
    assert!(err.to_string().contains("unsupported EMDX version 2"), "{err}");
    std::fs::write(&path, &full).unwrap();
    let err = emdpar::shard::load_manifest(&path).unwrap_err();
    assert!(err.to_string().contains("unsupported EMDX version 1"), "{err}");

    std::fs::remove_file(&path).ok();
}

#[test]
fn search_engine_integration_routes_and_reports() {
    let ds = dataset();
    let config = Config {
        dataset: DatasetSpec::SynthText { n: 240, vocab: 600, dim: 16, seed: 77 },
        threads: THREADS,
        index: Some(IndexParams {
            nlist: 12,
            nprobe: 3,
            train_iters: 8,
            seed: 5,
            min_points_per_list: 1,
        }),
        ..Default::default()
    };
    let se = SearchEngine::with_dataset(config, Arc::clone(&ds)).unwrap();
    let plain = search_engine(&ds);

    let q = ds.histogram(30);
    // default route prunes: fewer candidates scored than the database size
    let pruned = se.search(&q, Method::Act { k: 2 }, 8).unwrap();
    assert_eq!(pruned.hits.len(), 8);
    assert_eq!(pruned.hits[0].1, 30, "self hit survives pruning");
    let m = se.metrics();
    assert!(m.pruned_fraction() > 0.0);
    assert!(
        m.candidates_scored.load(std::sync::atomic::Ordering::Relaxed) < ds.len() as u64
    );

    // per-request exhaustive override matches the plain engine bit-for-bit
    let a = se.search_opts(&q, Method::Act { k: 2 }, 8, Some(12)).unwrap();
    let b = plain.search(&q, Method::Act { k: 2 }, 8).unwrap();
    assert_eq!(a.hits, b.hits);

    // min_points_per_list caps an oversized nlist at train time
    let capped = IvfIndex::train(
        plain.native().wcd_centroids(),
        ds.embeddings.dim(),
        &IndexParams {
            nlist: 10_000,
            nprobe: 1,
            train_iters: 4,
            seed: 1,
            min_points_per_list: 10,
        },
        THREADS,
        0,
    )
    .unwrap();
    assert!(capped.nlist() <= 24, "nlist {} not capped", capped.nlist());
}
