//! Distributed-corpus integration suite: `emdpar node` shard servers
//! behind the hedged fan-out client, against the in-process fan-out.
//!
//! * bit-identity: remote fan-out at full probe returns byte-identical
//!   hits to the in-process sharded engine across plain, indexed and
//!   certified-cascade requests,
//! * fault injection: a stalled primary is hedged (bit-identical result),
//!   a replica killed on accept is retried on the survivor, a shard with
//!   no live replica is dropped from the merge with `partial: true`
//!   (surfaced on the wire too), and garbage / truncated responses become
//!   structured errors instead of hangs,
//! * segmented persistence: `add_docs` appends `EMDX` v3 segments without
//!   rewriting the base dataset or earlier segments, restarts replay the
//!   chain, and a full rewrite folds + clears it — on the coordinator and
//!   on a slice-backed node.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use emdpar::prelude::{
    spawn_node, CascadeSpec, Config, DatasetSpec, Histogram, IndexParams, Method, ReactorServer,
    RemoteParams, SearchEngine, SearchRequest, SearchResult, ShardParams, Topology,
};
use emdpar::util::json::Json;

// ---------------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------------

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("emdpar_remote_shards").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Generate a deterministic base dataset and persist it as the shared
/// `EMD1` file every node slices.
fn write_base(dir: &Path, n: usize, seed: u64) -> PathBuf {
    let ds = Config {
        dataset: DatasetSpec::SynthText { n, vocab: 160, dim: 8, seed },
        ..Default::default()
    }
    .load_dataset()
    .unwrap();
    let path = dir.join("base.bin");
    emdpar::data::save(&ds, &path).unwrap();
    path
}

fn write_topology(dir: &Path, lists: Vec<Vec<String>>) -> String {
    let topo = Topology::new(lists).unwrap();
    let path = dir.join("topo.json");
    std::fs::write(&path, topo.to_json().to_string_compact()).unwrap();
    path.to_string_lossy().into_owned()
}

fn two_shards() -> Option<ShardParams> {
    Some(ShardParams { shards: 2, max_docs_per_shard: 1 << 20 })
}

fn remote_params(topology: String) -> RemoteParams {
    RemoteParams { topology, shard_timeout_ms: 5000, hedge_ms: 50, pool: 2, retries: 2 }
}

/// f32 bit patterns: asserting on these is the bit-identity claim.
fn bits(res: &SearchResult) -> Vec<(u32, usize)> {
    res.hits.iter().map(|&(d, id)| (d.to_bits(), id)).collect()
}

fn assert_identical(local: &[SearchResult], remote: &[SearchResult], what: &str) {
    assert_eq!(local.len(), remote.len(), "{what}: result count");
    for (q, (a, b)) in local.iter().zip(remote).enumerate() {
        assert_eq!(bits(a), bits(b), "{what}: query {q} hits diverge");
        assert_eq!(a.labels, b.labels, "{what}: query {q} labels diverge");
    }
}

/// Misbehaving replica endpoints for fault injection.
#[derive(Clone, Copy)]
enum FakeMode {
    /// Accept and hold the connection open without ever answering.
    Stall,
    /// Accept, then immediately close (a replica dying mid-stream).
    CloseOnAccept,
    /// Answer every request line with a non-JSON line.
    Garbage,
    /// Answer with a truncated JSON fragment, then close.
    Truncate,
}

fn fake_node(mode: FakeMode) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { return };
            std::thread::spawn(move || handle_fake(stream, mode));
        }
    });
    addr
}

fn handle_fake(stream: TcpStream, mode: FakeMode) {
    match mode {
        FakeMode::CloseOnAccept => drop(stream),
        FakeMode::Stall => {
            // drain whatever arrives but never answer; the connection dies
            // when the client (deadline or hedge winner) shuts it down
            let mut buf = [0u8; 1024];
            let mut r = &stream;
            while matches!(r.read(&mut buf), Ok(n) if n > 0) {}
        }
        FakeMode::Garbage => {
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = &stream;
            let mut line = String::new();
            while reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                if w.write_all(b"not json\n").and_then(|()| w.flush()).is_err() {
                    break;
                }
                line.clear();
            }
        }
        FakeMode::Truncate => {
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            if reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                let mut w = &stream;
                w.write_all(b"{\"ok\":true,\"hits\":[[0.25").and_then(|()| w.flush()).ok();
            }
            stream.shutdown(Shutdown::Both).ok();
        }
    }
}

fn queries_from(path: &Path, n: usize) -> Vec<Histogram> {
    let ds = emdpar::data::load(path).unwrap();
    (0..n.min(ds.len())).map(|u| ds.histogram(u)).collect()
}

/// `{"op":"search",...}` request line for one query.
fn search_line(q: &Histogram, l: usize) -> String {
    let pairs = q
        .indices()
        .iter()
        .zip(q.weights())
        .map(|(&i, &w)| Json::Arr(vec![Json::Num(i as f64), Json::Num(w as f64)]))
        .collect();
    let req = Json::obj(vec![
        ("op", "search".into()),
        ("method", "rwmd".into()),
        ("l", l.into()),
        ("query", Json::Arr(pairs)),
    ]);
    req.to_string_compact()
}

// ---------------------------------------------------------------------------
// bit-identity
// ---------------------------------------------------------------------------

#[test]
fn remote_fanout_is_bit_identical_to_in_process() {
    let dir = fresh_dir("identity");
    let base = write_base(&dir, 40, 21);
    let index =
        Some(IndexParams { nlist: 4, nprobe: 4, train_iters: 5, seed: 2, min_points_per_list: 1 });
    let node_cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        index,
        ..Default::default()
    };
    let n0 = spawn_node(node_cfg.clone(), 0, 2, "127.0.0.1:0").unwrap();
    let n1 = spawn_node(node_cfg, 1, 2, "127.0.0.1:0").unwrap();
    let topo = write_topology(
        &dir,
        vec![vec![n0.addr().unwrap().to_string()], vec![n1.addr().unwrap().to_string()]],
    );
    let mk = |index: Option<IndexParams>, remote: Option<RemoteParams>| Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        sharded: two_shards(),
        index,
        remote,
        ..Default::default()
    };
    let queries = queries_from(&base, 8);

    // the same node pair serves a plain and an indexed coordinator: the
    // wire probe width is always explicit, so a plain coordinator keeps
    // the nodes exhaustive
    for (what, index) in [("plain", None), ("indexed full probe", index)] {
        let local = SearchEngine::from_config(mk(index, None)).unwrap();
        let remote =
            SearchEngine::from_config(mk(index, Some(remote_params(topo.clone())))).unwrap();

        let plain = SearchRequest::batch(queries.clone()).method(Method::Rwmd).topl(5);
        let a = local.execute(&plain).unwrap();
        let b = remote.execute(&plain).unwrap();
        assert_identical(&a.results, &b.results, what);
        assert!(!b.stats.partial, "{what}: every shard answered");

        let cascade = SearchRequest::batch(queries.clone())
            .cascade(CascadeSpec::new(Method::Act { k: 2 }).certified(true))
            .topl(5);
        let a = local.execute(&cascade).unwrap();
        let b = remote.execute(&cascade).unwrap();
        assert_identical(&a.results, &b.results, &format!("{what} cascade"));
        assert_eq!(a.stats.certified, b.stats.certified, "{what}: certificates diverge");
        assert!(!b.stats.partial);

        // remote connectivity surfaces as ready + connected
        let fleet = remote.remote_fleet().expect("remote engine has a fleet");
        assert!(fleet.ready_error().is_none(), "every shard reachable");
        let status = fleet.status_json().to_string_compact();
        assert!(status.contains("\"state\":\"connected\""), "{status}");
    }

    // reduced probe stays partial-free and keeps useful recall (the node
    // trains its own index copy, so only full probe promises identity)
    let local = SearchEngine::from_config(mk(index, None)).unwrap();
    let remote = SearchEngine::from_config(mk(index, Some(remote_params(topo)))).unwrap();
    let truth = local
        .execute(&SearchRequest::batch(queries.clone()).method(Method::Rwmd).topl(5))
        .unwrap();
    let reduced = remote
        .execute(&SearchRequest::batch(queries).method(Method::Rwmd).topl(5).nprobe(3))
        .unwrap();
    assert!(!reduced.stats.partial);
    let mut hit = 0usize;
    let mut total = 0usize;
    for (t, r) in truth.results.iter().zip(&reduced.results) {
        total += t.hits.len();
        hit += t
            .hits
            .iter()
            .filter(|(_, id)| r.hits.iter().any(|&(_, rid)| rid == *id))
            .count();
    }
    assert!(
        hit * 2 >= total,
        "reduced-probe recall collapsed: {hit}/{total} of the exhaustive top-5"
    );
}

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

#[test]
fn stalled_primary_is_hedged_bit_identically() {
    let dir = fresh_dir("hedge");
    let base = write_base(&dir, 30, 5);
    let node_cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        ..Default::default()
    };
    let n0 = spawn_node(node_cfg.clone(), 0, 2, "127.0.0.1:0").unwrap();
    let n1 = spawn_node(node_cfg, 1, 2, "127.0.0.1:0").unwrap();
    let stalled = fake_node(FakeMode::Stall);
    // shard 0's primary never answers; the hedge must win on the replica
    let topo = write_topology(
        &dir,
        vec![
            vec![stalled.to_string(), n0.addr().unwrap().to_string()],
            vec![n1.addr().unwrap().to_string()],
        ],
    );
    let mk = |remote: Option<RemoteParams>| Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        sharded: two_shards(),
        remote,
        ..Default::default()
    };
    let local = SearchEngine::from_config(mk(None)).unwrap();
    let remote = SearchEngine::from_config(mk(Some(RemoteParams {
        topology: topo,
        shard_timeout_ms: 5000,
        hedge_ms: 5,
        pool: 2,
        retries: 2,
    })))
    .unwrap();

    let req = SearchRequest::batch(queries_from(&base, 4)).method(Method::Rwmd).topl(4);
    let a = local.execute(&req).unwrap();
    let b = remote.execute(&req).unwrap();
    assert_identical(&a.results, &b.results, "hedged");
    assert!(!b.stats.partial, "the hedge completed shard 0");
    assert!(
        remote.metrics().remote_hedges.load(Ordering::Relaxed) >= 1,
        "hedge counter never fired"
    );
}

#[test]
fn replica_killed_on_accept_is_retried_on_the_survivor() {
    let dir = fresh_dir("retry");
    let base = write_base(&dir, 30, 6);
    let node_cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        ..Default::default()
    };
    let n0 = spawn_node(node_cfg.clone(), 0, 2, "127.0.0.1:0").unwrap();
    let n1 = spawn_node(node_cfg, 1, 2, "127.0.0.1:0").unwrap();
    let dying = fake_node(FakeMode::CloseOnAccept);
    let topo = write_topology(
        &dir,
        vec![
            vec![dying.to_string(), n0.addr().unwrap().to_string()],
            vec![n1.addr().unwrap().to_string()],
        ],
    );
    let mk = |remote: Option<RemoteParams>| Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        sharded: two_shards(),
        remote,
        ..Default::default()
    };
    let local = SearchEngine::from_config(mk(None)).unwrap();
    // hedging off: only the retry path can rescue shard 0
    let remote = SearchEngine::from_config(mk(Some(RemoteParams {
        topology: topo,
        shard_timeout_ms: 5000,
        hedge_ms: 0,
        pool: 2,
        retries: 2,
    })))
    .unwrap();

    let req = SearchRequest::batch(queries_from(&base, 4)).method(Method::Rwmd).topl(4);
    let a = local.execute(&req).unwrap();
    let b = remote.execute(&req).unwrap();
    assert_identical(&a.results, &b.results, "retried");
    assert!(!b.stats.partial);
    assert!(
        remote.metrics().remote_retries.load(Ordering::Relaxed) >= 1,
        "retry counter never fired"
    );
}

#[test]
fn dead_shard_drops_to_partial_and_marks_the_wire() {
    let dir = fresh_dir("partial");
    let base = write_base(&dir, 30, 7);
    let node_cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        ..Default::default()
    };
    let n0 = spawn_node(node_cfg, 0, 2, "127.0.0.1:0").unwrap();
    let stalled = fake_node(FakeMode::Stall);
    let topo = write_topology(
        &dir,
        vec![vec![n0.addr().unwrap().to_string()], vec![stalled.to_string()]],
    );
    let remote = SearchEngine::from_config(Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        sharded: two_shards(),
        remote: Some(RemoteParams {
            topology: topo,
            shard_timeout_ms: 150,
            hedge_ms: 0,
            pool: 1,
            retries: 0,
        }),
        ..Default::default()
    })
    .unwrap();

    let queries = queries_from(&base, 3);
    let resp = remote
        .execute(&SearchRequest::batch(queries.clone()).method(Method::Rwmd).topl(4))
        .unwrap();
    assert!(resp.stats.partial, "shard 1 missed its deadline");
    for res in &resp.results {
        assert!(!res.hits.is_empty(), "surviving shards still answer");
        for &(_, id) in &res.hits {
            assert!(id < 15, "hit {id} came from the dropped shard (shard 0 owns 0..15)");
        }
    }
    assert!(remote.metrics().remote_timeouts.load(Ordering::Relaxed) >= 1);

    // the degraded fleet is visible to health surfaces
    let fleet = remote.remote_fleet().unwrap();
    assert!(fleet.ready_error().unwrap().contains("shard 1"), "readiness names the dead shard");

    // and the wire carries the partial marker
    let server = ReactorServer::bind(remote, "127.0.0.1:0").unwrap();
    let stream = TcpStream::connect(server.local_addr().unwrap()).unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(format!("{}\n", search_line(&queries[0], 4)).as_bytes()).unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    assert!(line.contains("\"partial\":true"), "{line}");
}

#[test]
fn garbage_and_truncated_responses_are_structured_errors() {
    let dir = fresh_dir("garbage");
    let base = write_base(&dir, 24, 8);
    let mk = |addr: SocketAddr, name: &str| {
        let topo_dir = dir.join(name);
        std::fs::create_dir_all(&topo_dir).unwrap();
        let topo = write_topology(&topo_dir, vec![vec![addr.to_string()]]);
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::File(base.clone()),
            threads: 2,
            sharded: Some(ShardParams { shards: 1, max_docs_per_shard: 1 << 20 }),
            remote: Some(RemoteParams {
                topology: topo,
                shard_timeout_ms: 500,
                hedge_ms: 0,
                pool: 1,
                retries: 1,
            }),
            ..Default::default()
        })
        .unwrap()
    };
    let queries = queries_from(&base, 2);
    let req = SearchRequest::batch(queries).method(Method::Rwmd).topl(3);

    for (mode, name, expect) in [
        (FakeMode::Garbage, "garbage", "garbage response"),
        (FakeMode::Truncate, "truncate", "remote shards failed"),
    ] {
        let engine = mk(fake_node(mode), name);
        let begin = Instant::now();
        let err = engine.execute(&req).unwrap_err().to_string();
        assert!(
            begin.elapsed() < Duration::from_secs(10),
            "{name}: error took {:?} — the client hung instead of failing",
            begin.elapsed()
        );
        assert!(err.contains("remote shards failed"), "{name}: {err}");
        assert!(err.contains(expect), "{name}: {err}");
    }
}

// ---------------------------------------------------------------------------
// segmented persistence
// ---------------------------------------------------------------------------

#[test]
fn appends_write_segments_and_never_rewrite_the_base() {
    let dir = fresh_dir("segments");
    let base = write_base(&dir, 24, 33);
    let cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        sharded: two_shards(),
        ..Default::default()
    };
    let engine = SearchEngine::from_config(cfg.clone()).unwrap();
    let ds = emdpar::data::load(&base).unwrap();
    let base_bytes = std::fs::read(&base).unwrap();
    let segdir = dir.join("base.bin.segments");

    let docs: Vec<Histogram> = (0..2).map(|u| ds.histogram(u)).collect();
    engine.add_docs(&docs, &[7, 8]).unwrap();
    let seg0 = segdir.join("seg-000000.emdx");
    assert!(seg0.exists(), "first append wrote no segment");
    let seg0_bytes = std::fs::read(&seg0).unwrap();

    // the regression this suite exists for: a second append must extend
    // the chain, not rewrite segment 0 or the base dataset
    engine.add_docs(&docs[..1], &[9]).unwrap();
    assert!(segdir.join("seg-000001.emdx").exists(), "second append opened no new segment");
    assert_eq!(
        std::fs::read(&seg0).unwrap(),
        seg0_bytes,
        "second append rewrote segment 0"
    );
    assert_eq!(
        std::fs::read(&base).unwrap(),
        base_bytes,
        "append rewrote the base dataset"
    );
    assert_eq!(engine.num_docs(), 27);

    // a restart replays the chain onto the untouched base
    let restarted = SearchEngine::from_config(cfg.clone()).unwrap();
    assert_eq!(restarted.num_docs(), 27);
    for g in 24..27 {
        let a = engine.doc_histogram(g).unwrap();
        let b = restarted.doc_histogram(g).unwrap();
        assert_eq!(a.indices(), b.indices(), "doc {g}");
        assert_eq!(a.weights(), b.weights(), "doc {g}");
    }

    // a full rewrite folds the segments into the base and clears the chain
    assert!(restarted.persist_shards().unwrap());
    assert!(!seg0.exists(), "persist_shards left stale segments behind");
    assert_ne!(std::fs::read(&base).unwrap(), base_bytes, "rewrite absorbed the appends");
    let folded = SearchEngine::from_config(cfg).unwrap();
    assert_eq!(folded.num_docs(), 27);
}

#[test]
fn node_appends_persist_in_slice_segments_and_replay() {
    let dir = fresh_dir("node_segments");
    let base = write_base(&dir, 24, 44);
    let cfg = Config {
        dataset: DatasetSpec::File(base.clone()),
        threads: 2,
        linger_ms: 1,
        ..Default::default()
    };
    let node = spawn_node(cfg.clone(), 0, 2, "127.0.0.1:0").unwrap();
    assert_eq!(node.engine().num_docs(), 12, "shard 0 of 2 over 24 docs");
    let base_bytes = std::fs::read(&base).unwrap();

    let ds = emdpar::data::load(&base).unwrap();
    node.engine().add_docs(&[ds.histogram(3)], &[5]).unwrap();
    assert_eq!(node.engine().num_docs(), 13);
    // slice appends chain next to a per-slice sibling, never the shared base
    let segdir = dir.join("base.bin.s0of2.segments");
    assert!(segdir.join("seg-000000.emdx").exists(), "slice append wrote no segment");
    assert_eq!(std::fs::read(&base).unwrap(), base_bytes, "node rewrote the shared base");
    node.shutdown();

    let node = spawn_node(cfg, 0, 2, "127.0.0.1:0").unwrap();
    assert_eq!(node.engine().num_docs(), 13, "restart replayed the slice chain");
    node.shutdown();
}
