//! End-to-end integration: coordinator + server + evaluation harness over
//! both synthetic datasets, plus the paper's headline qualitative results
//! at CI scale (RWMD collapse on dense histograms, ACT rescue, ACT beats
//! BoW on text).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use emdpar::config::{Config, DatasetSpec};
use emdpar::coordinator::{SearchEngine, Server};
use emdpar::data::{generate_mnist, generate_text, MnistConfig, TextConfig};
use emdpar::eval::{precision_at, sweep_all_pairs};
use emdpar::lc::{EngineParams, LcEngine, Method};
use emdpar::util::json::Json;

#[test]
fn text_precision_act_beats_bow_and_rwmd() {
    // Fig. 8(a) qualitative shape at CI scale: ACT-1 > RWMD, ACT-1 > BoW.
    // short, noisy documents over a wide vocabulary: same-class documents
    // share few literal words, so embedding-aware measures must win
    let ds = std::sync::Arc::new(generate_text(&TextConfig {
        n: 240,
        classes: 8,
        vocab: 2400,
        dim: 24,
        doc_len: 20,
        spread: 0.5,
        topic_frac: 0.45,
        general_frac: 0.35,
        seed: 21,
        ..Default::default()
    }));
    let rows = sweep_all_pairs(
        &ds,
        &[Method::Bow, Method::Rwmd, Method::Act { k: 2 }],
        &[8],
        EngineParams { threads: 4, ..Default::default() },
    )
    .unwrap();
    let p = |name: &str| {
        rows.iter().find(|r| r.method == name).map(|r| r.precision[0].1).unwrap()
    };
    let (bow, rwmd, act1) = (p("BoW"), p("RWMD"), p("ACT-1"));
    assert!(act1 > bow, "ACT-1 {act1} must beat BoW {bow}");
    assert!(act1 >= rwmd - 0.02, "ACT-1 {act1} must not trail RWMD {rwmd}");
    assert!(act1 > 0.5, "absolute accuracy sanity: {act1}");
}

#[test]
fn mnist_background_breaks_rwmd_act_recovers() {
    // Table 6 qualitative shape: with background pixels, RWMD ≈ chance
    // (1/10), OMR and ACT recover.
    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n: 120, background: 0.4, ..Default::default() }));
    let eng = LcEngine::new(std::sync::Arc::clone(&ds), EngineParams { threads: 4, ..Default::default() });
    let l = 4;
    let rwmd = eng.all_pairs_symmetric(Method::Rwmd);
    let omr = eng.all_pairs_symmetric(Method::Omr);
    let act7 = eng.all_pairs_symmetric(Method::Act { k: 8 });
    let p_rwmd = precision_at(&rwmd, &ds.labels, &ds.labels, l, true);
    let p_omr = precision_at(&omr, &ds.labels, &ds.labels, l, true);
    let p_act7 = precision_at(&act7, &ds.labels, &ds.labels, l, true);
    // full-overlap histograms: every RWMD distance is 0 -> random ranking
    assert!(p_rwmd < 0.3, "RWMD should collapse, got {p_rwmd}");
    assert!(p_omr > p_rwmd + 0.3, "OMR must rescue: {p_omr} vs {p_rwmd}");
    assert!(p_act7 >= p_omr - 0.02, "ACT-7 {p_act7} must not trail OMR {p_omr}");
}

#[test]
fn mnist_no_background_all_methods_work() {
    // Table 5 qualitative shape: sparse digits, all methods well above chance
    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n: 150, ..Default::default() }));
    let eng = LcEngine::new(std::sync::Arc::clone(&ds), EngineParams { threads: 4, ..Default::default() });
    for method in [Method::Bow, Method::Rwmd, Method::Act { k: 2 }] {
        let m = eng.all_pairs_symmetric(method);
        let p = precision_at(&m, &ds.labels, &ds.labels, 4, true);
        assert!(p > 0.5, "{}: precision {p}", method.name());
    }
}

#[test]
fn server_end_to_end_over_tcp() {
    let config = Config {
        dataset: DatasetSpec::SynthMnist { n: 80, background: 0.0, seed: 2 },
        threads: 2,
        linger_ms: 1,
        max_batch: 4,
        ..Default::default()
    };
    let engine = SearchEngine::from_config(config).unwrap();
    let expect_label = engine.dataset().labels[10];
    let server = Server::bind(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        // pipeline several searches on one connection
        let mut responses = Vec::new();
        for id in [10usize, 11, 12] {
            let req = format!(
                "{{\"op\": \"search_id\", \"id\": {id}, \"l\": 3, \"method\": \"act-1\"}}\n"
            );
            w.write_all(req.as_bytes()).unwrap();
        }
        w.flush().unwrap();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            responses.push(Json::parse(line.trim()).unwrap());
        }
        responses
    });
    server.serve_n(1).unwrap();
    let responses = client.join().unwrap();
    assert_eq!(responses.len(), 3);
    let first = &responses[0];
    assert_eq!(first.get("ok"), Some(&Json::Bool(true)));
    let hits = first.get("hits").and_then(Json::as_arr).unwrap();
    // self-match first with its own label
    let top = hits[0].as_arr().unwrap();
    assert_eq!(top[1].as_usize(), Some(10));
    assert_eq!(top[2].as_usize(), Some(expect_label as usize));
}

#[test]
fn wmd_pruned_search_agrees_with_act_ranking_roughly() {
    // The exact-EMD (WMD) top-1 neighbour should usually be in ACT-7's
    // top-4: checks the approximation is faithful enough for retrieval.
    use emdpar::core::Metric;
    use emdpar::exact::wmd_topl_pruned;
    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n: 40, side: 14, ..Default::default() }));
    let eng = LcEngine::new(std::sync::Arc::clone(&ds), EngineParams { threads: 2, ..Default::default() });
    let db: Vec<_> = (0..ds.len()).map(|u| ds.histogram(u)).collect();
    let mut agree = 0;
    let queries = 6;
    for uq in 0..queries {
        let (top_exact, _) = wmd_topl_pruned(&ds.embeddings, &db[uq], &db, Metric::L2, 2);
        // skip self (distance 0)
        let exact_best = top_exact.iter().map(|&(_, u)| u).find(|&u| u != uq).unwrap();
        let row = eng.distances(&db[uq], Method::Act { k: 8 });
        let mut order: Vec<usize> = (0..row.len()).filter(|&u| u != uq).collect();
        order.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
        if order[..4].contains(&exact_best) {
            agree += 1;
        }
    }
    assert!(agree >= queries - 1, "ACT-7 missed the exact nearest too often: {agree}/{queries}");
}
