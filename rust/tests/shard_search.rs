//! Sharded live-corpus integration tests (ISSUE 4 acceptance):
//!
//! * fan-out search over S ∈ {1, 2, 4} shards with `nprobe = nlist` on
//!   every shard is **bit-identical** to single-corpus exhaustive
//!   `search_batch` (same ids, bit-equal distances), with and without
//!   per-shard IVF indexes;
//! * post-append searches find the new documents, and a swept per-shard
//!   `nprobe` reaches recall@10 >= 0.95 while scoring <= 25% of the
//!   corpus under pruning;
//! * the `EMDX` v2 manifest round-trips the live layout through a
//!   file-backed engine restart;
//! * `add_docs` works end-to-end over the TCP protocol.

// the legacy SearchEngine shims are exercised deliberately: their
// bit-identity to the planner is part of what this suite pins down
#![allow(deprecated)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use emdpar::config::{Config, DatasetSpec, IndexParams, ShardParams};
use emdpar::coordinator::{SearchEngine, Server};
use emdpar::core::{CsrMatrix, Dataset, Histogram, Method};
use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::recall_at;
use emdpar::lc::EngineParams;
use emdpar::shard::{search_batch, ShardedCorpus};
use emdpar::util::json::Json;

const THREADS: usize = 2;

fn dataset() -> Arc<Dataset> {
    Arc::new(generate_text(&TextConfig {
        n: 240,
        classes: 4,
        vocab: 600,
        dim: 16,
        doc_len: 40,
        seed: 77,
        ..Default::default()
    }))
}

fn index_params(nlist: usize) -> IndexParams {
    IndexParams { nlist, nprobe: 2, train_iters: 8, seed: 5, min_points_per_list: 1 }
}

fn sharded_config(ds_n: usize, shards: usize, index: Option<IndexParams>) -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n: ds_n, vocab: 600, dim: 16, seed: 77 },
        threads: THREADS,
        sharded: Some(ShardParams { shards, max_docs_per_shard: 1 << 20 }),
        index,
        ..Default::default()
    }
}

/// Bit-exact row slice of a dataset (no re-normalization).
fn slice_dataset(ds: &Dataset, range: std::ops::Range<usize>) -> Dataset {
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    let mut labels = Vec::new();
    for u in range {
        let (idx, w) = ds.matrix.row(u);
        indices.extend_from_slice(idx);
        data.extend_from_slice(w);
        indptr.push(indices.len());
        labels.push(ds.labels[u]);
    }
    let matrix = CsrMatrix::from_raw(indptr, indices, data, ds.matrix.ncols());
    Dataset::from_csr(ds.name.clone(), ds.embeddings.clone(), matrix, labels)
}

#[test]
fn fanout_is_bit_identical_to_exhaustive_search_batch() {
    let ds = dataset();
    let single = SearchEngine::with_dataset(
        Config { threads: THREADS, ..Default::default() },
        Arc::clone(&ds),
    )
    .unwrap();
    let queries: Vec<Histogram> =
        [0usize, 17, 101, 239].iter().map(|&u| ds.histogram(u)).collect();
    let methods = [
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 4 },
        Method::Bow,
        Method::Wcd,
    ];
    for shards in [1usize, 2, 4] {
        for with_index in [false, true] {
            let se = SearchEngine::with_dataset(
                sharded_config(240, shards, with_index.then(|| index_params(8))),
                Arc::clone(&ds),
            )
            .unwrap();
            for method in methods {
                let exhaustive = single.search_batch(&queries, method, 10).unwrap();
                // nprobe covering every shard's nlist forces the full
                // probe on indexed shards; plain shards are exhaustive
                let got = se
                    .search_batch_opts(&queries, method, 10, Some(usize::MAX >> 1))
                    .unwrap();
                for (ex, sh) in exhaustive.iter().zip(&got) {
                    assert_eq!(
                        ex.hits, sh.hits,
                        "shards {shards} index {with_index} {method}"
                    );
                    assert_eq!(ex.labels, sh.labels, "shards {shards} {method}");
                }
            }
        }
    }
}

#[test]
fn single_query_fanout_matches_batch_and_monolithic() {
    let ds = dataset();
    let single = SearchEngine::with_dataset(
        Config { threads: THREADS, ..Default::default() },
        Arc::clone(&ds),
    )
    .unwrap();
    let se = SearchEngine::with_dataset(
        sharded_config(240, 4, Some(index_params(8))),
        Arc::clone(&ds),
    )
    .unwrap();
    let q = ds.histogram(42);
    let mono = single.search(&q, Method::Act { k: 2 }, 7).unwrap();
    let fan = se.search_opts(&q, Method::Act { k: 2 }, 7, Some(8)).unwrap();
    assert_eq!(mono.hits, fan.hits);
    // pruned single query still finds itself and records probe metrics
    let pruned = se.search_opts(&q, Method::Act { k: 2 }, 7, Some(1)).unwrap();
    assert_eq!(pruned.hits[0].1, 42);
    assert!(pruned.hits[0].0.abs() < 1e-5);
    let m = se.metrics();
    assert!(m.index_queries.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    assert!(m.shard_batches.load(std::sync::atomic::Ordering::Relaxed) >= 2);
}

#[test]
fn post_append_recall_meets_target_at_low_candidate_fraction() {
    // the clustered regime an IVF index exists for (cf. the recall sweep in
    // rust/tests/index_pruning.rs): topic words dominate, so per-shard WCD
    // centroids separate cleanly
    let full = generate_text(&TextConfig {
        n: 280,
        classes: 6,
        vocab: 600,
        dim: 16,
        doc_len: 60,
        topic_frac: 0.8,
        general_frac: 0.1,
        spread: 0.25,
        seed: 131,
        ..Default::default()
    });
    let base = slice_dataset(&full, 0..240);
    let extra_docs: Vec<Histogram> = (240..280).map(|u| full.histogram(u)).collect();
    let extra_labels: Vec<u16> = full.labels[240..280].to_vec();

    let ep = EngineParams { threads: THREADS, ..Default::default() };
    let mut best_cheap_recall = 0.0f64;
    let mut swept = Vec::new();
    for nlist in [6usize, 8, 12] {
        let mut corpus = ShardedCorpus::build(
            &base,
            ShardParams { shards: 4, max_docs_per_shard: 1 << 20 },
            ep,
            Some(&index_params(nlist)),
        )
        .unwrap();
        let out = corpus.append(&extra_docs, &extra_labels).unwrap();
        assert_eq!(out.ids, (240..280).collect::<Vec<_>>());
        let n = corpus.len();
        assert_eq!(n, 280);

        // queries cover old and appended documents (step 13 is coprime
        // with 6 classes and with 280)
        let queries: Vec<Histogram> = (0..21).map(|i| corpus.histogram((i * 13) % 280)).collect();
        // exhaustive truth from the corpus itself (full probe on every shard)
        let truth: Vec<Vec<usize>> =
            search_batch(&corpus, &queries, Method::Act { k: 2 }, 10, Some(usize::MAX >> 1))
                .unwrap()
                .results
                .into_iter()
                .map(|r| r.hits.into_iter().map(|(_, id)| id).collect())
                .collect();

        for nprobe in [1usize, 2, 3, 4, 6] {
            if nprobe >= nlist {
                continue;
            }
            let batch =
                search_batch(&corpus, &queries, Method::Act { k: 2 }, 10, Some(nprobe))
                    .unwrap();
            let mut recall = 0.0f64;
            let mut frac = 0.0f64;
            for (t, r) in truth.iter().zip(&batch.results) {
                assert!(r.pruned);
                let got: Vec<usize> = r.hits.iter().map(|&(_, id)| id).collect();
                recall += recall_at(t, &got);
                frac += r.candidates as f64 / n as f64;
            }
            recall /= queries.len() as f64;
            frac /= queries.len() as f64;
            swept.push((nlist, nprobe, frac, recall));
            if frac <= 0.25 && recall > best_cheap_recall {
                best_cheap_recall = recall;
            }
        }

        // every appended document is findable under pruning: it probes its
        // own shard-local list first, so the self-hit survives
        for &g in &[240usize, 255, 279] {
            let q = corpus.histogram(g);
            let res =
                emdpar::shard::search(&corpus, &q, Method::Act { k: 2 }, 5, Some(2)).unwrap();
            assert_eq!(res.hits[0].1, g, "appended doc {g} must find itself (nlist {nlist})");
            assert!(res.hits[0].0.abs() < 1e-4);
        }
    }
    assert!(
        best_cheap_recall >= 0.95,
        "no swept (nlist, nprobe) reached post-append recall@10 >= 0.95 at <= 25% \
         candidates: {swept:?}"
    );
}

#[test]
fn file_backed_engine_persists_and_reloads_the_live_layout() {
    let dir = std::env::temp_dir().join("emdpar_shard_search_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corpus.bin");
    let sidecar = dir.join("corpus.emdx");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&sidecar).ok();

    let ds = dataset();
    emdpar::data::save(&ds, &path).unwrap();
    let config = Config {
        dataset: DatasetSpec::File(path.clone()),
        threads: THREADS,
        sharded: Some(ShardParams { shards: 3, max_docs_per_shard: 1 << 20 }),
        index: Some(index_params(6)),
        ..Default::default()
    };

    // first boot: builds fresh, then appends (which persists dataset +
    // manifest)
    let engine = SearchEngine::from_config(config.clone()).unwrap();
    let novel = Histogram::from_pairs(vec![(3, 0.5), (11, 0.3), (29, 0.2)]);
    let out = engine.add_docs(std::slice::from_ref(&novel), &[9]).unwrap();
    assert_eq!(out.ids, vec![240]);
    assert!(sidecar.exists(), "append persists the EMDX v2 manifest");
    let q = ds.histogram(5);
    let expect = engine.search_opts(&q, Method::Rwmd, 8, Some(2)).unwrap();
    let expect_layout = engine.shard_stats().unwrap();
    drop(engine);

    // second boot: reloads the same live corpus (appended doc included)
    let engine = SearchEngine::from_config(config).unwrap();
    assert_eq!(engine.num_docs(), 241);
    assert_eq!(engine.shard_stats().unwrap(), expect_layout);
    let again = engine.search_opts(&q, Method::Rwmd, 8, Some(2)).unwrap();
    assert_eq!(again.hits, expect.hits, "reloaded corpus routes identically");
    let self_hit = engine
        .search_opts(&engine.doc_histogram(240).unwrap(), Method::Rwmd, 4, None)
        .unwrap();
    assert_eq!(self_hit.hits[0].1, 240);
    assert_eq!(self_hit.labels[0], 9);

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&sidecar).ok();
}

#[test]
fn add_docs_roundtrips_over_tcp() {
    let engine = SearchEngine::with_dataset(
        sharded_config(240, 2, Some(index_params(6))),
        dataset(),
    )
    .unwrap();
    let server = Server::bind(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        let mut w = stream;
        for line in [
            "{\"op\": \"add_docs\", \"docs\": [[[7, 0.5], [13, 0.5]]], \"labels\": [2]}",
            "{\"op\": \"search_id\", \"id\": 240, \"l\": 4, \"method\": \"act-1\"}",
            "{\"op\": \"stats\"}",
        ] {
            w.write_all(line.as_bytes()).unwrap();
            w.write_all(b"\n").unwrap();
            w.flush().unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            out.push(Json::parse(resp.trim()).unwrap());
        }
        out
    });
    server.serve_n(1).unwrap();
    let out = client.join().unwrap();
    assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)), "{:?}", out[0]);
    assert_eq!(out[0].get("n").and_then(Json::as_usize), Some(241));
    let hits = out[1].get("hits").and_then(Json::as_arr).unwrap();
    let first = hits[0].as_arr().unwrap();
    assert_eq!(first[1].as_usize(), Some(240), "appended doc searchable over TCP");
    assert_eq!(first[2].as_usize(), Some(2));
    let shards = out[2].get("shards").and_then(Json::as_arr).unwrap();
    assert_eq!(shards.len(), 2);
    let total: usize =
        shards.iter().map(|s| s.get("docs").and_then(Json::as_usize).unwrap()).sum();
    assert_eq!(total, 241);
}
