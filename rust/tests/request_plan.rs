//! Query-planner request-path acceptance (ISSUE 5):
//!
//! * every legacy entry point is **bit-identical** to its `SearchRequest`
//!   equivalent across methods × {plain, indexed, sharded} × ℓ × nprobe;
//! * the TCP request object JSON round-trips exactly;
//! * a cascade executes over a **sharded** corpus (previously impossible):
//!   at `nprobe >= nlist` on every shard its hits and distances are
//!   bit-identical to exhaustive rerank, and the certification contract is
//!   preserved.

#![allow(deprecated)] // the legacy shims are compared against the planner

use std::sync::Arc;

use emdpar::config::{Config, DatasetSpec, IndexParams, ShardParams};
use emdpar::coordinator::{
    cascade_search, CascadeSpec, SearchEngine, SearchRequest, Stage, TopL,
};
use emdpar::core::{Dataset, Histogram, Method};
use emdpar::util::json::Json;

fn dataset() -> Arc<Dataset> {
    Arc::new(
        Config {
            dataset: DatasetSpec::SynthText { n: 60, vocab: 240, dim: 10, seed: 33 },
            ..Config::default()
        }
        .load_dataset()
        .unwrap(),
    )
}

fn index_params() -> IndexParams {
    IndexParams { nlist: 5, nprobe: 2, train_iters: 6, seed: 4, min_points_per_list: 1 }
}

fn engine(ds: &Arc<Dataset>, index: bool, shards: Option<usize>) -> SearchEngine {
    SearchEngine::with_dataset(
        Config {
            threads: 2,
            index: index.then(index_params),
            sharded: shards.map(|s| ShardParams { shards: s, max_docs_per_shard: 1 << 20 }),
            ..Config::default()
        },
        Arc::clone(ds),
    )
    .unwrap()
}

#[test]
fn legacy_entry_points_are_bit_identical_to_requests() {
    let ds = dataset();
    let engines =
        [engine(&ds, false, None), engine(&ds, true, None), engine(&ds, true, Some(3))];
    let queries: Vec<Histogram> = (0..4).map(|u| ds.histogram(u * 11)).collect();
    for (e, eng) in engines.iter().enumerate() {
        for method in [Method::Rwmd, Method::Act { k: 2 }, Method::Wcd] {
            for l in [1usize, 6] {
                for nprobe in [None, Some(2), Some(64)] {
                    let tag = format!("engine {e} {method} l={l} nprobe={nprobe:?}");
                    // single-query legacy vs request
                    let legacy = eng.search_opts(&queries[0], method, l, nprobe).unwrap();
                    let mut req =
                        SearchRequest::query(queries[0].clone()).method(method).topl(l);
                    if let Some(np) = nprobe {
                        req = req.nprobe(np);
                    }
                    let resp = eng.execute(&req).unwrap();
                    assert_eq!(legacy.hits, resp.results[0].hits, "{tag}");
                    assert_eq!(legacy.labels, resp.results[0].labels, "{tag}");
                    // batched legacy vs request
                    let legacy = eng.search_batch_opts(&queries, method, l, nprobe).unwrap();
                    let mut req = SearchRequest::batch(queries.clone()).method(method).topl(l);
                    if let Some(np) = nprobe {
                        req = req.nprobe(np);
                    }
                    let resp = eng.execute(&req).unwrap();
                    assert_eq!(legacy.len(), resp.results.len(), "{tag}");
                    for (a, b) in legacy.iter().zip(&resp.results) {
                        assert_eq!(a.hits, b.hits, "{tag}");
                        assert_eq!(a.labels, b.labels, "{tag}");
                    }
                }
            }
        }
    }
}

#[test]
fn request_route_matches_first_principles_topl() {
    // plain engine, exhaustive route: the planner must equal a TopL scan of
    // the raw distance row (not just the legacy shim, which delegates)
    let ds = dataset();
    let eng = engine(&ds, false, None);
    let q = ds.histogram(7);
    let row = eng.native().distances(&q, Method::Act { k: 2 });
    let mut want = TopL::new(5);
    want.push_slice(&row, 0);
    let resp = eng
        .execute(&SearchRequest::query(q).method(Method::Act { k: 2 }).topl(5))
        .unwrap();
    assert_eq!(resp.results[0].hits, want.into_sorted());
    assert_eq!(resp.stats.candidates_scored, ds.len());
}

#[test]
fn cascade_request_matches_legacy_cascade_search() {
    let ds = dataset();
    let eng = engine(&ds, false, None);
    let q = ds.histogram(3);
    for rerank in [Method::Act { k: 4 }, Method::Ict, Method::Exact] {
        for overfetch in [1usize, 4, 64] {
            let legacy = cascade_search(&eng.native(), &q, rerank, 5, overfetch).unwrap();
            let req = SearchRequest::query(q.clone())
                .topl(5)
                .cascade(CascadeSpec::new(rerank).overfetch(overfetch));
            let resp = eng.execute(&req).unwrap();
            let tag = format!("{rerank} overfetch={overfetch}");
            assert_eq!(resp.results[0].hits, legacy.hits, "{tag}");
            assert_eq!(resp.stats.certified[0], legacy.certified, "{tag}");
            assert_eq!(resp.stats.reranked, legacy.reranked, "{tag}");
        }
    }
}

#[test]
fn cascade_over_sharded_corpus_full_probe_is_bit_identical_to_exhaustive_rerank() {
    // the previously-impossible composition: cascade over a sharded corpus.
    // Per-shard RWMD shortlists -> global top-(overfetch·ℓ+1) merge ->
    // dominating rerank, bit-identical to brute-force rerank at full probe.
    let ds = dataset();
    let n = ds.len();
    for shards in [2usize, 4] {
        let eng = engine(&ds, true, Some(shards));
        for (qid, rerank) in [(5usize, Method::Exact), (20, Method::Act { k: 4 })] {
            let q = ds.histogram(qid);
            let req = SearchRequest::query(q.clone())
                .topl(4)
                .nprobe(1 << 20) // >= nlist on every shard: full probe
                .cascade(CascadeSpec::new(rerank).overfetch(n));
            let resp = eng.execute(&req).unwrap();
            // exhaustive rerank reference: the per-pair measure over every
            // document, top-4 by (distance, id)
            let dist = eng.registry().distance(rerank);
            let qn = q.normalized();
            let mut want = TopL::new(4);
            for u in 0..n {
                let d = dist.distance(&ds.embeddings, &ds.histogram(u), &qn).unwrap() as f32;
                want.push(d, u);
            }
            let want = want.into_sorted();
            assert_eq!(resp.results[0].hits, want, "S={shards} {rerank}");
            assert!(
                resp.stats.certified[0],
                "full-coverage full-overfetch cascade must be certified"
            );
            // and identical to the monolithic legacy cascade over the
            // engine's own fallback engine
            let legacy = cascade_search(&eng.native(), &q, rerank, 4, n).unwrap();
            assert_eq!(resp.results[0].hits, legacy.hits, "S={shards} {rerank}");
            assert_eq!(resp.stats.certified[0], legacy.certified);
        }
    }
}

#[test]
fn certified_cascade_over_shards_forces_full_coverage() {
    let ds = dataset();
    let eng = engine(&ds, true, Some(3));
    let q = ds.histogram(9);
    let req = SearchRequest::query(q.clone())
        .topl(3)
        .nprobe(1) // ignored: certified demands coverage
        .cascade(CascadeSpec::new(Method::Ict).overfetch(ds.len()).certified(true));
    let resp = eng.execute(&req).unwrap();
    assert!(resp.stats.certified[0]);
    assert_eq!(resp.stats.candidates_scored, ds.len(), "certified forces full coverage");
    // the same request uncertified prunes — and cannot claim a certificate
    let req = SearchRequest::query(q)
        .topl(3)
        .nprobe(1)
        .cascade(CascadeSpec::new(Method::Ict).overfetch(ds.len()));
    let resp = eng.execute(&req).unwrap();
    assert!(resp.stats.candidates_scored < ds.len(), "nprobe 1 must prune somewhere");
    assert!(!resp.stats.certified[0], "pruned stage 1 cannot claim a global certificate");
}

#[test]
fn sharded_cascade_finds_appended_documents() {
    // cascade over the *live* corpus: appended docs are visible to both
    // stages (the planner reads the corpus, not the build-time snapshot)
    let ds = dataset();
    let eng = engine(&ds, true, Some(2));
    let doc = Histogram::from_pairs(vec![(7, 0.6), (13, 0.4)]);
    let out = eng.add_docs(std::slice::from_ref(&doc), &[9]).unwrap();
    assert_eq!(out.ids, vec![60]);
    let req = SearchRequest::query(doc)
        .topl(3)
        .cascade(CascadeSpec::new(Method::Exact).overfetch(eng.num_docs()).certified(true));
    let resp = eng.execute(&req).unwrap();
    assert_eq!(resp.results[0].hits[0].1, 60, "the appended doc reranks first");
    assert_eq!(resp.results[0].labels[0], 9);
    assert!(resp.stats.certified[0]);
}

#[test]
fn tcp_request_object_round_trips() {
    let wire = "{\"op\": \"search\", \"method\": \"act-1\", \"l\": 5, \"nprobe\": 3, \
                \"cascade\": {\"rerank\": \"emd\", \"overfetch\": 4, \"certified\": false}, \
                \"query\": [[1, 0.5], [4, 0.5]]}";
    let req = SearchRequest::from_json(&Json::parse(wire).unwrap()).unwrap();
    assert_eq!(req.method, Some(Method::Act { k: 2 }));
    assert_eq!(req.l, Some(5));
    assert_eq!(req.nprobe, Some(3));
    assert_eq!(req.queries().len(), 1);
    let spec = req.cascade.unwrap();
    assert_eq!(spec.rerank, Method::Exact);
    assert_eq!(spec.overfetch, Some(4));
    assert!(!spec.certified);
    // serialize -> reparse -> equal (weights travel as f64: bit-exact)
    let back =
        SearchRequest::from_json(&Json::parse(&req.to_json().to_string_compact()).unwrap())
            .unwrap();
    assert_eq!(back, req);
}

#[test]
fn plan_composes_prune_fanout_merge_rerank() {
    let ds = dataset();
    let eng = engine(&ds, true, Some(3));
    let q = ds.histogram(0);
    let p = eng
        .plan(
            &SearchRequest::query(q)
                .topl(4)
                .nprobe(2)
                .cascade(CascadeSpec::new(Method::Exact)),
        )
        .unwrap();
    let kinds: Vec<&str> = p
        .stages
        .iter()
        .map(|s| match s {
            Stage::Prune { .. } => "prune",
            Stage::Score { .. } => "score",
            Stage::ShardFanout { .. } => "fanout",
            Stage::Merge { .. } => "merge",
            Stage::CascadeRerank { .. } => "rerank",
            Stage::ExactRerank { .. } => "exact-rerank",
        })
        .collect();
    assert_eq!(kinds, ["prune", "score", "fanout", "merge", "rerank"]);
    assert_eq!(p.method, Method::Rwmd, "cascade stage 1 is canonical LC-RWMD");
    assert!(!p.describe().is_empty());
}

#[test]
fn group_keys_route_equivalent_requests_together() {
    let ds = dataset();
    let eng = engine(&ds, true, None);
    let q = ds.histogram(1);
    // nprobe beyond nlist and nprobe = nlist resolve to the same effective
    // width: one grouped dispatch on the server
    let a = SearchRequest::query(q.clone()).nprobe(5).group_key(&eng);
    let b = SearchRequest::query(q.clone()).nprobe(500).group_key(&eng);
    assert_eq!(a, b);
    // different ℓ splits the group
    let c = SearchRequest::query(q).topl(3).group_key(&eng);
    assert_ne!(a, c);
}
