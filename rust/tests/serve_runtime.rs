//! Serving-runtime integration suite: the event-loop [`ReactorServer`]
//! against the legacy thread-per-connection [`Server`].
//!
//! * byte-identity: both servers answer the full request matrix
//!   (search / search_id / cascade / add_docs / stats / malformed lines)
//!   with byte-for-byte identical responses across plain, indexed and
//!   sharded engines,
//! * FIFO pipelining under concurrent mixed-op clients,
//! * admission control (`overloaded` + `retry_after_ms`), per-request
//!   deadlines, idle-connection timeouts, oversized/invalid-UTF-8 lines,
//! * the CI soak gate: hammer the reactor with concurrent pipelined
//!   clients, assert zero dropped/misordered responses and a clean
//!   shutdown (`EMDPAR_SOAK_MS` scales the duration).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use emdpar::coordinator::SearchEngine;
use emdpar::prelude::{
    Config, DatasetSpec, IndexParams, ReactorServer, ServeParams, Server, ShardParams,
};
use emdpar::util::json::Json;

// ---------------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------------

fn plain_config() -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
        threads: 2,
        linger_ms: 1,
        ..Default::default()
    }
}

fn indexed_config() -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n: 48, vocab: 200, dim: 8, seed: 12 },
        threads: 2,
        linger_ms: 1,
        index: Some(IndexParams {
            nlist: 6,
            nprobe: 2,
            train_iters: 6,
            seed: 4,
            min_points_per_list: 1,
        }),
        ..Default::default()
    }
}

fn sharded_config() -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 15 },
        threads: 2,
        linger_ms: 1,
        sharded: Some(ShardParams { shards: 2, max_docs_per_shard: 1 << 20 }),
        index: Some(IndexParams {
            nlist: 4,
            nprobe: 4,
            train_iters: 5,
            seed: 2,
            min_points_per_list: 1,
        }),
        ..Default::default()
    }
}

fn engine(cfg: Config) -> SearchEngine {
    SearchEngine::from_config(cfg).unwrap()
}

/// Pipeline every line down one connection (single write), then read one
/// response per line.
fn pipeline_client(addr: SocketAddr, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut out = Vec::with_capacity(lines.len());
    phased_pipeline(stream, &[lines.to_vec()], &mut out);
    out
}

/// Pipeline each phase down an open connection, fully reading that phase's
/// responses before writing the next.  The read barrier is an ordering
/// guarantee: a response on the wire means its request finished executing,
/// so later phases (e.g. `add_docs`, `stats`) cannot race in-flight
/// searches from earlier ones.
fn phased_pipeline(stream: TcpStream, phases: &[Vec<String>], out: &mut Vec<String>) {
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for phase in phases {
        let mut payload = String::new();
        for line in phase {
            payload.push_str(line);
            payload.push('\n');
        }
        writer.write_all(payload.as_bytes()).unwrap();
        writer.flush().unwrap();
        for _ in 0..phase.len() {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            out.push(resp.trim_end_matches('\n').to_string());
        }
    }
}

fn legacy_roundtrip(engine: SearchEngine, phases: &[Vec<String>]) -> Vec<String> {
    let server = Server::bind(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let phases = phases.to_vec();
    let client = std::thread::spawn(move || {
        let mut out = Vec::new();
        phased_pipeline(TcpStream::connect(addr).unwrap(), &phases, &mut out);
        out
    });
    server.serve_n(1).unwrap();
    client.join().unwrap()
}

fn reactor_roundtrip(engine: SearchEngine, phases: &[Vec<String>]) -> Vec<String> {
    let server = ReactorServer::bind(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let phases = phases.to_vec();
    let client = std::thread::spawn(move || {
        let mut out = Vec::new();
        phased_pipeline(TcpStream::connect(addr).unwrap(), &phases, &mut out);
        out
    });
    server.serve_n(1).unwrap();
    client.join().unwrap()
}

// ---------------------------------------------------------------------------
// byte-identity across the request matrix
// ---------------------------------------------------------------------------

/// The full request matrix: valid searches (all the protocol forms),
/// cascades, malformed/invalid lines, a live append, stats.  Three phases
/// with read barriers between them, so the append and the stats snapshot
/// are deterministically ordered after every in-flight search on both
/// runtimes (within a phase requests race only for latency histograms,
/// which the comparison excludes).
fn request_matrix() -> Vec<Vec<String>> {
    let phase1: Vec<String> = [
        r#"{"op": "ping"}"#,
        r#"{"op": "search_id", "id": 3, "l": 4, "method": "act-1"}"#,
        r#"{"op": "search", "l": 3, "query": [[0, 0.5], [3, 0.5]], "method": "rwmd"}"#,
        r#"{"op": "search_id", "id": 2, "l": 3, "method": "emd"}"#,
        r#"{"op": "search_id", "id": 2, "l": 3, "method": "wcd", "nprobe": 2}"#,
        r#"{"op": "search_id", "id": 4, "l": 3, "cascade": {"rerank": "emd", "overfetch": 16, "certified": true}}"#,
        r#"{"op": "search_id", "id": 4, "l": 3, "cascade": "act-3"}"#,
        r#"{not json"#,
        r#"{"op": "nope"}"#,
        r#"{"op": "search", "query": []}"#,
        r#"{"op": "search_id", "id": 4, "l": 3, "cascade": "bow"}"#,
        r#"{"op": "search_id", "id": 100000, "l": 3}"#,
    ]
    .into_iter()
    .map(String::from)
    .collect();
    let phase2: Vec<String> = [
        r#"{"op": "add_docs", "docs": [[[2, 0.6], [9, 0.4]], [[11, 1.0]]], "labels": [5, 6]}"#,
        r#"{"op": "search_id", "id": 5, "l": 3, "method": "rwmd"}"#,
    ]
    .into_iter()
    .map(String::from)
    .collect();
    let phase3 = vec![r#"{"op": "stats"}"#.to_string()];
    vec![phase1, phase2, phase3]
}

/// Counters that must agree between the two servers (latency histograms and
/// admission counters legitimately differ: the legacy server admits nothing).
const DETERMINISTIC_STATS: &[&str] =
    &["n", "errors", "queries", "index_queries", "cascade_queries", "deadline_expired"];

fn assert_servers_identical(make: fn() -> Config) {
    let phases = request_matrix();
    let legacy = legacy_roundtrip(engine(make()), &phases);
    let reactor = reactor_roundtrip(engine(make()), &phases);
    let lines: Vec<String> = phases.into_iter().flatten().collect();
    assert_eq!(legacy.len(), lines.len());
    assert_eq!(legacy.len(), reactor.len());
    for (i, (l, r)) in legacy.iter().zip(&reactor).enumerate() {
        if lines[i].contains("\"stats\"") {
            let (lj, rj) = (Json::parse(l).unwrap(), Json::parse(r).unwrap());
            for key in DETERMINISTIC_STATS {
                assert_eq!(lj.get(key), rj.get(key), "stats '{key}' diverged");
            }
        } else {
            assert_eq!(l, r, "response {i} diverged for request {}", lines[i]);
        }
    }
    // every response is a complete JSON object with an "ok" verdict
    for resp in &reactor {
        let j = Json::parse(resp).unwrap();
        assert!(j.get("ok").is_some(), "{resp}");
    }
}

#[test]
fn reactor_matches_legacy_on_plain_engine() {
    assert_servers_identical(plain_config);
}

#[test]
fn reactor_matches_legacy_on_indexed_engine() {
    assert_servers_identical(indexed_config);
}

#[test]
fn reactor_matches_legacy_on_sharded_engine() {
    assert_servers_identical(sharded_config);
}

// ---------------------------------------------------------------------------
// FIFO pipelining under concurrent mixed-op clients
// ---------------------------------------------------------------------------

/// One client's mixed-op script plus a closure validating response `i`.
fn mixed_script(client_id: usize, n_docs: usize) -> Vec<(String, fn(&Json, usize))> {
    fn expect_pong(j: &Json, _id: usize) {
        assert_eq!(j.get("pong"), Some(&Json::Bool(true)), "{j:?}");
    }
    fn expect_self_hit(j: &Json, id: usize) {
        let hits = j.get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(id), "{j:?}");
    }
    fn expect_error(j: &Json, _id: usize) {
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{j:?}");
    }
    fn expect_ok(j: &Json, _id: usize) {
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j:?}");
    }
    let a = client_id % n_docs;
    let b = (client_id * 7 + 3) % n_docs;
    vec![
        (r#"{"op": "ping"}"#.to_string(), expect_pong as fn(&Json, usize)),
        (format!(r#"{{"op": "search_id", "id": {a}, "l": 3, "method": "act-1"}}"#), expect_self_hit),
        (r#"{"op": "nope"}"#.to_string(), expect_error),
        (format!(r#"{{"op": "search_id", "id": {b}, "l": 3, "method": "rwmd"}}"#), expect_self_hit),
        (r#"{"op": "stats"}"#.to_string(), expect_ok),
        (format!(r#"{{"op": "search_id", "id": {a}, "l": 2, "method": "wcd"}}"#), expect_self_hit),
    ]
}

/// Expected ids for the two search_id positions in `mixed_script`.
fn script_ids(client_id: usize, n_docs: usize) -> [usize; 6] {
    let a = client_id % n_docs;
    let b = (client_id * 7 + 3) % n_docs;
    [0, a, 0, b, 0, a]
}

#[test]
fn pipelined_fifo_under_concurrent_mixed_clients() {
    let n_docs = 30;
    let mut cfg = plain_config();
    cfg.linger_ms = 5; // encourage cross-client batching
    cfg.serve = ServeParams { reactors: 2, ..Default::default() };
    let server = ReactorServer::bind(engine(cfg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let clients = 6;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let script = mixed_script(c, n_docs);
                let lines: Vec<String> = script.iter().map(|(l, _)| l.clone()).collect();
                let responses = pipeline_client(addr, &lines);
                let ids = script_ids(c, n_docs);
                for (i, ((_, check), resp)) in script.iter().zip(&responses).enumerate() {
                    let j = Json::parse(resp).unwrap_or_else(|e| {
                        panic!("client {c} response {i} not json ({e}): {resp}")
                    });
                    check(&j, ids[i]);
                }
            })
        })
        .collect();
    server.serve_n(clients).unwrap();
    for h in handles {
        h.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// admission control, deadlines, idle timeout, robustness
// ---------------------------------------------------------------------------

#[test]
fn overload_sheds_with_structured_error() {
    let mut cfg = plain_config();
    // hold the first search in the batcher long enough that its admission
    // slot is still occupied when the rest of the pipeline arrives
    cfg.linger_ms = 200;
    cfg.max_batch = 64;
    cfg.serve = ServeParams { max_inflight: 1, retry_after_ms: 7, ..Default::default() };
    let search = r#"{"op": "search_id", "id": 1, "l": 3, "method": "rwmd"}"#.to_string();
    let lines = vec![search; 6];
    let out = reactor_roundtrip(engine(cfg), &[lines]);
    let first = Json::parse(&out[0]).unwrap();
    assert_eq!(first.get("ok"), Some(&Json::Bool(true)), "admitted search completes: {first:?}");
    for resp in &out[1..] {
        let j = Json::parse(resp).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{j:?}");
        assert_eq!(j.get("error").and_then(Json::as_str), Some("overloaded"), "{j:?}");
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_usize), Some(7), "{j:?}");
    }
}

#[test]
fn reactor_honors_per_request_deadline() {
    let mut cfg = plain_config();
    cfg.linger_ms = 50; // the 1ms deadline below expires inside the linger
    cfg.max_batch = 64;
    let lines = vec![
        r#"{"op": "search_id", "id": 1, "l": 3, "deadline_ms": 1}"#.to_string(),
        r#"{"op": "ping"}"#.to_string(),
    ];
    let out = reactor_roundtrip(engine(cfg), &[lines]);
    let j = Json::parse(&out[0]).unwrap();
    assert_eq!(j.get("error").and_then(Json::as_str), Some("deadline exceeded"), "{j:?}");
    let pong = Json::parse(&out[1]).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)), "connection survives the shed");
}

#[test]
fn idle_connections_are_reaped() {
    let mut cfg = plain_config();
    cfg.serve = ServeParams { idle_timeout_ms: 50, ..Default::default() };
    let server = ReactorServer::bind(engine(cfg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = [0u8; 16];
        let t0 = Instant::now();
        let n = stream.read(&mut buf).unwrap(); // blocks until the server closes
        assert_eq!(n, 0, "idle connection must be closed by the server");
        assert!(t0.elapsed() < Duration::from_secs(5), "reaped via the idle sweep, not never");
    });
    server.serve_n(1).unwrap();
    client.join().unwrap();
    assert_eq!(server.active_connections(), 0);
}

#[test]
fn reactor_survives_oversized_and_invalid_utf8_lines() {
    let mut cfg = plain_config();
    cfg.serve = ServeParams { max_line_bytes: 256, ..Default::default() };
    let server = ReactorServer::bind(engine(cfg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(b"{\"op\": \"ping\"}\n");
        payload.extend_from_slice(&vec![b'x'; 4096]);
        payload.push(b'\n');
        payload.extend_from_slice(b"{\"op\": \"ping\" \xff\xfe}\n");
        payload.extend_from_slice(b"{\"op\": \"ping\"}\n");
        stream.write_all(&payload).unwrap();
        let mut reader = BufReader::new(stream);
        let mut out = Vec::new();
        for _ in 0..4 {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            out.push(Json::parse(resp.trim()).unwrap());
        }
        out
    });
    server.serve_n(1).unwrap();
    let out = client.join().unwrap();
    assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
    assert!(out[1].get("error").and_then(Json::as_str).unwrap().contains("exceeds 256 bytes"));
    assert!(out[2].get("error").and_then(Json::as_str).unwrap().contains("invalid utf-8"));
    assert_eq!(out[3].get("pong"), Some(&Json::Bool(true)), "connection survives both");
}

// ---------------------------------------------------------------------------
// soak gate
// ---------------------------------------------------------------------------

/// The CI soak: concurrent pipelined clients hammering the reactor with a
/// fresh connection per round (exercising accept, pipelining and reaping).
/// `EMDPAR_SOAK_MS` scales the number of rounds (default ≈300ms of work
/// locally).  Every response must arrive, in FIFO order, with the shape its
/// request demands; the server must drain and shut down cleanly afterwards.
#[test]
fn soak_concurrent_pipelined_clients_zero_drops() {
    let soak_ms: u64 = std::env::var("EMDPAR_SOAK_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    // a round (6 pipelined mixed requests) costs ~15ms on a cold CI box
    let rounds = ((soak_ms / 15).max(1)) as usize;
    let n_docs = 30;
    let mut cfg = plain_config();
    cfg.linger_ms = 2;
    cfg.serve = ServeParams { reactors: 2, ..Default::default() };
    let server = ReactorServer::bind(engine(cfg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let clients = 8;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut total = 0usize;
                let script = mixed_script(c, n_docs);
                let lines: Vec<String> = script.iter().map(|(l, _)| l.clone()).collect();
                let ids = script_ids(c, n_docs);
                for _ in 0..rounds {
                    let responses = pipeline_client(addr, &lines);
                    assert_eq!(responses.len(), lines.len(), "dropped responses");
                    for (i, ((_, check), resp)) in script.iter().zip(&responses).enumerate() {
                        let j = Json::parse(resp).unwrap_or_else(|e| {
                            panic!("client {c} response {i} not json ({e}): {resp}")
                        });
                        check(&j, ids[i]);
                    }
                    total += responses.len();
                }
                total
            })
        })
        .collect();
    server.serve_n(clients * rounds).unwrap();
    let mut total = 0usize;
    for h in handles {
        total += h.join().unwrap();
    }
    assert_eq!(total, clients * rounds * 6, "every pipelined response must arrive");
    assert_eq!(server.active_connections(), 0, "all connections drained");
    drop(server); // Drop joins every reactor thread: clean shutdown
}
