//! Workload-telemetry & recall-audit acceptance (ISSUE 9).
//!
//! * telemetry + auditing are **byte-identity neutral**: with the store
//!   unarmed and sampling off, and with both fully on, the reactor answers
//!   the same request script with byte-for-byte identical responses;
//! * the `{"op":"telemetry"}` wire op reports per-workload windowed
//!   aggregates, and at 1-in-1 sampling the background auditor replays
//!   served queries at full probe — for a workload already running at
//!   full probe the audited recall@ℓ is exactly 1.0;
//! * an unarmed store (`telemetry_window_ms = 0`) records nothing;
//! * the `--metrics-addr` HTTP listener wired to a live [`ReactorServer`]
//!   answers `/healthz`, `/readyz` (via the engine+admission probe) and
//!   exposes the per-workload Prometheus gauges.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use emdpar::coordinator::SearchEngine;
use emdpar::prelude::{Config, DatasetSpec, ReactorServer, ServeParams};
use emdpar::util::json::Json;

fn config(telemetry_window_ms: u64, audit_sample: u64) -> Config {
    Config {
        dataset: DatasetSpec::SynthText { n: 40, vocab: 160, dim: 8, seed: 21 },
        threads: 2,
        linger_ms: 1,
        serve: ServeParams { telemetry_window_ms, audit_sample, ..Default::default() },
        ..Default::default()
    }
}

fn engine(cfg: Config) -> SearchEngine {
    SearchEngine::from_config(cfg).unwrap()
}

/// Pipeline `lines` down one reactor connection, one response per line.
fn roundtrip(cfg: Config, lines: &[String]) -> Vec<String> {
    let server = ReactorServer::bind(engine(cfg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let lines = lines.to_vec();
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut payload = String::new();
        for line in &lines {
            payload.push_str(line);
            payload.push('\n');
        }
        w.write_all(payload.as_bytes()).unwrap();
        w.flush().unwrap();
        let mut out = Vec::with_capacity(lines.len());
        for _ in 0..lines.len() {
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            out.push(resp.trim_end_matches('\n').to_string());
        }
        out
    });
    server.serve_n(1).unwrap();
    client.join().unwrap()
}

/// Deterministic request script (no `stats`: latency histograms may differ).
fn script() -> Vec<String> {
    [
        r#"{"op": "ping"}"#,
        r#"{"op": "search_id", "id": 3, "l": 4, "method": "act-1"}"#,
        r#"{"op": "search", "l": 3, "query": [[0, 0.5], [3, 0.5]], "method": "rwmd"}"#,
        r#"{"op": "search_id", "id": 2, "l": 3, "method": "emd"}"#,
        r#"{"op": "search_id", "id": 4, "l": 3, "cascade": {"rerank": "emd", "overfetch": 16, "certified": true}}"#,
        r#"{not json"#,
        r#"{"op": "search", "query": []}"#,
        r#"{"op": "search_id", "id": 7, "l": 3, "method": "rwmd"}"#,
    ]
    .into_iter()
    .map(String::from)
    .collect()
}

#[test]
fn telemetry_and_auditing_leave_the_wire_byte_identical() {
    let lines = script();
    let off = roundtrip(config(0, 0), &lines);
    let on = roundtrip(config(1000, 2), &lines);
    assert_eq!(off.len(), lines.len());
    for (i, (o, n)) in off.iter().zip(&on).enumerate() {
        assert_eq!(o, n, "response {i} diverged for request {}", lines[i]);
    }
}

#[test]
fn unarmed_store_records_nothing_over_the_wire() {
    let server = ReactorServer::bind(engine(config(0, 0)), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        w.write_all(
            b"{\"op\": \"search_id\", \"id\": 1, \"l\": 3}\n{\"op\":\"telemetry\"}\n",
        )
        .unwrap();
        let mut hits = String::new();
        r.read_line(&mut hits).unwrap();
        let mut tele = String::new();
        r.read_line(&mut tele).unwrap();
        let j = Json::parse(tele.trim()).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{tele}");
        let t = j.get("telemetry").unwrap();
        assert_eq!(
            t.get("workloads").and_then(Json::as_arr).map(<[Json]>::len),
            Some(0),
            "unarmed store must stay empty: {tele}"
        );
        let a = j.get("audit").unwrap();
        assert_eq!(a.get("sample").and_then(Json::as_usize), Some(0), "{tele}");
        assert_eq!(a.get("audited").and_then(Json::as_usize), Some(0), "{tele}");
    });
    server.serve_n(1).unwrap();
    client.join().unwrap();
}

#[test]
fn full_probe_workload_audits_to_recall_one_over_the_wire() {
    // no index configured: the served route IS the exhaustive reference,
    // so every full-probe replay must agree exactly
    let server = ReactorServer::bind(engine(config(1000, 1)), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        for id in 0..4 {
            w.write_all(
                format!("{{\"op\": \"search_id\", \"id\": {id}, \"l\": 3, \"method\": \"rwmd\"}}\n")
                    .as_bytes(),
            )
            .unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            assert!(j.get("hits").is_some(), "{resp}");
        }
        // poll the telemetry op until the background worker has replayed
        // all four samples (1-in-1 sampling)
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            w.write_all(b"{\"op\":\"telemetry\"}\n").unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            let tele = j.get("telemetry").unwrap();
            let workloads = tele.get("workloads").and_then(Json::as_arr).unwrap();
            assert!(!workloads.is_empty(), "served queries must land in the window: {resp}");
            assert_eq!(
                workloads[0].get("queries").and_then(Json::as_usize),
                Some(4),
                "{resp}"
            );
            assert!(
                workloads[0].get("qps").and_then(Json::as_f64).unwrap() > 0.0,
                "{resp}"
            );
            let audit = j.get("audit").unwrap();
            assert_eq!(audit.get("sample").and_then(Json::as_usize), Some(1), "{resp}");
            if audit.get("audited").and_then(Json::as_usize).unwrap_or(0) >= 4 {
                let est = audit.get("workloads").and_then(Json::as_arr).unwrap();
                assert_eq!(est.len(), 1, "one workload audited: {resp}");
                assert_eq!(est[0].get("audits").and_then(Json::as_usize), Some(4), "{resp}");
                assert_eq!(est[0].get("recall").and_then(Json::as_f64), Some(1.0), "{resp}");
                assert_eq!(est[0].get("min_recall").and_then(Json::as_f64), Some(1.0), "{resp}");
                assert!(
                    est[0].get("replay_us").and_then(Json::as_usize).unwrap() > 0,
                    "{resp}"
                );
                break;
            }
            assert!(Instant::now() < deadline, "audits never completed: {resp}");
            std::thread::sleep(Duration::from_millis(50));
        }
    });
    server.serve_n(1).unwrap();
    client.join().unwrap();
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn health_surface_and_workload_gauges_ride_the_metrics_listener() {
    let server = ReactorServer::bind(engine(config(1000, 0)), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let render_engine = Arc::clone(server.engine());
    let render: Arc<dyn Fn() -> String + Send + Sync> =
        Arc::new(move || emdpar::obs::prom::render_engine(&render_engine));
    let (maddr, _handle) =
        emdpar::obs::http::spawn_listener("127.0.0.1:0", render, Some(server.ready_probe()))
            .unwrap();
    // drive one search so a workload lands in the live window
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        w.write_all(b"{\"op\": \"search_id\", \"id\": 5, \"l\": 3, \"method\": \"rwmd\"}\n")
            .unwrap();
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        assert!(resp.contains("hits"), "{resp}");
    });
    server.serve_n(1).unwrap();
    client.join().unwrap();

    let health = http_get(maddr, "/healthz");
    assert!(health.starts_with("HTTP/1.0 200"), "{health}");
    let ready = http_get(maddr, "/readyz");
    assert!(ready.starts_with("HTTP/1.0 200"), "{ready}");
    assert!(ready.ends_with("ready\n"), "{ready}");
    let metrics = http_get(maddr, "/metrics");
    assert!(metrics.contains("emdpar_queries_total 1"), "{metrics}");
    assert!(metrics.contains("emdpar_workload_qps{workload=\"rwmd_l3_full\"}"), "{metrics}");
    assert!(metrics.contains("emdpar_workload_queries{workload=\"rwmd_l3_full\"} 1"), "{metrics}");
    assert!(metrics.contains("emdpar_audit_sample 0"), "{metrics}");
    assert!(metrics.contains("emdpar_audits_total 0"), "{metrics}");
}
