//! Integration: the PJRT artifact pipeline must reproduce the native CPU
//! LC engine bit-for-bit up to f32 tolerance, across k values, for both
//! the split (phase1 + phase2-per-tile) and fused paths.
//!
//! Requires `make artifacts` (skips with a message if artifacts/ is absent).

use std::path::Path;

use emdpar::core::Metric;
use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::{EngineParams, LcEngine, Method};
use emdpar::runtime::{ArtifactEngine, Executor};

fn executor() -> Option<Executor> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Executor::new(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping artifact tests: {err:#}");
            None
        }
    }
}

fn dev_dataset(exec: &Executor) -> emdpar::core::Dataset {
    let spec = exec
        .manifest()
        .artifacts
        .values()
        .find(|a| a.profile == "dev")
        .expect("dev profile present");
    generate_text(&TextConfig {
        n: 300, // more than two tiles (dev n_tile = 128): exercises padding
        classes: 5,
        vocab: spec.v,
        dim: spec.m,
        doc_len: spec.h / 2,
        seed: 11,
        ..Default::default()
    })
}

#[test]
fn artifact_matches_native_across_k() {
    let Some(exec) = executor() else { return };
    let ds = dev_dataset(&exec);
    let art = ArtifactEngine::new(&exec, &ds, "dev").expect("bind dev profile");
    let native = LcEngine::new(
        std::sync::Arc::new(ds.clone()),
        EngineParams { metric: Metric::L2, threads: 2, symmetric: false, ..Default::default() },
    );
    for k in exec.manifest().ks_for("dev") {
        let q = ds.histogram(1);
        let got = art.distances(&q, k, false).expect("artifact distances");
        let want = native.distances(&q, Method::Act { k });
        assert_eq!(got.len(), want.len());
        for (u, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-4 + 1e-3 * w.abs(),
                "k={k} doc={u}: pjrt {g} vs native {w}"
            );
        }
    }
}

#[test]
fn artifact_symmetric_matches_native_symmetric() {
    let Some(exec) = executor() else { return };
    let ds = dev_dataset(&exec);
    let art = ArtifactEngine::new(&exec, &ds, "dev").expect("bind dev profile");
    let native = LcEngine::new(
        std::sync::Arc::new(ds.clone()),
        EngineParams { metric: Metric::L2, threads: 2, symmetric: true, ..Default::default() },
    );
    let q = ds.histogram(7);
    let got = art.distances(&q, 2, true).unwrap();
    let want = native.distances(&q, Method::Act { k: 2 });
    for (u, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 1e-4 + 1e-3 * w.abs(),
            "doc={u}: pjrt {g} vs native {w}"
        );
    }
}

#[test]
fn fused_tile_matches_split_pipeline() {
    let Some(exec) = executor() else { return };
    let ds = dev_dataset(&exec);
    let art = ArtifactEngine::new(&exec, &ds, "dev").unwrap();
    let q = ds.histogram(3);
    let k = 4;
    let split = art.distances(&q, k, false).unwrap();
    let (fused_a, _fused_b) = art.distances_fused_tile(&q, k, 0).unwrap();
    let tile_rows = fused_a.len().min(split.len());
    for u in 0..tile_rows {
        assert!(
            (split[u] - fused_a[u]).abs() < 1e-4,
            "doc {u}: split {} vs fused {}",
            split[u],
            fused_a[u]
        );
    }
}

#[test]
fn padded_tail_rows_cost_zero() {
    let Some(exec) = executor() else { return };
    let ds = dev_dataset(&exec);
    let art = ArtifactEngine::new(&exec, &ds, "dev").unwrap();
    // last tile has padding (300 = 2*128 + 44); results must have exactly n
    let q = ds.histogram(0);
    let got = art.distances(&q, 2, false).unwrap();
    assert_eq!(got.len(), ds.len());
    assert_eq!(art.num_tiles(), 3);
}

#[test]
fn executor_caches_compilations() {
    let Some(exec) = executor() else { return };
    let ds = dev_dataset(&exec);
    let art = ArtifactEngine::new(&exec, &ds, "dev").unwrap();
    let q = ds.histogram(0);
    art.distances(&q, 2, false).unwrap();
    let after_first = exec.compiled_count();
    art.distances(&q, 2, false).unwrap();
    assert_eq!(exec.compiled_count(), after_first, "recompiled on second query");
}
