//! Integration: the theorem chain through the *unified distance API* —
//! every bound obtained via `MethodRegistry` lookup (boxed `Distance` /
//! `BatchDistance` trait objects), never by calling the per-module free
//! functions directly.  On random datasets the chain
//!
//! ```text
//! BoW-adjusted <= RWMD <= OMR <= ACT-k <= ACT-k' (k' > k) <= ICT <= EMD
//! ```
//!
//! must hold pairwise, Sinkhorn must upper-bound exact EMD, and the batched
//! `BatchDistance` objects must agree with the per-pair objects.

use std::sync::Arc;

use emdpar::data::{generate_text, TextConfig};
use emdpar::prelude::{
    BatchDistance, Distance, Embeddings, EngineBuilder, EngineParams, Histogram, LcEngine,
    Method, MethodRegistry, Metric,
};
use emdpar::util::prop::{check, ensure, Prop};
use emdpar::util::rng::Rng;

fn random_vocab(rng: &mut Rng, v: usize, m: usize) -> Embeddings {
    Embeddings::new((0..v * m).map(|_| rng.normal() as f32).collect(), v, m)
}

fn random_hist(rng: &mut Rng, v: usize, support: usize) -> Histogram {
    let idx = rng.sample_indices(v, support);
    Histogram::from_pairs(
        idx.into_iter().map(|i| (i as u32, rng.range_f64(0.05, 1.0) as f32)).collect(),
    )
    .normalized()
}

/// Overlapping pair: q shares `overlap` of p's support.
fn overlapping_pair(rng: &mut Rng, v: usize, h: usize, overlap: f64) -> (Histogram, Histogram) {
    let p = random_hist(rng, v, h);
    let n_shared = (overlap * h as f64) as usize;
    let mut pairs: Vec<(u32, f32)> = p
        .indices()
        .iter()
        .take(n_shared)
        .map(|&i| (i, rng.range_f64(0.05, 1.0) as f32))
        .collect();
    while pairs.len() < h {
        let i = rng.below(v) as u32;
        if !pairs.iter().any(|&(j, _)| j == i) {
            pairs.push((i, rng.range_f64(0.05, 1.0) as f32));
        }
    }
    (p, Histogram::from_pairs(pairs).normalized())
}

/// The chain, cheapest first, as registry lookups.
fn chain_methods() -> Vec<Method> {
    vec![
        Method::BowAdjusted,
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 4 },
        Method::Ict,
        Method::Exact,
    ]
}

#[test]
fn theorem_chain_through_registry_objects() {
    let registry = MethodRegistry::new(Metric::L2);
    let bounds: Vec<Box<dyn Distance>> =
        chain_methods().into_iter().map(|m| registry.distance(m)).collect();
    check("trait-chain", 0x7C4A1, 40, |rng| {
        let vocab = random_vocab(rng, 24, 3);
        let overlap = [0.0, 0.3, 0.7, 1.0][rng.below(4)];
        let (p, q) = overlapping_pair(rng, 24, 8, overlap);
        let vals: Vec<f64> =
            bounds.iter().map(|b| b.distance(&vocab, &p, &q).unwrap()).collect();
        for w in 0..vals.len() - 1 {
            if vals[w] > vals[w + 1] + 1e-5 {
                return Prop::Fail(format!(
                    "{} = {} > {} = {} (overlap {overlap})",
                    bounds[w].name(),
                    vals[w],
                    bounds[w + 1].name(),
                    vals[w + 1]
                ));
            }
        }
        Prop::Ok
    });
}

#[test]
fn sinkhorn_upper_bounds_exact_through_registry() {
    let registry = MethodRegistry::new(Metric::L2);
    let sinkhorn = registry.distance(Method::Sinkhorn);
    let exact = registry.distance(Method::Exact);
    check("trait-sinkhorn", 0x51AC, 15, |rng| {
        let vocab = random_vocab(rng, 12, 2);
        let p = random_hist(rng, 12, 5);
        let q = random_hist(rng, 12, 5);
        let s = sinkhorn.distance(&vocab, &p, &q).unwrap();
        let e = exact.distance(&vocab, &p, &q).unwrap();
        ensure(s >= e - 1e-5, || format!("sinkhorn {s} < emd {e}"))
    });
}

#[test]
fn batch_objects_agree_with_pair_objects() {
    // the LC engines' batched rows must match the per-pair trait objects
    // for the symmetric measures (symmetric engine mode)
    let ds = Arc::new(generate_text(&TextConfig {
        n: 14,
        classes: 3,
        vocab: 90,
        dim: 6,
        doc_len: 8,
        seed: 77,
        ..Default::default()
    }));
    let engine = Arc::new(LcEngine::new(
        Arc::clone(&ds),
        EngineParams { metric: Metric::L2, threads: 2, symmetric: true, ..Default::default() },
    ));
    let registry = MethodRegistry::new(Metric::L2);
    for method in [Method::BowAdjusted, Method::Ict, Method::Exact] {
        let batch = registry.batch(&engine, method);
        let pair = registry.distance(method);
        let q = ds.histogram(2);
        let row = batch.distances(&q).unwrap();
        assert_eq!(row.len(), ds.len());
        for u in 0..ds.len() {
            let want = pair.distance(&ds.embeddings, &ds.histogram(u), &q).unwrap() as f32;
            assert!(
                (row[u] - want).abs() < 1e-5,
                "{method} doc {u}: batch {} vs pair {want}",
                row[u]
            );
        }
    }
}

#[test]
fn dataset_scale_chain_via_batch_objects() {
    // the chain must also hold elementwise on whole all-pairs matrices
    // computed through BatchDistance objects on a generated dataset
    let ds = Arc::new(generate_text(&TextConfig {
        n: 16,
        classes: 4,
        vocab: 100,
        dim: 6,
        doc_len: 8,
        seed: 5,
        ..Default::default()
    }));
    let engine = Arc::new(LcEngine::new(
        Arc::clone(&ds),
        EngineParams { metric: Metric::L2, threads: 2, symmetric: true, ..Default::default() },
    ));
    let registry = MethodRegistry::new(Metric::L2);
    let matrices: Vec<(Method, Vec<f32>)> = chain_methods()
        .into_iter()
        .map(|m| (m, registry.batch(&engine, m).all_pairs_symmetric().unwrap()))
        .collect();
    for w in 0..matrices.len() - 1 {
        let (ma, a) = &matrices[w];
        let (mb, b) = &matrices[w + 1];
        for i in 0..a.len() {
            assert!(
                a[i] <= b[i] + 1e-4,
                "{ma} = {} > {mb} = {} at {i}",
                a[i],
                b[i]
            );
        }
    }
}

#[test]
fn builder_and_registry_compose() {
    // end-to-end: builder-constructed engine + registry lookup of the
    // comparators — the ISSUE's acceptance path
    let engine = EngineBuilder::new()
        .dataset_spec(emdpar::prelude::DatasetSpec::SynthText {
            n: 12,
            vocab: 80,
            dim: 6,
            seed: 3,
        })
        .threads(2)
        .build_lc()
        .unwrap();
    let engine = Arc::new(engine);
    let registry = engine.registry();
    for method in [Method::Sinkhorn, Method::Exact] {
        let batch = registry.batch(&engine, method);
        let row = batch.distances(&engine.dataset().histogram(0)).unwrap();
        assert_eq!(row.len(), 12, "{method}");
    }
}
