//! Batched-vs-single equivalence: the multi-query Phase-1 pipeline
//! (`LcEngine::distances_batch`, the blocked all-pairs sweep, the batched
//! `BatchDistance` entry point and the server-side grouped dispatch) must be
//! **bit-identical** to the single-query path for every LC method, every
//! plan width, every thread count and every block size — and two
//! consecutive batches through one `PlanScratch` must give identical
//! results (no state leaks through the recycled arena).

// the legacy SearchEngine shims are exercised deliberately: their
// bit-identity to the planner is part of what this suite pins down
#![allow(deprecated)]

use std::sync::Arc;

use emdpar::core::{
    BatchDistance, CompressedKind, Dataset, Histogram, Method, MethodRegistry, Metric,
};
use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::{BatchPlanner, EngineParams, KernelBackend, LcEngine, PlanParams, PlanScratch};

fn dataset(n: usize) -> Arc<Dataset> {
    Arc::new(generate_text(&TextConfig {
        n,
        classes: 3,
        vocab: 220,
        dim: 7, // odd: exercises the dot-product tail lanes
        doc_len: 18,
        seed: 42,
        ..Default::default()
    }))
}

fn engine(ds: &Arc<Dataset>, threads: usize, symmetric: bool, batch_block: usize) -> LcEngine {
    LcEngine::new(
        Arc::clone(ds),
        EngineParams { metric: Metric::L2, threads, symmetric, batch_block, ..Default::default() },
    )
}

fn lc_methods() -> Vec<Method> {
    vec![
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 1 },
        Method::Act { k: 2 },
        Method::Act { k: 4 },
        Method::Act { k: 8 },
    ]
}

/// The headline acceptance test: `distances_batch` == per-query
/// `distances`, bitwise, for every LC method × k ∈ {1,2,4,8} × thread
/// counts × block sizes, in both asymmetric and symmetric engine modes.
#[test]
fn batched_rows_bit_equal_single_query_rows() {
    let ds = dataset(30);
    let queries: Vec<Histogram> = (0..13).map(|u| ds.histogram(u)).collect();
    for symmetric in [false, true] {
        for threads in [1usize, 2, 5] {
            for batch_block in [1usize, 3, 8, 16] {
                let eng = engine(&ds, threads, symmetric, batch_block);
                for method in lc_methods() {
                    let flat = eng.distances_batch(&queries, method);
                    assert_eq!(flat.len(), queries.len() * ds.len());
                    for (i, q) in queries.iter().enumerate() {
                        let single = eng.distances(q, method);
                        let got = &flat[i * ds.len()..(i + 1) * ds.len()];
                        assert_eq!(
                            got, &single[..],
                            "{method} sym={symmetric} threads={threads} B={batch_block} q={i}"
                        );
                    }
                }
            }
        }
    }
}

/// Plan-free and per-pair methods also satisfy the batched contract
/// (row-by-row path), so every canonical method is batch-dispatchable.
#[test]
fn non_plan_methods_batch_equal_single() {
    let ds = dataset(12);
    let eng = engine(&ds, 2, true, 4);
    let queries: Vec<Histogram> = (0..5).map(|u| ds.histogram(u)).collect();
    for method in [Method::Bow, Method::Wcd, Method::BowAdjusted, Method::Ict] {
        let flat = eng.distances_batch(&queries, method);
        for (i, q) in queries.iter().enumerate() {
            let single = eng.distances(q, method);
            assert_eq!(&flat[i * ds.len()..(i + 1) * ds.len()], &single[..], "{method} q={i}");
        }
    }
}

/// The blocked all-pairs sweep must reproduce the per-query rows bitwise
/// (row u of the asymmetric matrix == `distances(histogram(u))` with an
/// asymmetric engine), across thread counts and block sizes.
#[test]
fn blocked_all_pairs_bit_equal_per_query_rows() {
    let ds = dataset(26);
    let n = ds.len();
    let reference = engine(&ds, 1, false, 1);
    for threads in [1usize, 4] {
        for batch_block in [1usize, 4, 8, 32] {
            let eng = engine(&ds, threads, false, batch_block);
            for method in [Method::Rwmd, Method::Omr, Method::Act { k: 3 }] {
                let matrix = eng.all_pairs_asymmetric(method);
                for u in 0..n {
                    let row = reference.distances(&ds.histogram(u), method);
                    assert_eq!(
                        &matrix[u * n..(u + 1) * n],
                        &row[..],
                        "{method} threads={threads} B={batch_block} row={u}"
                    );
                }
            }
        }
    }
}

/// The parallel O(n²) symmetrization must agree with a serial max-mirror.
#[test]
fn parallel_symmetrization_matches_serial() {
    let ds = dataset(24);
    let n = ds.len();
    for method in [Method::Rwmd, Method::Act { k: 2 }] {
        let eng = engine(&ds, 4, false, 8);
        let asym = eng.all_pairs_asymmetric(method);
        let sym = eng.all_pairs_symmetric(method);
        for u in 0..n {
            for v in 0..n {
                let want = asym[u * n + v].max(asym[v * n + u]);
                assert_eq!(sym[u * n + v], want, "{method} ({u},{v})");
            }
        }
    }
}

/// Two consecutive batches through ONE `PlanScratch` give identical results
/// to fresh-scratch planning — the recycled arena leaks no state.
#[test]
fn scratch_reuse_across_batches_is_identical() {
    let ds = dataset(20);
    let vn = ds.embeddings.row_sq_norms();
    let planner = BatchPlanner::new(&ds.embeddings, &vn);
    let params = PlanParams { k: 3, metric: Metric::L2, keep_d: true, threads: 2, kernel: None };
    let batch_a: Vec<Histogram> = (0..6).map(|u| ds.histogram(u)).collect();
    let batch_b: Vec<Histogram> = (6..14).map(|u| ds.histogram(u)).collect();

    // fresh scratch per batch = reference
    let want_a = planner.plan_block(&batch_a, params, &mut PlanScratch::new());
    let want_b = planner.plan_block(&batch_b, params, &mut PlanScratch::new());

    // one scratch across both batches
    let mut shared = PlanScratch::new();
    let mut got_a = planner.plan_block(&batch_a, params, &mut shared);
    for (g, w) in got_a.iter().zip(&want_a) {
        assert_eq!((g.k, g.h), (w.k, w.h));
        assert_eq!(g.qw, w.qw);
        assert_eq!(g.z, w.z);
        assert_eq!(g.s, w.s);
        assert_eq!(g.w, w.w);
        assert_eq!(g.d, w.d);
    }
    shared.recycle(&mut got_a);
    let got_b = planner.plan_block(&batch_b, params, &mut shared);
    for (g, w) in got_b.iter().zip(&want_b) {
        assert_eq!((g.k, g.h), (w.k, w.h));
        assert_eq!(g.qw, w.qw);
        assert_eq!(g.z, w.z);
        assert_eq!(g.s, w.s);
        assert_eq!(g.w, w.w);
        assert_eq!(g.d, w.d);
    }
}

/// The `BatchDistance` trait's multi-query entry point: the LC override and
/// the default row-by-row implementation agree for every canonical method.
#[test]
fn trait_distances_batch_matches_per_query() {
    let ds = dataset(14);
    let eng = Arc::new(engine(&ds, 2, true, 4));
    let registry = MethodRegistry::new(Metric::L2);
    let queries: Vec<Histogram> = (0..6).map(|u| ds.histogram(u)).collect();
    for method in [Method::Rwmd, Method::Act { k: 2 }, Method::Bow, Method::Sinkhorn] {
        let batch = registry.batch(&eng, method);
        let flat = batch.distances_batch(&queries).unwrap();
        assert_eq!(flat.len(), queries.len() * ds.len());
        for (i, q) in queries.iter().enumerate() {
            let single = batch.distances(q).unwrap();
            assert_eq!(&flat[i * ds.len()..(i + 1) * ds.len()], &single[..], "{method} q={i}");
        }
    }
}

/// ISSUE 7 acceptance: every SIMD kernel backend this host supports
/// produces Phase-1 plans (and full batched distance rows) bit-identical to
/// the scalar reference.  The scalar backend defines the crate's canonical
/// arithmetic; AVX2/AVX-512 must reproduce it exactly, so forcing a backend
/// can only ever change speed.
#[test]
fn every_supported_kernel_backend_is_bit_identical_to_scalar() {
    let ds = dataset(24);
    let vn = ds.embeddings.row_sq_norms();
    let planner = BatchPlanner::new(&ds.embeddings, &vn);
    let queries: Vec<Histogram> = (0..9).map(|u| ds.histogram(u)).collect();
    let backends = emdpar::lc::kernels::supported_backends();
    assert!(backends.contains(&KernelBackend::Scalar));
    for k in [1usize, 3, 8] {
        let reference = planner.plan_block(
            &queries,
            PlanParams {
                k,
                metric: Metric::L2,
                keep_d: true,
                threads: 2,
                kernel: Some(KernelBackend::Scalar),
            },
            &mut PlanScratch::new(),
        );
        for &backend in &backends {
            let got = planner.plan_block(
                &queries,
                PlanParams {
                    k,
                    metric: Metric::L2,
                    keep_d: true,
                    threads: 2,
                    kernel: Some(backend),
                },
                &mut PlanScratch::new(),
            );
            for (g, w) in got.iter().zip(&reference) {
                assert_eq!((g.k, g.h), (w.k, w.h), "{backend} k={k}");
                assert_eq!(g.z, w.z, "{backend} k={k}");
                assert_eq!(g.s, w.s, "{backend} k={k}");
                assert_eq!(g.w, w.w, "{backend} k={k}");
                assert_eq!(g.d, w.d, "{backend} k={k}");
            }
        }
    }
    // end-to-end rows through a forced-backend engine agree bitwise too
    let scalar_eng = LcEngine::new(
        Arc::clone(&ds),
        EngineParams {
            threads: 2,
            kernel: Some(KernelBackend::Scalar),
            ..Default::default()
        },
    );
    for &backend in &backends {
        let eng = LcEngine::new(
            Arc::clone(&ds),
            EngineParams { threads: 2, kernel: Some(backend), ..Default::default() },
        );
        for method in [Method::Rwmd, Method::Act { k: 2 }] {
            assert_eq!(
                eng.distances_batch(&queries, method),
                scalar_eng.distances_batch(&queries, method),
                "{backend} {method}"
            );
        }
    }
}

/// ISSUE 7 acceptance: a full-probe search through the f16 compressed
/// stage-1 tier returns exactly the f32 exhaustive top-ℓ — the planner's
/// exact rerank restores bit-identity end to end.
#[test]
fn compressed_tier_full_probe_search_bit_equals_f32_exhaustive() {
    use emdpar::config::{Config, DatasetSpec, IndexParams};
    use emdpar::coordinator::{CascadeSpec, SearchEngine, SearchRequest, Stage};
    let base = Config {
        dataset: DatasetSpec::SynthText { n: 48, vocab: 200, dim: 9, seed: 7 },
        threads: 2,
        // keep = overfetch·ℓ covers the whole 48-doc corpus: the exact
        // rerank then provably restores the uncompressed ranking bitwise
        overfetch: 16,
        index: Some(IndexParams {
            nlist: 4,
            nprobe: 4, // full probe
            train_iters: 6,
            seed: 5,
            min_points_per_list: 1,
        }),
        ..Default::default()
    };
    let exact = SearchEngine::from_config(base.clone()).unwrap();
    let tiered = SearchEngine::from_config(Config {
        compressed: CompressedKind::F16,
        ..base
    })
    .unwrap();
    let queries: Vec<Histogram> = (0..5).map(|u| exact.dataset().histogram(u * 9)).collect();
    for method in [Method::Rwmd, Method::Omr, Method::Act { k: 2 }] {
        let req = SearchRequest::batch(queries.clone()).method(method).topl(5);
        let plan = tiered.plan(&req).unwrap();
        assert!(plan.compressed, "{method}");
        assert!(
            plan.stages.iter().any(|s| matches!(s, Stage::ExactRerank { .. })),
            "{method}"
        );
        let want = exact.execute(&req).unwrap();
        let got = tiered.execute(&req).unwrap();
        for (g, w) in got.results.iter().zip(&want.results) {
            assert_eq!(g.hits, w.hits, "{method}");
            assert_eq!(g.labels, w.labels, "{method}");
        }
    }
    // cascaded variant: same hits, but the compressed stage 1 surrenders
    // the exactness certificate (f16 scores are not lower bounds)
    let creq = SearchRequest::batch(queries)
        .topl(5)
        .cascade(CascadeSpec::new(Method::Exact).overfetch(16));
    let want = exact.execute(&creq).unwrap();
    let got = tiered.execute(&creq).unwrap();
    for (g, w) in got.results.iter().zip(&want.results) {
        assert_eq!(g.hits, w.hits);
    }
    assert!(want.stats.certified.iter().all(|&c| c));
    assert!(got.stats.certified.iter().all(|&c| !c));
}

/// End-to-end: the coordinator's batched search returns the same hits as
/// per-query search (the server's grouped dispatch rides on this).
#[test]
fn search_batch_matches_single_search() {
    use emdpar::config::{Config, DatasetSpec};
    use emdpar::coordinator::SearchEngine;
    let config = Config {
        dataset: DatasetSpec::SynthText { n: 32, vocab: 180, dim: 8, seed: 11 },
        threads: 2,
        shards: 3,
        batch_block: 4,
        ..Default::default()
    };
    let eng = SearchEngine::from_config(config).unwrap();
    let queries: Vec<Histogram> = (0..7).map(|u| eng.dataset().histogram(u)).collect();
    for method in [Method::Rwmd, Method::Act { k: 2 }] {
        let batched = eng.search_batch(&queries, method, 5).unwrap();
        assert_eq!(batched.len(), queries.len());
        for (q, res) in queries.iter().zip(&batched) {
            let single = eng.search(q, method, 5).unwrap();
            assert_eq!(res.hits, single.hits, "{method}");
            assert_eq!(res.labels, single.labels, "{method}");
        }
    }
}
