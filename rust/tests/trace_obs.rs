//! Observability acceptance (ISSUE 8): end-to-end query tracing.
//!
//! * traced requests return a span timeline whose tree **nests**: every
//!   child lies inside its parent's window, top-level stages are laid out
//!   in order, and the per-stage spans sum within the end-to-end envelope
//!   — across {plain, indexed, sharded, indexed+sharded} × {base, cascade};
//! * tracing is **bit-identity neutral**: hits/labels/certificates match
//!   exactly with tracing on vs off;
//! * `QueryStats` carries per-stage microseconds on every request (traced
//!   or not);
//! * the `trace` request field round-trips the wire and stays absent from
//!   untraced request JSON (byte-compat);
//! * the ring collector survives wraparound with accurate drop counts;
//! * a slow-query threshold arms ambient collection without touching the
//!   response;
//! * the Prometheus exposition of a live engine passes a format lint.

use std::collections::BTreeMap;
use std::sync::Arc;

use emdpar::config::{Config, DatasetSpec, IndexParams, ServeParams, ShardParams};
use emdpar::coordinator::{CascadeSpec, SearchEngine, SearchRequest};
use emdpar::core::{Dataset, Method};
use emdpar::obs::{SpanRec, TraceCollector};
use emdpar::util::json::Json;

fn dataset() -> Arc<Dataset> {
    Arc::new(
        Config {
            dataset: DatasetSpec::SynthText { n: 60, vocab: 240, dim: 10, seed: 33 },
            ..Config::default()
        }
        .load_dataset()
        .unwrap(),
    )
}

fn engine(ds: &Arc<Dataset>, index: bool, shards: Option<usize>) -> SearchEngine {
    SearchEngine::with_dataset(
        Config {
            threads: 2,
            index: index.then(|| IndexParams {
                nlist: 5,
                nprobe: 2,
                train_iters: 6,
                seed: 4,
                min_points_per_list: 1,
            }),
            sharded: shards.map(|s| ShardParams { shards: s, max_docs_per_shard: 1 << 20 }),
            ..Config::default()
        },
        Arc::clone(ds),
    )
    .unwrap()
}

/// Structural invariants of one returned timeline: a single root at id 1
/// covering [0, dur], every other span parented to an existing span and
/// contained in its parent's window.
fn check_nesting(spans: &[SpanRec], tag: &str) {
    assert!(!spans.is_empty(), "{tag}: empty timeline");
    let root = &spans[0];
    assert_eq!(root.span_id, 1, "{tag}: root id");
    assert_eq!(root.parent_id, 0, "{tag}: root parent");
    assert_eq!(root.name_str(), "request", "{tag}: root name");
    assert_eq!(root.start_us, 0, "{tag}: root starts the session clock");
    let by_id: BTreeMap<u16, &SpanRec> = spans.iter().map(|s| (s.span_id, s)).collect();
    assert_eq!(by_id.len(), spans.len(), "{tag}: span ids unique");
    let mut prev_top_start = 0u64;
    for s in &spans[1..] {
        let parent = by_id
            .get(&s.parent_id)
            .unwrap_or_else(|| panic!("{tag}: span {} orphaned (parent {})", s.span_id, s.parent_id));
        assert!(
            s.start_us >= parent.start_us,
            "{tag}: {} starts before its parent {}",
            s.name_str(),
            parent.name_str()
        );
        assert!(
            s.start_us + s.dur_us <= parent.start_us + parent.dur_us,
            "{tag}: {} [{}, +{}] escapes parent {} [{}, +{}]",
            s.name_str(),
            s.start_us,
            s.dur_us,
            parent.name_str(),
            parent.start_us,
            parent.dur_us
        );
        if s.parent_id == 1 {
            assert!(s.start_us >= prev_top_start, "{tag}: top-level stages out of order");
            prev_top_start = s.start_us;
        }
    }
}

fn names(spans: &[SpanRec]) -> Vec<&'static str> {
    spans.iter().map(SpanRec::name_str).collect()
}

#[test]
fn traced_requests_return_nested_ordered_timelines() {
    let ds = dataset();
    let shapes: [(&str, SearchEngine); 4] = [
        ("plain", engine(&ds, false, None)),
        ("indexed", engine(&ds, true, None)),
        ("sharded", engine(&ds, false, Some(3))),
        ("indexed+sharded", engine(&ds, true, Some(3))),
    ];
    for (tag, eng) in &shapes {
        let req = SearchRequest::query(ds.histogram(7))
            .method(Method::Rwmd)
            .topl(4)
            .trace(true);
        let resp = eng.execute(&req).unwrap();
        let spans = resp.spans.as_deref().expect("traced request returns spans");
        check_nesting(spans, tag);
        let ns = names(spans);
        if tag.contains("sharded") {
            assert!(ns.contains(&"shard_fanout"), "{tag}: {ns:?}");
            assert!(ns.contains(&"merge"), "{tag}: {ns:?}");
            // one child lane per shard, tid = shard index
            let fan = spans.iter().find(|s| s.name_str() == "shard_fanout").unwrap();
            let lanes: Vec<u16> = spans
                .iter()
                .filter(|s| s.parent_id == fan.span_id)
                .map(|s| s.tid)
                .collect();
            assert_eq!(lanes, vec![0, 1, 2], "{tag}: shard lanes");
        } else {
            assert!(ns.contains(&"score"), "{tag}: {ns:?}");
        }
        if tag.contains("indexed") && !tag.contains("sharded") {
            assert!(ns.contains(&"prune"), "{tag}: {ns:?}");
        }
        // the ring got the same spans (epoch-relative)
        assert!(eng.tracer().total() >= spans.len() as u64, "{tag}: ring flushed");
    }
}

#[test]
fn sharded_cascade_spans_sum_within_the_e2e_envelope() {
    // the acceptance shape: sharded + indexed engine, certified cascade
    let ds = dataset();
    let eng = engine(&ds, true, Some(3));
    let req = SearchRequest::query(ds.histogram(5))
        .topl(4)
        .cascade(CascadeSpec::new(Method::Exact).overfetch(ds.len()).certified(true))
        .trace(true);
    let resp = eng.execute(&req).unwrap();
    let spans = resp.spans.as_deref().unwrap();
    check_nesting(spans, "sharded cascade");
    let ns = names(spans);
    assert!(ns.contains(&"cascade_rerank"), "{ns:?}");
    assert!(ns.contains(&"shard_fanout"), "{ns:?}");
    // per-stage spans sum within the end-to-end envelope: the root covers
    // every top-level stage, and the engine's total covers the stage stats
    let root_dur = spans[0].dur_us;
    let stage_sum: u64 =
        spans.iter().filter(|s| s.parent_id == 1).map(|s| s.dur_us).sum();
    assert!(
        stage_sum <= root_dur,
        "stage sum {stage_sum}us exceeds the {root_dur}us request envelope"
    );
    assert!(root_dur >= resp.stats.total_us, "root covers the executed plan");
    let stats_sum = resp.stats.prune_us
        + resp.stats.score_us
        + resp.stats.fanout_us
        + resp.stats.merge_us
        + resp.stats.rerank_us;
    assert!(
        stats_sum <= resp.stats.total_us,
        "stage stats {stats_sum}us exceed total {}us",
        resp.stats.total_us
    );
    assert!(resp.stats.total_us > 0, "an exact-rerank cascade takes measurable time");
    assert!(resp.stats.certified[0], "tracing must not break certification");
}

#[test]
fn tracing_is_bit_identity_neutral() {
    let ds = dataset();
    for (tag, eng) in [
        ("plain", engine(&ds, false, None)),
        ("indexed+sharded", engine(&ds, true, Some(3))),
    ] {
        for method in [Method::Rwmd, Method::Act { k: 2 }] {
            let base = SearchRequest::query(ds.histogram(11)).method(method).topl(5);
            let off = eng.execute(&base.clone().trace(false)).unwrap();
            let on = eng.execute(&base.trace(true)).unwrap();
            assert_eq!(off.results[0].hits, on.results[0].hits, "{tag} {method}");
            assert_eq!(off.results[0].labels, on.results[0].labels, "{tag} {method}");
            assert!(off.spans.is_none() && on.spans.is_some(), "{tag} {method}");
        }
        // and through a certified cascade
        let base = SearchRequest::query(ds.histogram(2))
            .topl(3)
            .cascade(CascadeSpec::new(Method::Ict).overfetch(ds.len()).certified(true));
        let off = eng.execute(&base.clone()).unwrap();
        let on = eng.execute(&base.trace(true)).unwrap();
        assert_eq!(off.results[0].hits, on.results[0].hits, "{tag} cascade");
        assert_eq!(off.stats.certified, on.stats.certified, "{tag} cascade");
    }
}

#[test]
fn query_stats_carry_stage_micros_without_tracing() {
    let ds = dataset();
    let eng = engine(&ds, true, Some(3));
    let resp = eng
        .execute(&SearchRequest::query(ds.histogram(0)).method(Method::Rwmd).topl(4))
        .unwrap();
    assert!(resp.spans.is_none(), "untraced request");
    // the sharded route fills fanout/merge; every route fills total
    assert!(resp.stats.total_us >= resp.stats.fanout_us);
    assert!(
        resp.stats.fanout_us + resp.stats.merge_us <= resp.stats.total_us,
        "stage micros fit inside the total"
    );
    // the pruned (non-sharded) route fills prune/score instead
    let eng = engine(&ds, true, None);
    let resp = eng
        .execute(&SearchRequest::query(ds.histogram(0)).method(Method::Rwmd).topl(4))
        .unwrap();
    assert!(resp.stats.prune_us + resp.stats.score_us <= resp.stats.total_us);
}

#[test]
fn trace_flag_round_trips_the_wire_and_stays_absent_when_off() {
    let req = SearchRequest::query(emdpar::core::Histogram::from_pairs(vec![(1, 1.0)]))
        .topl(3)
        .trace(true);
    let wire = req.to_json().to_string_compact();
    assert!(wire.contains("\"trace\":true"), "{wire}");
    let back = SearchRequest::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, req, "traced request round-trips");
    // untraced requests serialize exactly as before the field existed
    let req = SearchRequest::query(emdpar::core::Histogram::from_pairs(vec![(1, 1.0)])).topl(3);
    let wire = req.to_json().to_string_compact();
    assert!(!wire.contains("trace"), "byte-compat broken: {wire}");
    let back = SearchRequest::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert!(!back.trace, "absent means off");
}

#[test]
fn ring_wraparound_keeps_newest_and_counts_drops() {
    let col = TraceCollector::new(16);
    col.set_enabled(true);
    for i in 0..40u64 {
        col.push(SpanRec {
            trace_id: i,
            span_id: 1,
            parent_id: 0,
            name: 0,
            tid: 0,
            start_us: i,
            dur_us: 1,
        });
    }
    let snap = col.snapshot();
    assert_eq!(snap.total, 40);
    assert_eq!(snap.dropped, 24, "40 pushed into 16 slots");
    assert_eq!(snap.spans.len(), 16);
    let starts: Vec<u64> = snap.spans.iter().map(|s| s.start_us).collect();
    assert_eq!(starts, (24..40).collect::<Vec<u64>>(), "oldest overwritten, newest kept");
}

#[test]
fn slow_query_threshold_arms_ambient_collection() {
    // a 1µs threshold marks every query slow: spans land in the ring even
    // though the response carries none
    let ds = dataset();
    let eng = SearchEngine::with_dataset(
        Config {
            threads: 2,
            serve: ServeParams { slow_query_us: 1, ..Default::default() },
            ..Config::default()
        },
        Arc::clone(&ds),
    )
    .unwrap();
    assert!(eng.tracer().enabled(), "configured threshold arms the collector at build");
    let resp = eng
        .execute(&SearchRequest::query(ds.histogram(3)).method(Method::Rwmd).topl(3))
        .unwrap();
    assert!(resp.spans.is_none(), "slow-query logging never leaks into responses");
    assert!(eng.tracer().total() >= 1, "the slow query's spans reached the ring");
}

#[test]
fn live_engine_prometheus_exposition_passes_a_format_lint() {
    let ds = dataset();
    let eng = engine(&ds, true, Some(2));
    eng.execute(&SearchRequest::query(ds.histogram(1)).topl(3).trace(true)).unwrap();
    let text = emdpar::obs::prom::render(&eng.metrics(), Some(eng.tracer()));
    // exposition-format grammar: every line is `# HELP|TYPE ...` or
    // `name[{labels}] value` with a conforming metric name
    for (ln, line) in text.lines().enumerate() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("line {}: {line:?}", ln + 1));
        value.parse::<f64>().unwrap_or_else(|_| panic!("line {}: bad value {value:?}", ln + 1));
        let base = series.split_once('{').map_or(series, |(b, _)| b);
        assert!(
            !base.is_empty()
                && base
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "line {}: bad metric name {base:?}",
            ln + 1
        );
    }
    assert!(text.contains("emdpar_queries_total 1"), "{text}");
    assert!(text.contains("emdpar_trace_spans_total"), "{text}");
    assert!(text.contains("emdpar_e2e_us_bucket{le=\"+Inf\"}"), "{text}");
}
