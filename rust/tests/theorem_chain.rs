//! Integration: the paper's theorem properties across whole modules —
//! Theorem 2 chain (RWMD ≤ OMR ≤ ACT-k ≤ ICT ≤ EMD), Theorem 1 (ICT is the
//! relaxed optimum), Theorem 3 (OMR effectiveness), and the Sinkhorn / WMD
//! comparator relationships — exercised through the public API on random
//! histogram pairs via the in-repo property-test framework.

use emdpar::approx::{
    act_symmetric, ict_directed, ict_symmetric, omr_symmetric, rwmd_symmetric, sinkhorn,
    SinkhornParams,
};
use emdpar::core::{Embeddings, Histogram, Metric};
use emdpar::exact::emd;
use emdpar::util::prop::{check, ensure, Prop};
use emdpar::util::rng::Rng;

fn random_vocab(rng: &mut Rng, v: usize, m: usize) -> Embeddings {
    Embeddings::new((0..v * m).map(|_| rng.normal() as f32).collect(), v, m)
}

fn random_hist(rng: &mut Rng, v: usize, support: usize) -> Histogram {
    let idx = rng.sample_indices(v, support);
    Histogram::from_pairs(
        idx.into_iter().map(|i| (i as u32, rng.range_f64(0.05, 1.0) as f32)).collect(),
    )
    .normalized()
}

/// Overlapping pair: q shares `overlap` of p's support.
fn overlapping_pair(rng: &mut Rng, v: usize, h: usize, overlap: f64) -> (Histogram, Histogram) {
    let p = random_hist(rng, v, h);
    let n_shared = (overlap * h as f64) as usize;
    let mut pairs: Vec<(u32, f32)> = p
        .indices()
        .iter()
        .take(n_shared)
        .map(|&i| (i, rng.range_f64(0.05, 1.0) as f32))
        .collect();
    while pairs.len() < h {
        let i = rng.below(v) as u32;
        if !pairs.iter().any(|&(j, _)| j == i) {
            pairs.push((i, rng.range_f64(0.05, 1.0) as f32));
        }
    }
    (p, Histogram::from_pairs(pairs).normalized())
}

#[test]
fn theorem2_chain_holds_everywhere() {
    check("thm2-chain", 0xE3D, 40, |rng| {
        let vocab = random_vocab(rng, 24, 3);
        let overlap = [0.0, 0.3, 0.7, 1.0][rng.below(4)];
        let (p, q) = overlapping_pair(rng, 24, 8, overlap);
        let rwmd = rwmd_symmetric(&vocab, &p, &q, Metric::L2);
        let omr = omr_symmetric(&vocab, &p, &q, Metric::L2);
        let act2 = act_symmetric(&vocab, &p, &q, Metric::L2, 2);
        let act4 = act_symmetric(&vocab, &p, &q, Metric::L2, 4);
        let ict = ict_symmetric(&vocab, &p, &q, Metric::L2);
        let ex = emd(&vocab, &p, &q, Metric::L2);
        let eps = 1e-6;
        if rwmd > omr + eps {
            return Prop::Fail(format!("RWMD {rwmd} > OMR {omr}"));
        }
        if omr > act2 + eps {
            return Prop::Fail(format!("OMR {omr} > ACT-1 {act2}"));
        }
        if act2 > act4 + eps {
            return Prop::Fail(format!("ACT-1 {act2} > ACT-3 {act4}"));
        }
        if act4 > ict + eps {
            return Prop::Fail(format!("ACT-3 {act4} > ICT {ict}"));
        }
        ensure(ict <= ex + 1e-5, || format!("ICT {ict} > EMD {ex}"))
    });
}

#[test]
fn theorem3_omr_is_effective_rwmd_is_not() {
    check("thm3-effective", 77, 30, |rng| {
        let vocab = random_vocab(rng, 16, 3);
        // full overlap, different weights (Fig. 3)
        let (p, q) = overlapping_pair(rng, 16, 6, 1.0);
        if p.weights() == q.weights() {
            return Prop::Discard;
        }
        let rwmd = rwmd_symmetric(&vocab, &p, &q, Metric::L2);
        let omr = omr_symmetric(&vocab, &p, &q, Metric::L2);
        if rwmd != 0.0 {
            return Prop::Fail(format!("RWMD should be blind, got {rwmd}"));
        }
        ensure(omr > 0.0, || "OMR failed to separate distinct histograms".to_string())
    });
}

#[test]
fn ict_is_exact_on_nested_singletons() {
    // One-bin vs one-bin: every bound equals the ground distance.
    let mut rng = Rng::new(5);
    let vocab = random_vocab(&mut rng, 8, 2);
    let p = Histogram::from_pairs(vec![(0, 1.0)]);
    let q = Histogram::from_pairs(vec![(3, 1.0)]);
    let d = Metric::L2.distance(vocab.row(0), vocab.row(3)) as f64;
    assert!((ict_directed(&vocab, &p, &q, Metric::L2) - d).abs() < 1e-6);
    assert!((emd(&vocab, &p, &q, Metric::L2) - d).abs() < 1e-6);
}

#[test]
fn sinkhorn_upper_bounds_emd_and_tightens() {
    check("sinkhorn-vs-emd", 13, 15, |rng| {
        let vocab = random_vocab(rng, 12, 2);
        let p = random_hist(rng, 12, 5);
        let q = random_hist(rng, 12, 5);
        let ex = emd(&vocab, &p, &q, Metric::L2);
        let loose = sinkhorn(
            &vocab, &p, &q, Metric::L2,
            SinkhornParams { lambda: 20.0, max_iters: 1000, tol: 1e-9 },
        );
        ensure(loose >= ex - 1e-5, || format!("sinkhorn {loose} < emd {ex}"))
    });
}

#[test]
fn lc_engine_chain_on_dataset_scale() {
    // The same chain must hold for the batched engines on a real dataset.
    use emdpar::data::{generate_mnist, MnistConfig};
    use emdpar::lc::{EngineParams, LcEngine, Method};
    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n: 60, side: 14, ..Default::default() }));
    let eng = LcEngine::new(std::sync::Arc::clone(&ds), EngineParams { threads: 2, ..Default::default() });
    let r = eng.all_pairs_symmetric(Method::Rwmd);
    let o = eng.all_pairs_symmetric(Method::Omr);
    let a1 = eng.all_pairs_symmetric(Method::Act { k: 2 });
    let a7 = eng.all_pairs_symmetric(Method::Act { k: 8 });
    for i in 0..r.len() {
        assert!(r[i] <= o[i] + 1e-5, "RWMD > OMR at {i}");
        assert!(o[i] <= a1[i] + 1e-5, "OMR > ACT-1 at {i}");
        assert!(a1[i] <= a7[i] + 1e-5, "ACT-1 > ACT-7 at {i}");
    }
}
