//! Ground-distance computation between embedded coordinates.
//!
//! The paper's cost is the Euclidean (L2) distance between embedding
//! vectors; L1, squared-L2 and cosine are provided for ablations.  The LC
//! engines never materialize an `h x h` cost matrix — costs are computed
//! on the fly against the vocabulary — but the per-pair solvers (exact EMD,
//! Sinkhorn, Algorithms 1-3) use [`cost_matrix`].

use super::vocab::Embeddings;

/// Ground metric between embedding vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Euclidean distance (the paper's choice for both datasets).
    L2,
    /// Squared Euclidean (2-Wasserstein-style costs).
    SqL2,
    /// Manhattan distance.
    L1,
    /// Cosine distance `1 - cos(a, b)` (assumes non-degenerate vectors).
    Cosine,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "l2" | "euclidean" => Some(Metric::L2),
            "sql2" | "sqeuclidean" => Some(Metric::SqL2),
            "l1" | "manhattan" => Some(Metric::L1),
            "cosine" => Some(Metric::Cosine),
            _ => None,
        }
    }

    /// Distance between two vectors of equal dimension.
    #[inline]
    pub fn distance(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L2 => sq_l2(a, b).sqrt(),
            Metric::SqL2 => sq_l2(a, b),
            Metric::L1 => a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).sum(),
            Metric::Cosine => {
                let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
                for (&x, &y) in a.iter().zip(b) {
                    dot += x as f64 * y as f64;
                    na += x as f64 * x as f64;
                    nb += y as f64 * y as f64;
                }
                let denom = (na.sqrt() * nb.sqrt()).max(1e-30);
                (1.0 - dot / denom).max(0.0) as f32
            }
        }
    }
}

#[inline]
fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Dense row-major `(hp, hq)` cost matrix between two coordinate sets
/// (the `C` of paper eq. (1)).
pub fn cost_matrix(p_coords: &Embeddings, q_coords: &Embeddings, metric: Metric) -> Vec<f32> {
    let hp = p_coords.num_vectors();
    let hq = q_coords.num_vectors();
    let mut out = vec![0.0f32; hp * hq];
    for i in 0..hp {
        let a = p_coords.row(i);
        let row = &mut out[i * hq..(i + 1) * hq];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = metric.distance(a, q_coords.row(j));
        }
    }
    out
}

/// Cost matrix between two histograms' support coordinates drawn from a
/// shared vocabulary.  Coordinates with equal vocabulary index get an exact
/// 0 (the overlap OMR/ICT key off), regardless of fp rounding.
pub fn support_cost_matrix(
    vocab: &Embeddings,
    p_support: &[u32],
    q_support: &[u32],
    metric: Metric,
) -> Vec<f32> {
    let hp = p_support.len();
    let hq = q_support.len();
    let mut out = vec![0.0f32; hp * hq];
    for (i, &pi) in p_support.iter().enumerate() {
        let a = vocab.row(pi as usize);
        let row = &mut out[i * hq..(i + 1) * hq];
        for (j, &qj) in q_support.iter().enumerate() {
            row[j] = if pi == qj { 0.0 } else { metric.distance(a, vocab.row(qj as usize)) };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_matches_hand_computation() {
        assert!((Metric::L2.distance(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(Metric::SqL2.distance(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(Metric::L1.distance(&[0.0, 0.0], &[3.0, 4.0]), 7.0);
    }

    #[test]
    fn cosine_bounds() {
        let d_same = Metric::Cosine.distance(&[1.0, 0.0], &[2.0, 0.0]);
        let d_orth = Metric::Cosine.distance(&[1.0, 0.0], &[0.0, 1.0]);
        let d_opp = Metric::Cosine.distance(&[1.0, 0.0], &[-1.0, 0.0]);
        assert!(d_same.abs() < 1e-6);
        assert!((d_orth - 1.0).abs() < 1e-6);
        assert!((d_opp - 2.0).abs() < 1e-6);
    }

    #[test]
    fn parse_metric_names() {
        assert_eq!(Metric::parse("L2"), Some(Metric::L2));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Cosine));
        assert_eq!(Metric::parse("nope"), None);
    }

    #[test]
    fn cost_matrix_shape_and_values() {
        let p = Embeddings::new(vec![0.0, 0.0, 1.0, 0.0], 2, 2);
        let q = Embeddings::new(vec![0.0, 1.0], 1, 2);
        let c = cost_matrix(&p, &q, Metric::L2);
        assert_eq!(c.len(), 2);
        assert!((c[0] - 1.0).abs() < 1e-6);
        assert!((c[1] - (2.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn support_cost_exact_zero_on_shared_index() {
        let vocab = Embeddings::new(vec![0.1, 0.2, 0.3, 0.4], 2, 2);
        let c = support_cost_matrix(&vocab, &[0, 1], &[1, 0], Metric::L2);
        // (i=0 -> q index 1): nonzero; (i=0 -> q index 0... wait supports are
        // vocabulary ids: p=[0,1], q=[1,0] -> C[0][1] = 0 (both id 0)
        assert!(c[0] > 0.0);
        assert_eq!(c[1], 0.0);
        assert_eq!(c[2], 0.0);
        assert!(c[3] > 0.0);
    }
}
