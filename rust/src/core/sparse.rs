//! Compressed-sparse-row database matrix **X** (paper Fig. 7): one row per
//! database histogram over the vocabulary.

use super::histogram::Histogram;

/// CSR matrix of non-negative f32 weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f32>,
    ncols: usize,
}

impl CsrMatrix {
    /// Assemble from histograms; every histogram must fit in `ncols`.
    pub fn from_histograms(rows: &[Histogram], ncols: usize) -> CsrMatrix {
        let nnz: usize = rows.iter().map(|h| h.len()).sum();
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        indptr.push(0);
        for h in rows {
            assert!(h.min_vocab_size() <= ncols, "histogram index out of vocabulary");
            indices.extend_from_slice(h.indices());
            data.extend_from_slice(h.weights());
            indptr.push(indices.len());
        }
        CsrMatrix { indptr, indices, data, ncols }
    }

    /// Assemble from raw CSR arrays (validated); used by the binary loader.
    pub fn from_raw(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f32>,
        ncols: usize,
    ) -> CsrMatrix {
        assert!(!indptr.is_empty() && indptr[0] == 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr/indices mismatch");
        assert_eq!(indices.len(), data.len(), "indices/data mismatch");
        assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr must be monotone");
        assert!(indices.iter().all(|&i| (i as usize) < ncols), "column index out of range");
        CsrMatrix { indptr, indices, data, ncols }
    }

    pub fn nrows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Average nonzeros per row — the paper's average histogram size h̄.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.nrows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.nrows() as f64
        }
    }

    #[inline]
    pub fn row(&self, u: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[u], self.indptr[u + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    pub fn row_histogram(&self, u: usize) -> Histogram {
        let (idx, w) = self.row(u);
        Histogram::from_pairs(idx.iter().copied().zip(w.iter().copied()).collect())
    }

    /// Scatter rows `[start, end)` into a dense row-major `(end-start, ncols)`
    /// tile, zero-padding missing rows beyond `nrows` (artifact tiling).
    pub fn to_dense_tile(&self, start: usize, end: usize, out: &mut [f32]) {
        let rows = end - start;
        assert_eq!(out.len(), rows * self.ncols);
        out.fill(0.0);
        for (r, u) in (start..end.min(self.nrows())).enumerate() {
            let (idx, w) = self.row(u);
            let row_out = &mut out[r * self.ncols..(r + 1) * self.ncols];
            for (&i, &x) in idx.iter().zip(w) {
                row_out[i as usize] = x;
            }
        }
        let _ = rows;
    }

    /// L2 norm of each row (for BoW cosine).
    pub fn row_l2_norms(&self) -> Vec<f32> {
        (0..self.nrows())
            .map(|u| {
                let (_, w) = self.row(u);
                (w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()) as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        let rows = vec![
            Histogram::from_pairs(vec![(0, 1.0), (2, 2.0)]),
            Histogram::from_pairs(vec![]),
            Histogram::from_pairs(vec![(3, 0.5)]),
        ];
        CsrMatrix::from_histograms(&rows, 4)
    }

    #[test]
    fn shape_and_nnz() {
        let m = sample();
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.ncols(), 4);
        assert_eq!(m.nnz(), 3);
        assert!((m.avg_row_nnz() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn row_access() {
        let m = sample();
        let (idx, w) = m.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(w, &[1.0, 2.0]);
        let (idx, _) = m.row(1);
        assert!(idx.is_empty());
    }

    #[test]
    fn dense_tile_with_padding() {
        let m = sample();
        let mut tile = vec![9.0; 2 * 4];
        m.to_dense_tile(2, 4, &mut tile); // row 3 is past the end -> zeros
        assert_eq!(tile, vec![0.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn histogram_roundtrip() {
        let m = sample();
        assert_eq!(m.row_histogram(0).indices(), &[0, 2]);
    }

    #[test]
    #[should_panic(expected = "out of vocabulary")]
    fn oversized_index_panics() {
        let rows = vec![Histogram::from_pairs(vec![(10, 1.0)])];
        CsrMatrix::from_histograms(&rows, 4);
    }

    #[test]
    fn l2_norms() {
        let m = sample();
        let n = m.row_l2_norms();
        assert!((n[0] - (5.0f32).sqrt()).abs() < 1e-6);
        assert_eq!(n[1], 0.0);
    }
}
