//! A labeled similarity-search dataset: histograms over a shared embedded
//! vocabulary + class labels (paper Table 4 properties).

use super::histogram::Histogram;
use super::sparse::CsrMatrix;
use super::vocab::Embeddings;

/// An in-memory dataset ready for the LC engines and solvers.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// `(v, m)` vocabulary coordinates.
    pub embeddings: Embeddings,
    /// Database histograms in CSR form (rows L1-normalized).
    pub matrix: CsrMatrix,
    /// Class label per histogram.
    pub labels: Vec<u16>,
}

/// Paper Table-4 style properties.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    pub n: usize,
    pub avg_h: f64,
    pub vocab_size: usize,
    pub used_vocab: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn new(
        name: impl Into<String>,
        embeddings: Embeddings,
        histograms: &[Histogram],
        labels: Vec<u16>,
    ) -> Dataset {
        assert_eq!(histograms.len(), labels.len(), "one label per histogram");
        let normalized: Vec<Histogram> = histograms.iter().map(|h| h.normalized()).collect();
        let matrix = CsrMatrix::from_histograms(&normalized, embeddings.num_vectors());
        Dataset { name: name.into(), embeddings, matrix, labels }
    }

    /// Assemble from an already-built CSR matrix without re-normalizing
    /// (used by the binary loader so weights round-trip bit-exactly).
    pub fn from_csr(
        name: impl Into<String>,
        embeddings: Embeddings,
        matrix: CsrMatrix,
        labels: Vec<u16>,
    ) -> Dataset {
        assert_eq!(matrix.nrows(), labels.len(), "one label per histogram");
        assert_eq!(matrix.ncols(), embeddings.num_vectors(), "vocab size mismatch");
        Dataset { name: name.into(), embeddings, matrix, labels }
    }

    pub fn len(&self) -> usize {
        self.matrix.nrows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Vocabulary entries that actually occur in some histogram (paper's
    /// "used v").
    pub fn used_vocab(&self) -> usize {
        let mut used = vec![false; self.matrix.ncols()];
        for u in 0..self.matrix.nrows() {
            let (idx, _) = self.matrix.row(u);
            for &i in idx {
                used[i as usize] = true;
            }
        }
        used.iter().filter(|&&b| b).count()
    }

    pub fn stats(&self) -> DatasetStats {
        let classes = self.labels.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
        DatasetStats {
            n: self.len(),
            avg_h: self.matrix.avg_row_nnz(),
            vocab_size: self.matrix.ncols(),
            used_vocab: self.used_vocab(),
            dim: self.embeddings.dim(),
            classes,
        }
    }

    /// The histogram of row `u` (owned copy).
    pub fn histogram(&self, u: usize) -> Histogram {
        self.matrix.row_histogram(u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let emb = Embeddings::new(vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0], 3, 2);
        let hists = vec![
            Histogram::from_pairs(vec![(0, 2.0), (1, 2.0)]),
            Histogram::from_pairs(vec![(2, 5.0)]),
        ];
        Dataset::new("tiny", emb, &hists, vec![0, 1])
    }

    #[test]
    fn rows_are_normalized() {
        let d = tiny();
        let (_, w) = d.matrix.row(0);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn stats_match() {
        let d = tiny();
        let s = d.stats();
        assert_eq!(s.n, 2);
        assert_eq!(s.vocab_size, 3);
        assert_eq!(s.used_vocab, 3);
        assert_eq!(s.dim, 2);
        assert_eq!(s.classes, 2);
        assert!((s.avg_h - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "one label per histogram")]
    fn label_mismatch_panics() {
        let emb = Embeddings::zeros(1, 2);
        Dataset::new("bad", emb, &[], vec![0]);
    }
}
