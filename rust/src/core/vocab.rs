//! Vocabulary embeddings: the `(v, m)` coordinate matrix **V** of paper
//! Section 5 (word2vec vectors for text, pixel coordinates for images).

use std::sync::Arc;

/// Row-major `(v, m)` embedding matrix.
///
/// The coordinate buffer is reference-counted: `clone` shares the same
/// storage instead of copying the `(v, m)` table, so the many places that
/// carry an `Embeddings` by value — every shard dataset of a
/// [`crate::shard::ShardedCorpus`], gathered sub-datasets, the sharded
/// engine's monolithic fallback — all point at one table.  Mutating methods
/// ([`Embeddings::row_mut`], [`Embeddings::l2_normalize`]) copy-on-write,
/// which only the dataset generators exercise (before any sharing starts).
#[derive(Debug, Clone, PartialEq)]
pub struct Embeddings {
    data: Arc<Vec<f32>>,
    v: usize,
    m: usize,
}

impl Embeddings {
    pub fn new(data: Vec<f32>, v: usize, m: usize) -> Embeddings {
        assert_eq!(data.len(), v * m, "embedding buffer size mismatch");
        Embeddings { data: Arc::new(data), v, m }
    }

    pub fn zeros(v: usize, m: usize) -> Embeddings {
        Embeddings { data: Arc::new(vec![0.0; v * m]), v, m }
    }

    /// Whether `self` and `other` share one underlying coordinate buffer
    /// (the memory-footprint invariant the sharded corpus relies on).
    pub fn shares_storage(&self, other: &Embeddings) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Pixel-grid embeddings for `side x side` images: vocabulary entry
    /// `r*side + c` has coordinate `(r, c)` (paper Fig. 1(a), m = 2).
    pub fn pixel_grid(side: usize) -> Embeddings {
        let mut data = Vec::with_capacity(side * side * 2);
        for r in 0..side {
            for c in 0..side {
                data.push(r as f32);
                data.push(c as f32);
            }
        }
        Embeddings::new(data, side * side, 2)
    }

    pub fn num_vectors(&self) -> usize {
        self.v
    }

    pub fn dim(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    /// Mutable row access (copy-on-write when the buffer is shared).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let m = self.m;
        &mut Arc::make_mut(&mut self.data)[i * m..(i + 1) * m]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// L2-normalize every row (paper: word2vec vectors are L2-normalized).
    /// Zero rows are left untouched.
    pub fn l2_normalize(&mut self) {
        for i in 0..self.v {
            let row = self.row_mut(i);
            let norm = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            if norm > 0.0 {
                let inv = (1.0 / norm) as f32;
                for x in row {
                    *x *= inv;
                }
            }
        }
    }

    /// Gather rows into a new matrix (used to build the query coordinate
    /// matrix Q from histogram support indices).
    pub fn gather(&self, rows: &[u32]) -> Embeddings {
        let mut data = Vec::with_capacity(rows.len() * self.m);
        for &r in rows {
            data.extend_from_slice(self.row(r as usize));
        }
        Embeddings::new(data, rows.len(), self.m)
    }

    /// Squared L2 norm of every row, in row order.  The Phase-1 Gram
    /// expansion consumes these; computing them once per dataset (instead of
    /// per row per `plan_query` call) removes an `O(n·v·m)` term from
    /// all-pairs sweeps.  Per row this is [`sq_norm`] — the lane-chunked
    /// row-norm kernel contract — so norm tables computed here, by
    /// [`crate::core::compress::F16Tier::row_sq_norms`] and by any
    /// `lc::kernels` backend are all bit-equal.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.v).map(|i| sq_norm(self.row(i))).collect()
    }

    /// An f16 copy of the table for compressed stage-1 scoring (see
    /// [`crate::core::compress::F16Tier`]).
    pub fn compressed_tier(&self) -> super::compress::F16Tier {
        super::compress::F16Tier::from_embeddings(self)
    }

    /// Weighted centroid of a histogram's coordinates (for WCD).
    pub fn centroid(&self, indices: &[u32], weights: &[f32]) -> Vec<f64> {
        let mut c = vec![0.0f64; self.m];
        for (&i, &w) in indices.iter().zip(weights) {
            let row = self.row(i as usize);
            for (acc, &x) in c.iter_mut().zip(row) {
                *acc += w as f64 * x as f64;
            }
        }
        c
    }
}

/// Lane-chunked squared norm: the scalar reference for the row-norm kernel
/// primitive (`lc::kernels::row_sq_norm_with`), shared by
/// [`Embeddings::row_sq_norms`] and the f16 tier's norm table.  The
/// arithmetic is exactly `dot(row, row)` under the Phase-1 bit-identity
/// contract: 16 accumulator lanes, unfused multiply-then-add, in-order lane
/// reduction, serial tail.
#[inline]
pub fn sq_norm(row: &[f32]) -> f32 {
    const LANES: usize = 16;
    let n = row.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let rc = &row[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            acc[l] += rc[l] * rc[l];
        }
    }
    let mut dot = 0.0f32;
    for &x in acc.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += row[t] * row[t];
    }
    dot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_grid_coords() {
        let e = Embeddings::pixel_grid(3);
        assert_eq!(e.num_vectors(), 9);
        assert_eq!(e.dim(), 2);
        assert_eq!(e.row(0), &[0.0, 0.0]);
        assert_eq!(e.row(5), &[1.0, 2.0]); // r=1, c=2
        assert_eq!(e.row(8), &[2.0, 2.0]);
    }

    #[test]
    fn l2_normalize_unit_rows() {
        let mut e = Embeddings::new(vec![3.0, 4.0, 0.0, 0.0], 2, 2);
        e.l2_normalize();
        assert!((e.row(0)[0] - 0.6).abs() < 1e-6);
        assert!((e.row(0)[1] - 0.8).abs() < 1e-6);
        assert_eq!(e.row(1), &[0.0, 0.0]); // zero row untouched
    }

    #[test]
    fn gather_selects_rows() {
        let e = Embeddings::new((0..8).map(|x| x as f32).collect(), 4, 2);
        let g = e.gather(&[2, 0]);
        assert_eq!(g.row(0), &[4.0, 5.0]);
        assert_eq!(g.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn clone_shares_storage_and_mutation_unshares() {
        let a = Embeddings::new(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let b = a.clone();
        assert!(a.shares_storage(&b), "clone must not copy the (v, m) table");
        assert_eq!(a, b);
        // copy-on-write: mutating one side leaves the other untouched
        let mut c = a.clone();
        c.row_mut(0)[0] = 9.0;
        assert!(!c.shares_storage(&a));
        assert_eq!(a.row(0), &[1.0, 2.0]);
        assert_eq!(c.row(0), &[9.0, 2.0]);
        // gathered matrices own fresh storage
        assert!(!a.gather(&[0]).shares_storage(&a));
    }

    #[test]
    fn centroid_weighted_mean() {
        let e = Embeddings::new(vec![0.0, 0.0, 2.0, 4.0], 2, 2);
        let c = e.centroid(&[0, 1], &[0.5, 0.5]);
        assert_eq!(c, vec![1.0, 2.0]);
    }
}
