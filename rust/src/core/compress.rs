//! Compressed embedding residency: IEEE binary16 (f16) conversion helpers,
//! the [`F16Tier`] stage-1 copy of an embedding table, and the
//! product-quantization groundwork types.
//!
//! The crate is dependency-free, so the f32 ↔ f16 conversions are
//! hand-rolled bit manipulation: `f32_to_f16` rounds to nearest-even
//! (including the subnormal range), `f16_to_f32` widens exactly — every
//! f16 value is representable as f32, so the software widening agrees
//! bitwise with the hardware `vcvtph2ps` the SIMD kernels use
//! ([`crate::lc::kernels`]).
//!
//! The tier halves the memory traffic of stage-1 candidate scoring (2
//! bytes/coordinate instead of 4); exactness is recovered by the planner's
//! exact-f32 rerank (see `coordinator::plan`), never assumed here.

use std::sync::Arc;

use super::vocab::Embeddings;

/// Which compressed stage-1 tier an engine keeps (config knob
/// `"compressed"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompressedKind {
    /// No compressed tier; every stage scores full-precision f32.
    #[default]
    Off,
    /// IEEE binary16 copy of the embedding (and IVF centroid) tables.
    F16,
}

impl CompressedKind {
    pub fn name(self) -> &'static str {
        match self {
            CompressedKind::Off => "none",
            CompressedKind::F16 => "f16",
        }
    }
}

/// Convert an f32 to IEEE binary16 with round-to-nearest-even.  Overflow
/// saturates to ±inf; values below the smallest subnormal round to ±0.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // inf / NaN (NaN keeps a truncated payload, forced non-zero)
        if mant == 0 {
            return sign | 0x7c00;
        }
        let payload = ((mant >> 13) as u16) & 0x03ff;
        return sign | 0x7c00 | if payload == 0 { 0x0200 } else { payload };
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16: 10 mantissa bits survive, 13 are rounded off
        let mant16 = (mant >> 13) as u16;
        let rest = mant & 0x1fff;
        let mut h = sign | (((e + 15) as u16) << 10) | mant16;
        if rest > 0x1000 || (rest == 0x1000 && (mant16 & 1) == 1) {
            h += 1; // rounding up may carry into the exponent — that is correct
        }
        return h;
    }
    if e >= -25 {
        // subnormal f16: shift the (implicit-bit-restored) mantissa right
        let full = mant | 0x0080_0000;
        let shift = (13 - 14 - e) as u32; // 13 dropped bits + denormalization
        let mant16 = (full >> shift) as u16;
        let rest = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = sign | mant16;
        if rest > halfway || (rest == halfway && (mant16 & 1) == 1) {
            h += 1;
        }
        return h;
    }
    sign // underflow to ±0
}

/// Widen an IEEE binary16 to f32 — exact for every input.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        // inf / NaN
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp != 0 {
        sign | ((exp + 112) << 23) | (mant << 13)
    } else if mant == 0 {
        sign
    } else {
        // subnormal f16 (= mant × 2⁻²⁴) is a *normal* f32: renormalize
        let n = mant.leading_zeros() - 21; // shift putting the MSB at bit 10
        sign | ((113 - n) << 23) | (((mant << n) & 0x03ff) << 13)
    };
    f32::from_bits(bits)
}

/// An f16 copy of an `(v, m)` embedding table, used **only** for stage-1
/// candidate scoring ([`crate::lc::LcEngine`] plans against it through the
/// `dot_f16` kernels; the planner reranks survivors at exact f32).
///
/// Shares nothing with the source [`Embeddings`]; cheap to clone
/// (`Arc`-backed like the source table).
#[derive(Debug, Clone, PartialEq)]
pub struct F16Tier {
    data: Arc<Vec<u16>>,
    v: usize,
    m: usize,
}

impl F16Tier {
    /// Encode every coordinate of `emb` (round-to-nearest-even).
    pub fn from_embeddings(emb: &Embeddings) -> F16Tier {
        let data = emb.as_slice().iter().map(|&x| f32_to_f16(x)).collect();
        F16Tier { data: Arc::new(data), v: emb.num_vectors(), m: emb.dim() }
    }

    pub fn num_vectors(&self) -> usize {
        self.v
    }

    pub fn dim(&self) -> usize {
        self.m
    }

    /// Encoded row `i` (length `m`).
    pub fn row(&self, i: usize) -> &[u16] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    /// Append the decoded f32 coordinates of row `i` to `out`.
    pub fn decode_row_into(&self, i: usize, out: &mut Vec<f32>) {
        out.extend(self.row(i).iter().map(|&h| f16_to_f32(h)));
    }

    /// Squared norms of the *decoded* rows, with the same lane-chunked
    /// arithmetic as [`Embeddings::row_sq_norms`] — this is the norm table
    /// Phase 1 must pair with the tier so compressed plans are internally
    /// consistent.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        let mut buf = Vec::with_capacity(self.m);
        (0..self.v)
            .map(|i| {
                buf.clear();
                self.decode_row_into(i, &mut buf);
                super::vocab::sq_norm(&buf)
            })
            .collect()
    }

    /// Bytes the encoded table occupies (half the f32 original).
    pub fn bytes(&self) -> usize {
        self.data.len() * 2
    }
}

/// Product-quantization groundwork (roadmap: PQ centroid/embedding tiers).
///
/// The shape is fixed here so configs can already name it — `m` subspaces
/// of `dim/m` coordinates, each coded to `1 << bits` centroids — but no
/// codebook trainer ships yet: [`PqParams::validate`] says so explicitly
/// and the config layer rejects `"compressed": "pq"` with the same message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PqParams {
    /// Number of subquantizers (must divide the embedding dim).
    pub subspaces: usize,
    /// Bits per code (codebook size `1 << bits` per subspace).
    pub bits: u8,
}

impl Default for PqParams {
    fn default() -> PqParams {
        PqParams { subspaces: 8, bits: 8 }
    }
}

impl PqParams {
    /// PQ is declared but not implemented; every entry point reports the
    /// same actionable error instead of silently falling back.
    pub fn validate(&self) -> crate::core::EmdResult<()> {
        Err(crate::core::EmdError::unsupported(
            "product quantization is groundwork: only the f16 tier is implemented \
             (set \"compressed\": \"f16\")",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_is_exact_for_every_encoding() {
        // every non-NaN f16 value must survive decode -> encode unchanged
        for h in 0u16..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let mant = h & 0x03ff;
            if exp == 0x1f && mant != 0 {
                continue; // NaN: payload equality is not guaranteed
            }
            let x = f16_to_f32(h);
            assert_eq!(f32_to_f16(x), h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn f16_decode_matches_reference_values() {
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert_eq!(f16_to_f32(0x8000), -0.0);
        assert!(f16_to_f32(0x8000).is_sign_negative());
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xc000), -2.0);
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // largest finite f16
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14)); // smallest normal
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // smallest subnormal
        assert_eq!(f16_to_f32(0x03ff), 1023.0 * 2.0f32.powi(-24)); // largest subnormal
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
    }

    #[test]
    fn f16_encode_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16 up:
        // ties-to-even keeps the even mantissa (1.0)
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), 0x3c00);
        // the next representable f32 above the tie rounds up
        assert_eq!(f32_to_f16((1.0 + 2.0f32.powi(-11)).next_up()), 0x3c01);
        // halfway between 0x3c01 and 0x3c02 rounds to even (0x3c02)
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
        // overflow saturates to inf
        assert_eq!(f32_to_f16(1.0e9), 0x7c00);
        assert_eq!(f32_to_f16(-1.0e9), 0xfc00);
        // underflow collapses to signed zero
        assert_eq!(f32_to_f16(1.0e-30), 0x0000);
        assert_eq!(f32_to_f16(-1.0e-30), 0x8000);
        // values straddling the smallest subnormal: just above half of it
        // rounds up, exactly half (a tie against zero) rounds to even zero
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(2.0f32.powi(-25).next_up()), 0x0001);
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
    }

    #[test]
    fn tier_encodes_and_decodes_consistently() {
        let emb = Embeddings::new(
            vec![0.5, -1.25, 3.0, 0.1, -0.0, 7.5, 1.0e-8, -2.5],
            4,
            2,
        );
        let tier = emb.compressed_tier();
        assert_eq!(tier.num_vectors(), 4);
        assert_eq!(tier.dim(), 2);
        assert_eq!(tier.bytes(), 16);
        let mut out = Vec::new();
        for i in 0..4 {
            out.clear();
            tier.decode_row_into(i, &mut out);
            for (&d, &orig) in out.iter().zip(emb.row(i)) {
                assert_eq!(d.to_bits(), f16_to_f32(f32_to_f16(orig)).to_bits());
                if orig.abs() > 1.0e-3 {
                    // rounding error within half an ulp at 11 significand bits
                    assert!((d - orig).abs() <= orig.abs() * 2.0f32.powi(-11), "{d} vs {orig}");
                }
            }
        }
        // norm table matches recomputing over decoded rows
        let norms = tier.row_sq_norms();
        let mut buf = Vec::new();
        for i in 0..4 {
            buf.clear();
            tier.decode_row_into(i, &mut buf);
            assert_eq!(norms[i].to_bits(), crate::core::vocab::sq_norm(&buf).to_bits());
        }
    }

    #[test]
    fn pq_is_explicit_groundwork() {
        let err = PqParams::default().validate().unwrap_err();
        assert!(err.to_string().contains("groundwork"), "{err}");
    }
}
