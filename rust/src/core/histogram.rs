//! Histograms over a vocabulary of embedded coordinates (paper Section 2).
//!
//! A histogram assigns non-negative weights to a sparse subset of the
//! vocabulary.  Weights are L1-normalized before any distance computation
//! (the paper assumes Σp = Σq = 1 throughout).

/// A sparse histogram: parallel `(vocab index, weight)` arrays with indices
/// strictly ascending.  Invariants are enforced by the constructors.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    indices: Vec<u32>,
    weights: Vec<f32>,
}

impl Histogram {
    /// Build from unsorted (index, weight) pairs: merges duplicate indices,
    /// drops non-positive weights, sorts by index.
    pub fn from_pairs(mut pairs: Vec<(u32, f32)>) -> Histogram {
        pairs.retain(|&(_, w)| w > 0.0);
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let mut indices = Vec::with_capacity(pairs.len());
        let mut weights: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, w) in pairs {
            if indices.last() == Some(&i) {
                *weights.last_mut().unwrap() += w;
            } else {
                indices.push(i);
                weights.push(w);
            }
        }
        Histogram { indices, weights }
    }

    /// Build from a dense weight vector (e.g. an image), keeping nonzeros.
    pub fn from_dense(dense: &[f32]) -> Histogram {
        let mut indices = Vec::new();
        let mut weights = Vec::new();
        for (i, &w) in dense.iter().enumerate() {
            if w > 0.0 {
                indices.push(i as u32);
                weights.push(w);
            }
        }
        Histogram { indices, weights }
    }

    /// Number of bins with positive weight (the paper's `h`).
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    pub fn total_mass(&self) -> f64 {
        self.weights.iter().map(|&w| w as f64).sum()
    }

    /// L1-normalize in place.  No-op on an empty histogram.
    pub fn normalize(&mut self) {
        let total = self.total_mass();
        if total > 0.0 {
            let inv = (1.0 / total) as f32;
            for w in &mut self.weights {
                *w *= inv;
            }
        }
    }

    /// A normalized copy.
    pub fn normalized(&self) -> Histogram {
        let mut h = self.clone();
        h.normalize();
        h
    }

    /// Keep only the `cap` heaviest bins (paper: 20News truncation to the
    /// most-frequent 500 words), then restore index order.
    pub fn truncate_top(&mut self, cap: usize) {
        if self.len() <= cap {
            return;
        }
        let mut order: Vec<usize> = (0..self.len()).collect();
        order.sort_by(|&a, &b| {
            self.weights[b].partial_cmp(&self.weights[a]).unwrap().then(a.cmp(&b))
        });
        order.truncate(cap);
        order.sort_unstable();
        self.indices = order.iter().map(|&i| self.indices[i]).collect();
        self.weights = order.iter().map(|&i| self.weights[i]).collect();
    }

    /// Scatter into a dense vector of length `v`.
    pub fn to_dense(&self, v: usize) -> Vec<f32> {
        let mut out = vec![0.0; v];
        for (&i, &w) in self.indices.iter().zip(&self.weights) {
            out[i as usize] += w;
        }
        out
    }

    /// Weight at a vocabulary index (0 if absent); O(log h).
    pub fn weight_at(&self, index: u32) -> f32 {
        match self.indices.binary_search(&index) {
            Ok(pos) => self.weights[pos],
            Err(_) => 0.0,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.indices.iter().copied().zip(self.weights.iter().copied())
    }

    /// Largest vocabulary index referenced + 1 (0 when empty).
    pub fn min_vocab_size(&self) -> usize {
        self.indices.last().map(|&i| i as usize + 1).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pairs_merges_and_sorts() {
        let h = Histogram::from_pairs(vec![(5, 1.0), (2, 2.0), (5, 0.5), (9, 0.0), (1, -1.0)]);
        assert_eq!(h.indices(), &[2, 5]);
        assert_eq!(h.weights(), &[2.0, 1.5]);
    }

    #[test]
    fn from_dense_keeps_nonzeros() {
        let h = Histogram::from_dense(&[0.0, 0.5, 0.0, 0.25]);
        assert_eq!(h.indices(), &[1, 3]);
        assert_eq!(h.weights(), &[0.5, 0.25]);
        assert_eq!(h.min_vocab_size(), 4);
    }

    #[test]
    fn normalize_sums_to_one() {
        let mut h = Histogram::from_pairs(vec![(0, 2.0), (1, 6.0)]);
        h.normalize();
        assert!((h.total_mass() - 1.0).abs() < 1e-7);
        assert!((h.weights()[0] - 0.25).abs() < 1e-7);
    }

    #[test]
    fn normalize_empty_is_noop() {
        let mut h = Histogram::from_pairs(vec![]);
        h.normalize();
        assert!(h.is_empty());
    }

    #[test]
    fn truncate_keeps_heaviest_in_index_order() {
        let mut h =
            Histogram::from_pairs(vec![(0, 0.1), (1, 0.9), (2, 0.05), (3, 0.5), (4, 0.3)]);
        h.truncate_top(3);
        assert_eq!(h.indices(), &[1, 3, 4]);
        assert_eq!(h.weights(), &[0.9, 0.5, 0.3]);
    }

    #[test]
    fn truncate_tie_prefers_lower_index() {
        let mut h = Histogram::from_pairs(vec![(0, 0.5), (1, 0.5), (2, 0.5)]);
        h.truncate_top(2);
        assert_eq!(h.indices(), &[0, 1]);
    }

    #[test]
    fn dense_roundtrip() {
        let h = Histogram::from_pairs(vec![(1, 0.5), (3, 0.5)]);
        let d = h.to_dense(5);
        assert_eq!(d, vec![0.0, 0.5, 0.0, 0.5, 0.0]);
        assert_eq!(Histogram::from_dense(&d), h);
    }

    #[test]
    fn weight_at_binary_search() {
        let h = Histogram::from_pairs(vec![(10, 0.25), (20, 0.75)]);
        assert_eq!(h.weight_at(10), 0.25);
        assert_eq!(h.weight_at(15), 0.0);
    }
}
