//! Core data model: histograms, vocabulary embeddings, the CSR database
//! matrix and ground-distance computation (paper Section 2 & 5).

pub mod cost;
pub mod dataset;
pub mod histogram;
pub mod sparse;
pub mod vocab;

pub use cost::{cost_matrix, support_cost_matrix, Metric};
pub use dataset::{Dataset, DatasetStats};
pub use histogram::Histogram;
pub use sparse::CsrMatrix;
pub use vocab::Embeddings;
