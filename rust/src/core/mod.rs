//! Core data model and the unified distance API: histograms, vocabulary
//! embeddings, the CSR database matrix and ground-distance computation
//! (paper Section 2 & 5), plus the crate-wide [`EmdError`], the canonical
//! [`Method`] enum, the [`Distance`] / [`BatchDistance`] traits and the
//! [`MethodRegistry`] every layer dispatches through.

pub mod compress;
pub mod cost;
pub mod dataset;
pub mod distance;
pub mod error;
pub mod histogram;
pub mod method;
pub mod sparse;
pub mod vocab;

pub use compress::{CompressedKind, F16Tier, PqParams};
pub use cost::{cost_matrix, support_cost_matrix, Metric};
pub use dataset::{Dataset, DatasetStats};
pub use distance::{BatchDistance, Distance, MethodRegistry};
pub use error::{EmdError, EmdResult};
pub use histogram::Histogram;
pub use method::{Method, METHOD_SYNTAX};
pub use sparse::CsrMatrix;
pub use vocab::Embeddings;
