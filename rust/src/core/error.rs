//! Crate-wide error type.
//!
//! Every fallible operation in `emdpar` returns [`EmdResult`]; the variants
//! below categorize failures so callers can branch on them (the TCP server
//! maps them to protocol error strings, the CLI prints them and exits).
//! Replaces the earlier ad-hoc mix of `anyhow::Result`, `io::Result` and
//! stringly-typed errors, and keeps the crate dependency-free.

use std::fmt;

/// Crate-wide result alias.
pub type EmdResult<T> = std::result::Result<T, EmdError>;

/// Unified error enum for every layer of the crate.
#[derive(Debug)]
pub enum EmdError {
    /// A user-supplied string is not a known enum value.
    /// `what` names the domain ("method", "metric", "backend", ...).
    Parse { what: &'static str, input: String, expected: &'static str },
    /// Invalid configuration (bad field value, failed validation).
    Config(String),
    /// File / socket IO, with context about what was being done.
    Io(String),
    /// JSON syntax or schema violation.
    Json(String),
    /// PJRT / artifact runtime failure (missing artifacts, shape mismatch,
    /// or the runtime not being compiled in).
    Artifact(String),
    /// Malformed client request on the serving protocol.
    Protocol(String),
    /// The requested operation is valid but not supported by the selected
    /// backend or method combination.
    Unsupported(String),
    /// Uncategorized failure.
    Msg(String),
}

impl EmdError {
    pub fn parse(what: &'static str, input: impl Into<String>, expected: &'static str) -> EmdError {
        EmdError::Parse { what, input: input.into(), expected }
    }

    pub fn config(msg: impl Into<String>) -> EmdError {
        EmdError::Config(msg.into())
    }

    pub fn io(msg: impl Into<String>) -> EmdError {
        EmdError::Io(msg.into())
    }

    pub fn json(msg: impl Into<String>) -> EmdError {
        EmdError::Json(msg.into())
    }

    pub fn artifact(msg: impl Into<String>) -> EmdError {
        EmdError::Artifact(msg.into())
    }

    pub fn protocol(msg: impl Into<String>) -> EmdError {
        EmdError::Protocol(msg.into())
    }

    pub fn unsupported(msg: impl Into<String>) -> EmdError {
        EmdError::Unsupported(msg.into())
    }

    pub fn msg(msg: impl Into<String>) -> EmdError {
        EmdError::Msg(msg.into())
    }
}

impl fmt::Display for EmdError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EmdError::Parse { what, input, expected } => {
                write!(f, "unknown {what} '{input}' (expected {expected})")
            }
            EmdError::Config(m) => write!(f, "config error: {m}"),
            EmdError::Io(m) => write!(f, "io error: {m}"),
            EmdError::Json(m) => write!(f, "json error: {m}"),
            EmdError::Artifact(m) => write!(f, "artifact runtime: {m}"),
            EmdError::Protocol(m) => write!(f, "bad request: {m}"),
            EmdError::Unsupported(m) => write!(f, "unsupported: {m}"),
            EmdError::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for EmdError {}

impl From<std::io::Error> for EmdError {
    fn from(e: std::io::Error) -> EmdError {
        EmdError::Io(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for EmdError {
    fn from(e: crate::util::json::JsonError) -> EmdError {
        EmdError::Json(e.to_string())
    }
}

impl From<crate::util::cli::CliError> for EmdError {
    fn from(e: crate::util::cli::CliError) -> EmdError {
        EmdError::Config(e.to_string())
    }
}

/// Early-return with an [`EmdError::Msg`] built from a format string.
#[macro_export]
macro_rules! emd_bail {
    ($($arg:tt)*) => {
        return Err($crate::core::EmdError::msg(format!($($arg)*)))
    };
}

/// Early-return unless the condition holds.  With a leading category
/// identifier (`config`, `protocol`, `artifact`, ...) the error lands in
/// the matching [`EmdError`] variant so callers can branch on it;
/// otherwise it falls back to [`EmdError::Msg`].
#[macro_export]
macro_rules! emd_ensure {
    ($cond:expr, $kind:ident, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::core::EmdError::$kind(format!($($arg)*)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::core::EmdError::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        let e = EmdError::parse("method", "magic", "bow|rwmd|...");
        assert!(e.to_string().contains("unknown method 'magic'"));
        assert!(EmdError::config("x").to_string().starts_with("config error"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: EmdError = io.into();
        assert!(matches!(e, EmdError::Io(_)));
    }

    #[test]
    fn bail_and_ensure_macros() {
        fn f(flag: bool) -> EmdResult<u32> {
            emd_ensure!(flag, "flag was {flag}");
            if !flag {
                emd_bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert!(f(false).is_err());
    }
}
