//! The unified distance API: per-pair [`Distance`] objects, batched
//! [`BatchDistance`] objects, and the [`MethodRegistry`] that maps every
//! [`Method`] — including Sinkhorn and exact EMD — to a boxed
//! implementation.
//!
//! Layering: the traits and the registry live in `core` so every layer
//! (approx solvers, LC engines, coordinator, eval harness, CLI) dispatches
//! through the same objects instead of calling per-module free functions
//! with incompatible signatures.

use std::sync::Arc;

use super::error::{EmdError, EmdResult};
use super::method::Method;
use super::{Embeddings, Histogram, Metric};

use crate::approx::SinkhornParams;

/// A per-pair distance measure over histograms sharing a vocabulary.
///
/// Implementations are self-contained (metric and solver parameters are
/// captured at construction) so `Box<dyn Distance>` objects can be handed
/// across threads — the LC engines' per-pair fallback and the cascade's
/// rerank stage both do.  Directed bounds are exposed in their *symmetric*
/// form (`max` of the two directions), the form the paper evaluates and the
/// form for which the Theorem-2 chain holds.
pub trait Distance: Send + Sync {
    /// Which canonical method this object computes.
    fn method(&self) -> Method;

    /// Human-readable name (defaults to the method name).
    fn name(&self) -> String {
        self.method().name()
    }

    /// Distance between two histograms over `vocab`.
    fn distance(&self, vocab: &Embeddings, p: &Histogram, q: &Histogram) -> EmdResult<f64>;
}

/// A distance measure bound to a database: one query row at a time, the LC
/// engines' native query-vs-all-rows shape.
pub trait BatchDistance: Send + Sync {
    /// Which canonical method this object computes.
    fn method(&self) -> Method;

    /// Number of database rows each query is scored against.
    fn num_rows(&self) -> usize;

    /// Distances from one query histogram to every database row.
    fn distances(&self, query: &Histogram) -> EmdResult<Vec<f32>>;

    /// Row-major `(queries.len(), num_rows)` distances for a block of
    /// queries — the multi-query entry point the dynamic batcher and the
    /// evaluation sweeps dispatch through.  The default maps the
    /// single-query method; engines with a batched Phase-1 kernel (see
    /// [`crate::lc::BatchPlanner`]) override it with a one-pass block
    /// pipeline that produces bit-identical rows faster.
    fn distances_batch(&self, queries: &[Histogram]) -> EmdResult<Vec<f32>> {
        let mut out = Vec::with_capacity(queries.len() * self.num_rows());
        for q in queries {
            out.extend_from_slice(&self.distances(q)?);
        }
        Ok(out)
    }

    /// Row-major `(n, n)` symmetric all-pairs matrix over the database
    /// (the paper's accuracy-evaluation protocol).
    fn all_pairs_symmetric(&self) -> EmdResult<Vec<f32>>;
}

/// Maps every [`Method`] to a boxed [`Distance`] / [`BatchDistance`].
///
/// The registry captures the ground metric and solver parameters once;
/// lookups are cheap and the returned objects are `'static`, so they can be
/// cached, boxed into collections, or moved into worker threads.
#[derive(Debug, Clone, Copy)]
pub struct MethodRegistry {
    metric: Metric,
    sinkhorn: SinkhornParams,
}

impl MethodRegistry {
    pub fn new(metric: Metric) -> MethodRegistry {
        MethodRegistry { metric, sinkhorn: SinkhornParams::default() }
    }

    /// Override the Sinkhorn solver parameters (λ, iteration budget, tol).
    pub fn with_sinkhorn(mut self, params: SinkhornParams) -> MethodRegistry {
        self.sinkhorn = params;
        self
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Per-pair distance object.  Every method is available here; the
    /// quadratic comparators (ICT, Sinkhorn, exact EMD) are exactly as
    /// first-class as the linear-complexity bounds.
    pub fn distance(&self, method: Method) -> Box<dyn Distance> {
        Box::new(PairDistance { method, metric: self.metric, sinkhorn: self.sinkhorn })
    }

    /// Batched query-vs-database object, backed by an LC engine.  Linear
    /// methods run the Phase-1/Phase-2 pipeline under the *engine's* params
    /// (metric, threads, symmetric); per-pair fallback methods (BoW-adj,
    /// ICT, Sinkhorn, exact EMD) evaluate through *this registry's* metric
    /// and solver parameters.
    pub fn batch(
        &self,
        engine: &Arc<crate::lc::LcEngine>,
        method: Method,
    ) -> Box<dyn BatchDistance> {
        Box::new(crate::lc::LcBatch::with_registry(Arc::clone(engine), method, self))
    }

    /// The canonical method family (see [`Method::canonical`]).
    pub fn methods() -> Vec<Method> {
        Method::canonical()
    }
}

/// The registry's per-pair adapter: one struct, one `match`, every method.
struct PairDistance {
    method: Method,
    metric: Metric,
    sinkhorn: SinkhornParams,
}

impl Distance for PairDistance {
    fn method(&self) -> Method {
        self.method
    }

    fn distance(&self, vocab: &Embeddings, p: &Histogram, q: &Histogram) -> EmdResult<f64> {
        let m = self.metric;
        Ok(match self.method {
            Method::Bow => crate::approx::bow_distance(p, q),
            Method::BowAdjusted => crate::approx::bow_adjusted_symmetric(vocab, p, q, m),
            Method::Wcd => {
                // WCD is the Euclidean distance between centroids; under any
                // other ground metric it carries no relation to EMD, so
                // refuse rather than silently compute the wrong thing.
                if m != Metric::L2 {
                    return Err(EmdError::unsupported(
                        "WCD is defined for the L2 ground metric only",
                    ));
                }
                crate::approx::wcd(vocab, p, q)
            }
            Method::Rwmd => crate::approx::rwmd_symmetric(vocab, p, q, m),
            Method::Omr => crate::approx::omr_symmetric(vocab, p, q, m),
            Method::Act { k } => crate::approx::act_symmetric(vocab, p, q, m, k.max(1)),
            Method::Ict => crate::approx::ict_symmetric(vocab, p, q, m),
            Method::Sinkhorn => crate::approx::sinkhorn(vocab, p, q, m, self.sinkhorn),
            Method::Exact => crate::exact::emd(vocab, p, q, m),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Embeddings, Histogram, Histogram) {
        let mut rng = Rng::new(seed);
        let (v, m) = (16, 3);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let vocab = Embeddings::new(data, v, m);
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(v, 5);
            Histogram::from_pairs(
                idx.into_iter()
                    .map(|i| (i as u32, rng.range_f64(0.05, 1.0) as f32))
                    .collect(),
            )
        };
        let p = mk(&mut rng);
        let q = mk(&mut rng);
        (vocab, p, q)
    }

    #[test]
    fn every_method_resolves_and_computes() {
        let (vocab, p, q) = setup(1);
        let registry = MethodRegistry::new(Metric::L2);
        for method in MethodRegistry::methods() {
            let d = registry.distance(method);
            assert_eq!(d.method(), method);
            assert_eq!(d.name(), method.name());
            let val = d.distance(&vocab, &p, &q).unwrap();
            assert!(val.is_finite() && val >= 0.0, "{method}: {val}");
        }
    }

    #[test]
    fn registry_matches_free_functions() {
        let (vocab, p, q) = setup(2);
        let registry = MethodRegistry::new(Metric::L2);
        let via = |m: Method| registry.distance(m).distance(&vocab, &p, &q).unwrap();
        assert_eq!(via(Method::Rwmd), crate::approx::rwmd_symmetric(&vocab, &p, &q, Metric::L2));
        assert_eq!(via(Method::Ict), crate::approx::ict_symmetric(&vocab, &p, &q, Metric::L2));
        assert_eq!(via(Method::Exact), crate::exact::emd(&vocab, &p, &q, Metric::L2));
    }

    #[test]
    fn sinkhorn_params_are_honored() {
        let (vocab, p, q) = setup(3);
        let loose = MethodRegistry::new(Metric::L2)
            .with_sinkhorn(SinkhornParams { lambda: 2.0, max_iters: 500, tol: 1e-9 });
        let tight = MethodRegistry::new(Metric::L2)
            .with_sinkhorn(SinkhornParams { lambda: 80.0, max_iters: 500, tol: 1e-9 });
        let ex = crate::exact::emd(&vocab, &p, &q, Metric::L2);
        let dl = loose.distance(Method::Sinkhorn).distance(&vocab, &p, &q).unwrap();
        let dt = tight.distance(Method::Sinkhorn).distance(&vocab, &p, &q).unwrap();
        assert!((dt - ex).abs() <= (dl - ex).abs() + 1e-9, "λ=80 no tighter: {dt} vs {dl} (emd {ex})");
    }

    #[test]
    fn wcd_rejects_non_l2_metrics() {
        let (vocab, p, q) = setup(4);
        let registry = MethodRegistry::new(Metric::SqL2);
        let err = registry.distance(Method::Wcd).distance(&vocab, &p, &q);
        assert!(matches!(err, Err(EmdError::Unsupported(_))), "{err:?}");
        // every other method computes under the configured metric
        for method in [Method::Rwmd, Method::Ict, Method::Exact] {
            assert!(registry.distance(method).distance(&vocab, &p, &q).is_ok(), "{method}");
        }
    }

    #[test]
    fn distance_objects_are_send_sync() {
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<dyn Distance>();
        assert_send_sync::<dyn BatchDistance>();
    }
}
