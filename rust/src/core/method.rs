//! The canonical distance-method selector.
//!
//! One `Method` enum, one `parse`, one `name`, one Phase-1 plan width —
//! shared by the LC engines, the config system, the coordinator, the TCP
//! protocol, the evaluation harness and the CLI.  Every method, including
//! the quadratic comparators (ICT, Sinkhorn, exact EMD), is reachable
//! through this enum and through [`crate::core::MethodRegistry`].
//!
//! Naming follows the paper: `ACT-j` runs `j` Phase-2 constrained-transfer
//! iterations, which corresponds to `Method::Act { k: j + 1 }` (top-k
//! nearest destinations, the last one unconstrained).

use std::fmt;

use super::error::{EmdError, EmdResult};

/// Distance measure selector for every layer of the stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// BoW cosine distance (baseline, no embeddings).
    Bow,
    /// BoW-adjusted lower bound: non-overlapping mass x minimum ground
    /// distance — the cheapest member of the bound chain.
    BowAdjusted,
    /// Word centroid distance (baseline).
    Wcd,
    /// RWMD (k = 1); batched form is LC-RWMD.
    Rwmd,
    /// OMR (overlap-only capacity, top-2); batched form is LC-OMR.
    Omr,
    /// ACT with k-1 constrained iterations; batched form is LC-ACT.
    Act { k: usize },
    /// ICT — the full constrained-transfer relaxation (quadratic per pair).
    Ict,
    /// Entropy-regularized OT via Sinkhorn iterations (quadratic per pair).
    Sinkhorn,
    /// Exact EMD by min-cost flow — the paper's "WMD" quality level.
    Exact,
}

/// Accepted spellings, shown in parse errors and CLI help.
pub const METHOD_SYNTAX: &str =
    "bow | bow-adj | wcd | rwmd | omr | act-<j> | ict | sinkhorn | emd";

impl Method {
    /// Parse a method name (case-insensitive).  The canonical spellings are
    /// the lowercase forms of [`Method::name`]; `exact`/`wmd` are accepted
    /// aliases for `emd`, `bow-adjusted` for `bow-adj`.
    pub fn parse(s: &str) -> EmdResult<Method> {
        let ls = s.trim().to_ascii_lowercase();
        match ls.as_str() {
            "bow" => return Ok(Method::Bow),
            "bow-adj" | "bow-adjusted" => return Ok(Method::BowAdjusted),
            "wcd" => return Ok(Method::Wcd),
            "rwmd" => return Ok(Method::Rwmd),
            "omr" => return Ok(Method::Omr),
            "ict" => return Ok(Method::Ict),
            "sinkhorn" => return Ok(Method::Sinkhorn),
            "emd" | "exact" | "wmd" => return Ok(Method::Exact),
            _ => {}
        }
        if let Some(rest) = ls.strip_prefix("act-") {
            // paper naming: ACT-j runs j Phase-2 iterations => k = j + 1.
            // j is bounded so untrusted protocol input cannot request an
            // arbitrarily wide Phase-1 plan (k <= 64, the validated range).
            if let Ok(j) = rest.parse::<usize>() {
                if j < 64 {
                    return Ok(Method::Act { k: j + 1 });
                }
            }
        }
        Err(EmdError::parse("method", s, METHOD_SYNTAX))
    }

    /// Parse a comma-separated method list (`"bow,rwmd,act-1,sinkhorn"`).
    pub fn parse_list(s: &str) -> EmdResult<Vec<Method>> {
        s.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(Method::parse)
            .collect()
    }

    /// Display name; `parse(name)` round-trips for every method.
    pub fn name(&self) -> String {
        match self {
            Method::Bow => "BoW".into(),
            Method::BowAdjusted => "BoW-adj".into(),
            Method::Wcd => "WCD".into(),
            Method::Rwmd => "RWMD".into(),
            Method::Omr => "OMR".into(),
            Method::Act { k } => format!("ACT-{}", k.saturating_sub(1)),
            Method::Ict => "ICT".into(),
            Method::Sinkhorn => "Sinkhorn".into(),
            Method::Exact => "EMD".into(),
        }
    }

    /// Phase-1 top-k requirement for the LC engines (0 = no plan: the
    /// method is either plan-free or computed per-pair).
    pub fn plan_k(&self) -> usize {
        match self {
            Method::Rwmd => 1,
            Method::Omr => 2,
            Method::Act { k } => (*k).max(1),
            _ => 0,
        }
    }

    /// Whether the batched LC pipeline computes this method in linear time
    /// (Phase-1 plan + database sweep).  The rest fall back to the per-pair
    /// solvers behind the same [`crate::core::BatchDistance`] interface.
    pub fn is_linear_complexity(&self) -> bool {
        matches!(
            self,
            Method::Bow | Method::Wcd | Method::Rwmd | Method::Omr | Method::Act { .. }
        )
    }

    /// Whether the measure is a lower bound of exact EMD under *any* ground
    /// metric (the Theorem 2 chain plus the BoW-adjusted bound).  BoW
    /// cosine lives on a different scale, Sinkhorn upper-bounds EMD, and
    /// WCD lower-bounds WMD only for the L2 metric, so none of those
    /// qualify here.
    pub fn is_lower_bound(&self) -> bool {
        matches!(
            self,
            Method::BowAdjusted | Method::Rwmd | Method::Omr | Method::Act { .. } | Method::Ict
        )
    }

    /// The canonical method family, ordered cheapest-first (the order used
    /// by sweeps and by the DESIGN.md quickstart).
    pub fn canonical() -> Vec<Method> {
        vec![
            Method::Bow,
            Method::BowAdjusted,
            Method::Wcd,
            Method::Rwmd,
            Method::Omr,
            Method::Act { k: 2 },
            Method::Act { k: 4 },
            Method::Act { k: 8 },
            Method::Ict,
            Method::Sinkhorn,
            Method::Exact,
        ]
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl std::str::FromStr for Method {
    type Err = EmdError;

    fn from_str(s: &str) -> EmdResult<Method> {
        Method::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_known_names() {
        assert_eq!(Method::parse("bow").unwrap(), Method::Bow);
        assert_eq!(Method::parse("BoW-adj").unwrap(), Method::BowAdjusted);
        assert_eq!(Method::parse("bow-adjusted").unwrap(), Method::BowAdjusted);
        assert_eq!(Method::parse("WCD").unwrap(), Method::Wcd);
        assert_eq!(Method::parse("rwmd").unwrap(), Method::Rwmd);
        assert_eq!(Method::parse("omr").unwrap(), Method::Omr);
        assert_eq!(Method::parse("ict").unwrap(), Method::Ict);
        assert_eq!(Method::parse("sinkhorn").unwrap(), Method::Sinkhorn);
        assert_eq!(Method::parse("emd").unwrap(), Method::Exact);
        assert_eq!(Method::parse("exact").unwrap(), Method::Exact);
        assert_eq!(Method::parse("wmd").unwrap(), Method::Exact);
        assert_eq!(Method::parse("ACT-7").unwrap(), Method::Act { k: 8 });
        assert_eq!(Method::parse("act-0").unwrap(), Method::Act { k: 1 });
        assert_eq!(Method::parse("act-63").unwrap(), Method::Act { k: 64 });
        assert!(Method::parse("nope").is_err());
        assert!(Method::parse("act-x").is_err());
        assert!(Method::parse("").is_err());
        // untrusted input cannot request an unbounded plan width
        assert!(Method::parse("act-64").is_err());
        assert!(Method::parse("act-10000000").is_err());
        assert!(Method::parse("act-18446744073709551615").is_err());
    }

    #[test]
    fn name_parse_round_trip_exhaustive() {
        let mut all = Method::canonical();
        // ACT suffixes beyond the canonical set, including the k=1 edge
        for k in [1usize, 2, 3, 9, 17, 64] {
            all.push(Method::Act { k });
        }
        for m in all {
            let name = m.name();
            assert_eq!(Method::parse(&name).unwrap(), m, "round-trip {name}");
            assert_eq!(
                Method::parse(&name.to_ascii_lowercase()).unwrap(),
                m,
                "lowercase round-trip {name}"
            );
            assert_eq!(format!("{m}"), name, "Display = name");
        }
    }

    #[test]
    fn parse_list_splits_and_trims() {
        let ms = Method::parse_list("bow, rwmd ,act-1,, sinkhorn").unwrap();
        assert_eq!(
            ms,
            vec![Method::Bow, Method::Rwmd, Method::Act { k: 2 }, Method::Sinkhorn]
        );
        assert!(Method::parse_list("bow,nope").is_err());
    }

    #[test]
    fn plan_k_matches_paper() {
        assert_eq!(Method::Rwmd.plan_k(), 1);
        assert_eq!(Method::Omr.plan_k(), 2);
        assert_eq!(Method::Act { k: 8 }.plan_k(), 8);
        for m in [Method::Bow, Method::BowAdjusted, Method::Wcd, Method::Ict, Method::Sinkhorn, Method::Exact] {
            assert_eq!(m.plan_k(), 0, "{m}");
        }
    }

    #[test]
    fn bound_and_complexity_classification() {
        assert!(Method::Rwmd.is_lower_bound());
        assert!(Method::Ict.is_lower_bound());
        assert!(!Method::Bow.is_lower_bound());
        assert!(!Method::Wcd.is_lower_bound());
        assert!(!Method::Sinkhorn.is_lower_bound());
        assert!(!Method::Exact.is_lower_bound());
        assert!(Method::Act { k: 4 }.is_linear_complexity());
        assert!(!Method::Exact.is_linear_complexity());
    }
}
