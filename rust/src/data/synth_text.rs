//! Synthetic 20-Newsgroups substitute: a topic-mixture corpus over a
//! vocabulary with cluster-structured embeddings (paper substitution — see
//! DESIGN.md).
//!
//! Geometry the experiments need (and that Google-News word2vec provides in
//! the paper): words belonging to the same topic are *near* in embedding
//! space, documents are sparse L1-normalized word histograms, and a
//! document's class is its dominant topic.  Frequencies follow a Zipf law
//! within each topic so histograms have realistic skew, and a shared pool
//! of "general" words gives documents of different classes overlapping
//! support (which is what separates WMD-family measures from BoW).

use crate::core::{Dataset, Embeddings, Histogram};
use crate::util::rng::Rng;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct TextConfig {
    /// Number of documents.
    pub n: usize,
    /// Number of classes/topics (paper: 20).
    pub classes: usize,
    /// Vocabulary size (paper used-v: 69682; default scaled down).
    pub vocab: usize,
    /// Embedding dimensionality (paper: 300).
    pub dim: usize,
    /// Mean words per document before truncation (paper h̄: 78.8).
    pub doc_len: usize,
    /// Keep only the `truncate` most frequent words per document (paper: 500).
    pub truncate: usize,
    /// Fraction of each document drawn from its own topic.
    pub topic_frac: f64,
    /// Fraction drawn from the shared general pool (rest: random topics).
    pub general_frac: f64,
    /// Embedding cluster spread (intra-topic noise std before L2-norm).
    pub spread: f64,
    pub seed: u64,
}

impl Default for TextConfig {
    fn default() -> Self {
        TextConfig {
            n: 1000,
            classes: 20,
            vocab: 8000,
            dim: 64,
            doc_len: 80,
            truncate: 500,
            topic_frac: 0.65,
            general_frac: 0.2,
            spread: 0.35,
            seed: 1234,
        }
    }
}

/// Word-to-topic assignment: the first `general` words are the shared pool,
/// the rest are split evenly across topics.
fn word_topic(word: usize, vocab: usize, classes: usize, general: usize) -> Option<usize> {
    if word < general {
        None
    } else {
        Some((word - general) * classes / (vocab - general))
    }
}

/// Generate the corpus.
pub fn generate(config: &TextConfig) -> Dataset {
    let mut rng = Rng::new(config.seed);
    let v = config.vocab;
    let m = config.dim;
    let t = config.classes;
    let general = (v / 10).max(t); // ~10% shared pool

    // --- embeddings: topic centers + per-word noise, L2-normalized ---------
    let mut centers = vec![0.0f64; (t + 1) * m];
    for c in centers.iter_mut() {
        *c = rng.normal();
    }
    let mut emb = vec![0.0f32; v * m];
    for word in 0..v {
        let topic = word_topic(word, v, t, general);
        let center = match topic {
            Some(tp) => &centers[tp * m..(tp + 1) * m],
            None => &centers[t * m..(t + 1) * m], // general pool has its own loose center
        };
        let spread = if topic.is_some() { config.spread } else { 1.0 };
        for d in 0..m {
            emb[word * m + d] = (center[d] + rng.normal_ms(0.0, spread)) as f32;
        }
    }
    let mut embeddings = Embeddings::new(emb, v, m);
    embeddings.l2_normalize(); // paper: word2vec vectors are L2-normalized

    // per-topic word lists for Zipf sampling
    let mut topic_words: Vec<Vec<u32>> = vec![Vec::new(); t];
    let mut general_words: Vec<u32> = Vec::new();
    for word in 0..v {
        match word_topic(word, v, t, general) {
            Some(tp) => topic_words[tp].push(word as u32),
            None => general_words.push(word as u32),
        }
    }

    // Zipf weights (rank^-1) per pool, precomputed
    let zipf = |len: usize| -> Vec<f64> { (1..=len).map(|r| 1.0 / r as f64).collect() };
    let topic_zipf: Vec<Vec<f64>> = topic_words.iter().map(|ws| zipf(ws.len())).collect();
    let general_zipf = zipf(general_words.len());

    // --- documents ----------------------------------------------------------
    let mut hists = Vec::with_capacity(config.n);
    let mut labels = Vec::with_capacity(config.n);
    for i in 0..config.n {
        let class = i % t;
        let mut local = rng.fork(i as u64);
        // document length: lognormal-ish around doc_len
        let len = ((config.doc_len as f64) * local.range_f64(0.5, 1.7)).round().max(5.0) as usize;
        let mut counts: std::collections::BTreeMap<u32, f32> = std::collections::BTreeMap::new();
        for _ in 0..len {
            let roll = local.f64();
            let word = if roll < config.topic_frac {
                let ws = &topic_words[class];
                ws[local.weighted(&topic_zipf[class])]
            } else if roll < config.topic_frac + config.general_frac {
                general_words[local.weighted(&general_zipf)]
            } else {
                let other = local.below(t);
                let ws = &topic_words[other];
                ws[local.weighted(&topic_zipf[other])]
            };
            *counts.entry(word).or_insert(0.0) += 1.0;
        }
        let mut h = Histogram::from_pairs(counts.into_iter().collect());
        h.truncate_top(config.truncate);
        hists.push(h);
        labels.push(class as u16);
    }

    Dataset::new("synth-20news", embeddings, &hists, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TextConfig {
        TextConfig { n: 120, classes: 4, vocab: 400, dim: 16, doc_len: 40, ..Default::default() }
    }

    #[test]
    fn corpus_shape() {
        let ds = generate(&small());
        let s = ds.stats();
        assert_eq!(s.n, 120);
        assert_eq!(s.vocab_size, 400);
        assert_eq!(s.dim, 16);
        assert_eq!(s.classes, 4);
        assert!(s.avg_h > 10.0 && s.avg_h < 60.0, "avg_h = {}", s.avg_h);
    }

    #[test]
    fn embeddings_are_unit_norm() {
        let ds = generate(&small());
        for i in 0..ds.embeddings.num_vectors() {
            let n: f64 =
                ds.embeddings.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            assert!((n - 1.0).abs() < 1e-5, "word {i} norm {n}");
        }
    }

    #[test]
    fn same_topic_words_are_closer() {
        let ds = generate(&small());
        let cfg = small();
        let general = (cfg.vocab / 10).max(cfg.classes);
        // pick two words of topic 0 and one of topic 2
        let t0a = general;
        let t0b = general + 1;
        let t2 = general + 2 * (cfg.vocab - general) / cfg.classes + 1;
        let d = |a: usize, b: usize| {
            crate::core::Metric::L2.distance(ds.embeddings.row(a), ds.embeddings.row(b))
        };
        assert!(d(t0a, t0b) < d(t0a, t2), "intra {} !< inter {}", d(t0a, t0b), d(t0a, t2));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn truncation_caps_histogram_size() {
        let mut cfg = small();
        cfg.doc_len = 300;
        cfg.truncate = 25;
        let ds = generate(&cfg);
        for u in 0..ds.len() {
            assert!(ds.histogram(u).len() <= 25);
        }
    }

    #[test]
    fn classes_are_balanced() {
        let ds = generate(&small());
        let mut counts = vec![0usize; 4];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert_eq!(counts, vec![30, 30, 30, 30]);
    }
}
