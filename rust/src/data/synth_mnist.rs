//! Synthetic MNIST substitute: procedurally rendered hand-written-style
//! digits (paper substitution — see DESIGN.md).
//!
//! Each class 0-9 is a polyline skeleton in the unit square; a sample jitters
//! the control points, applies a random affine transform (translate / rotate
//! / scale), draws the strokes with a soft round brush onto a `side x side`
//! grid and normalizes pixel intensities.  The result reproduces the
//! statistics the paper's experiments depend on: ~150 nonzero pixels per
//! 28x28 image, strong within-class EMD proximity, and (with
//! `background > 0`) the fully-overlapping dense histograms of Table 6 that
//! break RWMD.

use crate::core::{Dataset, Embeddings, Histogram};
use crate::util::rng::Rng;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct MnistConfig {
    /// Image side (paper: 28).
    pub side: usize,
    /// Number of images.
    pub n: usize,
    /// Uniform background weight added to every pixel, as a fraction of the
    /// total foreground mass (paper Table 6 uses "include the black pixels";
    /// 0.0 reproduces Table 5).
    pub background: f32,
    /// Brush radius in pixels.
    pub brush: f64,
    pub seed: u64,
}

impl Default for MnistConfig {
    fn default() -> Self {
        MnistConfig { side: 28, n: 1000, background: 0.0, brush: 1.1, seed: 42 }
    }
}

/// Polyline skeletons per digit in the unit square (x right, y down).
fn skeleton(digit: usize) -> Vec<Vec<(f64, f64)>> {
    // control points traced from typical handwritten shapes
    let oval = vec![
        (0.50, 0.08),
        (0.78, 0.22),
        (0.82, 0.55),
        (0.68, 0.88),
        (0.42, 0.92),
        (0.20, 0.72),
        (0.18, 0.35),
        (0.34, 0.12),
        (0.50, 0.08),
    ];
    match digit {
        0 => vec![oval],
        1 => vec![vec![(0.35, 0.25), (0.55, 0.08), (0.55, 0.92)]],
        2 => vec![vec![
            (0.22, 0.28),
            (0.35, 0.10),
            (0.65, 0.10),
            (0.78, 0.30),
            (0.60, 0.55),
            (0.30, 0.78),
            (0.20, 0.92),
            (0.80, 0.92),
        ]],
        3 => vec![vec![
            (0.25, 0.15),
            (0.60, 0.08),
            (0.75, 0.25),
            (0.55, 0.45),
            (0.75, 0.65),
            (0.60, 0.90),
            (0.25, 0.85),
        ]],
        4 => vec![
            vec![(0.62, 0.92), (0.62, 0.08), (0.18, 0.62), (0.85, 0.62)],
        ],
        5 => vec![vec![
            (0.75, 0.10),
            (0.30, 0.10),
            (0.27, 0.45),
            (0.60, 0.42),
            (0.78, 0.62),
            (0.68, 0.88),
            (0.25, 0.90),
        ]],
        6 => vec![vec![
            (0.68, 0.10),
            (0.38, 0.30),
            (0.24, 0.60),
            (0.32, 0.86),
            (0.62, 0.90),
            (0.74, 0.68),
            (0.58, 0.52),
            (0.30, 0.60),
        ]],
        7 => vec![vec![(0.20, 0.12), (0.80, 0.12), (0.45, 0.92)]],
        8 => vec![
            vec![
                (0.50, 0.08),
                (0.70, 0.20),
                (0.62, 0.42),
                (0.38, 0.52),
                (0.28, 0.72),
                (0.44, 0.90),
                (0.64, 0.86),
                (0.70, 0.68),
                (0.42, 0.50),
                (0.32, 0.30),
                (0.50, 0.08),
            ],
        ],
        9 => vec![vec![
            (0.72, 0.32),
            (0.52, 0.10),
            (0.28, 0.22),
            (0.30, 0.46),
            (0.58, 0.50),
            (0.72, 0.32),
            (0.70, 0.60),
            (0.58, 0.92),
        ]],
        _ => unreachable!("digit out of range"),
    }
}

/// Render one digit sample into a dense `side*side` intensity image.
pub fn render_digit(digit: usize, side: usize, brush: f64, rng: &mut Rng) -> Vec<f32> {
    assert!(digit < 10);
    let mut img = vec![0.0f32; side * side];
    // random affine: rotation ±0.22 rad, scale 0.85..1.1, translation ±0.07
    let theta = rng.range_f64(-0.22, 0.22);
    let scale = rng.range_f64(0.85, 1.10);
    let (sin, cos) = theta.sin_cos();
    let tx = rng.range_f64(-0.07, 0.07);
    let ty = rng.range_f64(-0.07, 0.07);
    let jitter = 0.03;

    for stroke in skeleton(digit) {
        // jitter control points, then transform
        let pts: Vec<(f64, f64)> = stroke
            .iter()
            .map(|&(x, y)| {
                let (x, y) = (x + rng.normal_ms(0.0, jitter), y + rng.normal_ms(0.0, jitter));
                // center, rotate+scale, uncenter, translate
                let (cx, cy) = (x - 0.5, y - 0.5);
                let (rx, ry) = (cos * cx - sin * cy, sin * cx + cos * cy);
                (0.5 + scale * rx + tx, 0.5 + scale * ry + ty)
            })
            .collect();
        // walk each segment with a soft round brush
        for w in pts.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
            let steps = (len * side as f64 * 2.0).ceil().max(1.0) as usize;
            for s in 0..=steps {
                let t = s as f64 / steps as f64;
                let px = (x0 + t * (x1 - x0)) * side as f64;
                let py = (y0 + t * (y1 - y0)) * side as f64;
                stamp(&mut img, side, px, py, brush);
            }
        }
    }
    // normalize to max intensity 1 and quantize to 256 levels like 8-bit data
    let max = img.iter().cloned().fold(0.0f32, f32::max);
    if max > 0.0 {
        for p in &mut img {
            *p = ((*p / max) * 255.0).round() / 255.0;
        }
    }
    img
}

/// Accumulate a soft round brush at (px, py) (pixel coordinates).
fn stamp(img: &mut [f32], side: usize, px: f64, py: f64, brush: f64) {
    let r = brush.ceil() as i64 + 1;
    let (cx, cy) = (px.round() as i64, py.round() as i64);
    for dy in -r..=r {
        for dx in -r..=r {
            let (x, y) = (cx + dx, cy + dy);
            if x < 0 || y < 0 || x >= side as i64 || y >= side as i64 {
                continue;
            }
            let dist2 = (x as f64 - px).powi(2) + (y as f64 - py).powi(2);
            let w = (-dist2 / (brush * brush)).exp();
            if w > 0.05 {
                let slot = &mut img[(y as usize) * side + x as usize];
                *slot = slot.max(w as f32);
            }
        }
    }
}

/// Generate a labeled digit dataset with pixel-grid embeddings.
pub fn generate(config: &MnistConfig) -> Dataset {
    let mut rng = Rng::new(config.seed);
    let side = config.side;
    let mut hists = Vec::with_capacity(config.n);
    let mut labels = Vec::with_capacity(config.n);
    for i in 0..config.n {
        let digit = i % 10; // balanced classes, shuffled order via seed-fork
        let mut local = rng.fork(i as u64);
        let mut img = render_digit(digit, side, config.brush, &mut local);
        if config.background > 0.0 {
            let fg: f32 = img.iter().sum();
            let per_pixel = config.background * fg / (side * side) as f32;
            for p in &mut img {
                *p += per_pixel;
            }
        }
        hists.push(Histogram::from_dense(&img));
        labels.push(digit as u16);
    }
    Dataset::new(
        if config.background > 0.0 { "synth-mnist-bg" } else { "synth-mnist" },
        Embeddings::pixel_grid(side),
        &hists,
        labels,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_have_mnist_like_sparsity() {
        let ds = generate(&MnistConfig { n: 100, ..Default::default() });
        let s = ds.stats();
        assert_eq!(s.vocab_size, 784);
        // paper Table 4: MNIST average h = 149.9; accept a generous band
        assert!(s.avg_h > 60.0 && s.avg_h < 320.0, "avg_h = {}", s.avg_h);
        assert_eq!(s.classes, 10);
    }

    #[test]
    fn background_makes_histograms_dense() {
        let ds = generate(&MnistConfig { n: 20, background: 0.3, ..Default::default() });
        assert_eq!(ds.stats().avg_h, 784.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&MnistConfig { n: 10, ..Default::default() });
        let b = generate(&MnistConfig { n: 10, ..Default::default() });
        assert_eq!(a.matrix, b.matrix);
        let c = generate(&MnistConfig { n: 10, seed: 7, ..Default::default() });
        assert_ne!(a.matrix, c.matrix);
    }

    #[test]
    fn within_class_closer_than_between_class_on_average() {
        // the property every accuracy experiment rests on, checked with
        // exact EMD on a small sample
        use crate::core::Metric;
        use crate::exact::emd;
        let ds = generate(&MnistConfig { n: 30, side: 14, ..Default::default() });
        let mut within = Vec::new();
        let mut between = Vec::new();
        for u in 0..12 {
            for v in (u + 1)..12 {
                let d = emd(&ds.embeddings, &ds.histogram(u), &ds.histogram(v), Metric::L2);
                if ds.labels[u] == ds.labels[v] {
                    within.push(d);
                } else {
                    between.push(d);
                }
            }
        }
        let mw = within.iter().sum::<f64>() / within.len().max(1) as f64;
        let mb = between.iter().sum::<f64>() / between.len().max(1) as f64;
        assert!(mw < mb, "within {mw} !< between {mb}");
    }

    #[test]
    fn all_ten_digits_render_nonempty() {
        let mut rng = Rng::new(1);
        for d in 0..10 {
            let img = render_digit(d, 28, 1.1, &mut rng);
            let nz = img.iter().filter(|&&p| p > 0.0).count();
            assert!(nz > 30, "digit {d} rendered only {nz} pixels");
        }
    }
}
