//! Dataset substrates: synthetic MNIST-like digits, synthetic
//! 20-Newsgroups-like corpus, and binary persistence.

pub mod store;
pub mod synth_mnist;
pub mod synth_text;

pub use store::{load, save};
pub use synth_mnist::{generate as generate_mnist, MnistConfig};
pub use synth_text::{generate as generate_text, TextConfig};
