//! Binary dataset persistence (substrate: no serde/bincode offline).
//!
//! Format `EMD1` (little-endian):
//! ```text
//! magic "EMD1" | name_len u32 | name bytes
//! v u64 | m u64 | embeddings f32[v*m]
//! n u64 | labels u16[n]
//! indptr u64[n+1] | nnz u64 | indices u32[nnz] | data f32[nnz]
//! ```

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::core::{Dataset, Embeddings, EmdResult};

const MAGIC: &[u8; 4] = b"EMD1";

/// Save a dataset to a file.
pub fn save(ds: &Dataset, path: &Path) -> EmdResult<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;

    let v = ds.embeddings.num_vectors();
    let m = ds.embeddings.dim();
    w.write_all(&(v as u64).to_le_bytes())?;
    w.write_all(&(m as u64).to_le_bytes())?;
    write_f32s(&mut w, ds.embeddings.as_slice())?;

    let n = ds.len();
    w.write_all(&(n as u64).to_le_bytes())?;
    for &l in &ds.labels {
        w.write_all(&l.to_le_bytes())?;
    }

    // CSR arrays via row access (keeps CsrMatrix internals private)
    let mut indptr: Vec<u64> = Vec::with_capacity(n + 1);
    indptr.push(0);
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    for u in 0..n {
        let (idx, wgt) = ds.matrix.row(u);
        indices.extend_from_slice(idx);
        data.extend_from_slice(wgt);
        indptr.push(indices.len() as u64);
    }
    for &p in &indptr {
        w.write_all(&p.to_le_bytes())?;
    }
    w.write_all(&(indices.len() as u64).to_le_bytes())?;
    for &i in &indices {
        w.write_all(&i.to_le_bytes())?;
    }
    write_f32s(&mut w, &data)?;
    w.flush()?;
    Ok(())
}

/// Load a dataset from a file.
pub fn load(path: &Path) -> EmdResult<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic (not an EMD1 file)").into());
    }
    let name_len = read_u32(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad name"))?;

    let v = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let emb = read_f32s(&mut r, v * m)?;
    let embeddings = Embeddings::new(emb, v, m);

    let n = read_u64(&mut r)? as usize;
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        labels.push(u16::from_le_bytes(b));
    }

    let mut indptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        indptr.push(read_u64(&mut r)? as usize);
    }
    let nnz = read_u64(&mut r)? as usize;
    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        indices.push(u32::from_le_bytes(b));
    }
    let data = read_f32s(&mut r, nnz)?;

    // rebuild the CSR matrix directly: no re-normalization, weights
    // round-trip bit-exactly
    let matrix = crate::core::CsrMatrix::from_raw(indptr, indices, data, v);
    Ok(Dataset::from_csr(name, embeddings, matrix, labels))
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> io::Result<()> {
    // chunked conversion avoids a full-buffer copy
    let mut buf = Vec::with_capacity(4096 * 4);
    for chunk in xs.chunks(4096) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, count: usize) -> io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; count * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_text::{generate, TextConfig};

    #[test]
    fn roundtrip_preserves_everything() {
        let ds = generate(&TextConfig {
            n: 30,
            classes: 3,
            vocab: 100,
            dim: 8,
            doc_len: 20,
            ..Default::default()
        });
        let dir = std::env::temp_dir().join("emdpar_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ds.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.matrix, ds.matrix);
        assert_eq!(back.embeddings, ds.embeddings);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("emdpar_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPEnope").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
