//! Deterministic pseudo-random number generation (PCG-XSH-RR 64/32 core).
//!
//! The offline build environment has no `rand` crate, so this module is the
//! project's RNG substrate: a small, fast, seedable generator with the
//! distributions the data generators and property tests need.  Determinism
//! across platforms is part of the contract — every dataset, experiment and
//! property test is reproducible from its seed.

/// PCG-XSH-RR pseudo-random generator (O'Neill 2014), 64-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with an explicit stream selector; distinct streams
    /// from the same seed are independent (used to give each worker thread
    /// its own stream).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Rng { state: 0, inc, spare_normal: None };
        rng.state = inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-item determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2654435769) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mulwide(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k << n: rejection; else shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut seen = std::collections::BTreeSet::new();
        while seen.len() < k {
            seen.insert(self.below(n));
        }
        seen.into_iter().collect()
    }

    /// Draw an index according to non-negative weights (linear scan).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn mulwide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(1000, 20);
        assert_eq!(s.len(), 20);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        let s2 = r.sample_indices(10, 9);
        assert_eq!(s2.len(), 9);
        assert!(s2.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [0.05, 0.9, 0.05];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert!(c[1] > 8_300, "{c:?}");
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
