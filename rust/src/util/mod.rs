//! Utility substrates built in-repo because the offline build environment
//! has no crates.io access (no rand / serde / clap / rayon / criterion /
//! proptest / anyhow) — the default build is dependency-free; even the
//! `xla` crate is gated behind the `pjrt` feature.

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
