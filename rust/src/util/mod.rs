//! Utility substrates built in-repo because the offline build environment
//! only ships the `xla` crate's dependency closure (no rand / serde / clap /
//! rayon / criterion / proptest).

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
