//! Minimal JSON parser + serializer (substrate: no serde offline).
//!
//! Supports the full JSON grammar (RFC 8259) minus exotic number forms; used
//! for the artifact manifest, the config system, dataset metadata and the
//! coordinator's line protocol.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize one number exactly as the [`Json`] serializer does: integral
/// values below 1e15 print as integers, everything else as f64 `Display`.
/// Public so streaming writers (the serve wire layer) can emit bytes that
/// are bit-identical to a [`Json`] tree serialization.
pub fn write_number(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_number(out, *x),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_json(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, depth + 1);
            }
            if !map.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Write `s` as a JSON string literal (quotes + escapes), exactly as the
/// [`Json`] serializer does.  Public for the same reason as
/// [`write_number`]: streaming writers must match the tree codec bit for
/// bit.
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\té😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\té😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":-3,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", "emdpar".into()),
            ("k", 8usize.into()),
            ("xs", Json::arr_f64(&[1.0, 2.0])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn streaming_helpers_match_tree_codec() {
        // the serve wire layer leans on these matching the tree serializer
        // bit for bit
        for x in [0.0, -0.0, 1.0, -3.0, 0.5, -2.25, 1e-7, 1e15, 9.007e15, f64::NAN] {
            let mut s = String::new();
            write_number(&mut s, x);
            assert_eq!(s, Json::Num(x).to_string_compact(), "x={x}");
        }
        for text in ["plain", "with \"quotes\"", "tab\there", "uni é😀", "ctl\u{1}"] {
            let mut s = String::new();
            write_escaped(text, &mut s);
            assert_eq!(s, Json::Str(text.to_string()).to_string_compact());
        }
    }
}
