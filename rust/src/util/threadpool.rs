//! Data-parallel execution substrate (no rayon/tokio offline).
//!
//! Two tools:
//! * [`parallel_for`] — scoped fork-join over an index range with
//!   deterministic contiguous chunk assignment; this is what the LC engines
//!   use to data-parallelize over vocabulary rows / database documents (the
//!   role the GPU grid plays in the paper).
//! * [`ThreadPool`] — a long-lived pool with a job queue, used by the
//!   coordinator to decouple request handling from compute.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use: `EMDPAR_THREADS` env override, else
/// available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("EMDPAR_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on up to `threads`
/// workers.
///
/// Chunk assignment is **deterministic and contiguous**: worker `w` owns
/// exactly the range `[w·⌈n/threads⌉, min((w+1)·⌈n/threads⌉, n))` — one
/// chunk per worker, fixed before any worker starts, no atomic
/// chunk-stealing.  Contiguity matters on NUMA machines: each worker
/// touches one dense span of the input/output arrays, so first-touch page
/// placement and hardware prefetch both see a single forward stream per
/// core instead of the interleaved access pattern stealing produces, and a
/// given index range is processed by the same worker on every call with
/// the same `(n, threads)` — cache- and page-affinity survive across
/// sweeps.  The LC kernels' results are chunk-shape independent (each
/// index's value is computed by the same arithmetic wherever it lands), so
/// this is purely a locality/scheduling change — asserted by the
/// serial-vs-parallel equality tests below and the bit-identity suite.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        f(0, n);
        return;
    }
    let per = n.div_ceil(threads);
    let f = &f;
    thread::scope(|scope| {
        for w in 0..threads {
            let start = w * per;
            let end = ((w + 1) * per).min(n);
            if start >= end {
                break;
            }
            scope.spawn(move || f(start, end));
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(n, threads, |start, end| {
            for i in start..end {
                // SAFETY: each index is written by exactly one chunk owner.
                unsafe { slots.write(i, f(i)) };
            }
        });
    }
    out
}

/// Shared mutable slice wrapper for disjoint-index parallel writes.
///
/// SAFETY contract: callers must guarantee every index is written by at most
/// one thread.  `parallel_for`'s chunking provides that guarantee.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.  See the type-level SAFETY contract.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read one element.  SAFETY: the caller must guarantee no other thread
    /// writes index `i` concurrently (e.g. index-ownership partitions where
    /// each cell's reader is also its only potential writer).
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }

    /// Get a mutable sub-slice.  Caller must keep sub-slices disjoint.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A long-lived worker pool with a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Release);
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, queued }
    }

    /// Enqueue a job; returns the queue depth after enqueueing (for
    /// backpressure decisions by the caller).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) -> usize {
        let depth = self.queued.fetch_add(1, Ordering::Acquire) + 1;
        self.tx.as_ref().expect("pool shut down").send(Box::new(job)).expect("workers alive");
        depth
    }

    /// Jobs enqueued but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until all queued jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_range_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_and_single() {
        parallel_for(0, 4, |_, _| panic!("should not run"));
        let count = AtomicUsize::new(0);
        parallel_for(1, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunk_assignment_is_deterministic_and_contiguous() {
        // the boundaries of two identical runs must match exactly, cover
        // 0..n without gaps or overlap, and follow the ⌈n/threads⌉ formula
        let run = || {
            let chunks = Mutex::new(Vec::new());
            parallel_for(103, 4, |s, e| {
                chunks.lock().unwrap().push((s, e));
            });
            let mut v = chunks.into_inner().unwrap();
            v.sort_unstable();
            v
        };
        let a = run();
        assert_eq!(a, run(), "same (n, threads) must yield the same chunks");
        let mut expect_start = 0;
        for &(s, e) in &a {
            assert_eq!(s, expect_start, "chunks must tile the range in order");
            assert!(e > s);
            expect_start = e;
        }
        assert_eq!(expect_start, 103);
        // ⌈103/4⌉ = 26
        assert_eq!(a, vec![(0, 26), (26, 52), (52, 78), (78, 103)]);
    }

    #[test]
    fn parallel_results_match_serial_bitwise() {
        // chunk shape never reaches into per-index arithmetic: any thread
        // count gives the serial result exactly
        let xs: Vec<f32> = (0..517).map(|i| (i as f32).sin()).collect();
        let serial = parallel_map(xs.len(), 1, |i| xs[i] * 3.0 + 1.0);
        for threads in [2usize, 3, 5, 8, 16] {
            assert_eq!(parallel_map(xs.len(), threads, |i| xs[i] * 3.0 + 1.0), serial);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 8, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let xs: Vec<u64> = (0..10_000).collect();
        let total = AtomicU64::new(0);
        parallel_for(xs.len(), 6, |s, e| {
            let part: u64 = xs[s..e].iter().sum();
            total.fetch_add(part, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), xs.iter().sum::<u64>());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // must join, not detach
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }
}
