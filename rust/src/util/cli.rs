//! Declarative CLI argument parser (substrate: no clap offline).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, typed
//! accessors with defaults, required options, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub required: bool,
    pub help: &'static str,
}

/// Specification of a (sub)command.
#[derive(Debug, Clone, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: false, default: None, required: false, help });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: true,
            default: Some(default),
            required: false,
            help,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, default: None, required: true, help });
        self
    }

    fn find(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    pub fn usage(&self, program: &str) -> String {
        let mut s = format!("{}\n\nUsage: {} {} [options]\n\nOptions:\n", self.about, program, self.name);
        for o in &self.opts {
            let lhs = if o.takes_value {
                format!("--{} <value>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let default = match o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ if o.required => " [required]".to_string(),
                _ => String::new(),
            };
            s.push_str(&format!("  {lhs:<28} {}{}\n", o.help, default));
        }
        s
    }

    /// Parse an argument list (without program/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .find(name)
                    .ok_or_else(|| CliError(format!("unknown option --{name} (try --help)")))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("--{name} expects a value")))?
                            .clone(),
                    };
                    values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} does not take a value")));
                    }
                    flags.push(name.to_string());
                }
            } else {
                positional.push(arg.clone());
            }
        }
        for o in &self.opts {
            if o.required && !values.contains_key(o.name) {
                return Err(CliError(format!("missing required option --{}", o.name)));
            }
            if let (true, Some(d)) = (o.takes_value, o.default) {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Parsed { values, flags, positional })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer, got '{}'", self.str(name))))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected a number, got '{}'", self.str(name))))
    }

    /// Comma-separated list of integers, e.g. `--ks 1,2,8`.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad integer '{s}'")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("search", "run a search")
            .opt("k", "8", "transfer iterations")
            .opt("ks", "1,2", "list")
            .req("dataset", "dataset path")
            .flag("background", "include background pixels")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let p = spec().parse(&args(&["--dataset", "d.bin"])).unwrap();
        assert_eq!(p.usize("k").unwrap(), 8);
        assert_eq!(p.str("dataset"), "d.bin");
        assert!(!p.flag("background"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&args(&[])).is_err());
    }

    #[test]
    fn equals_form_and_flags() {
        let p = spec().parse(&args(&["--dataset=x", "--k=3", "--background"])).unwrap();
        assert_eq!(p.usize("k").unwrap(), 3);
        assert!(p.flag("background"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&args(&["--nope", "--dataset", "x"])).is_err());
    }

    #[test]
    fn list_parsing() {
        let p = spec().parse(&args(&["--dataset", "x", "--ks", "1,2,8,16"])).unwrap();
        assert_eq!(p.usize_list("ks").unwrap(), vec![1, 2, 8, 16]);
    }

    #[test]
    fn bad_number_is_error() {
        let p = spec().parse(&args(&["--dataset", "x", "--k", "abc"])).unwrap();
        assert!(p.usize("k").is_err());
    }

    #[test]
    fn positional_collected() {
        let p = spec().parse(&args(&["--dataset", "x", "query.png"])).unwrap();
        assert_eq!(p.positional, vec!["query.png".to_string()]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = spec().usage("emdpar");
        assert!(u.contains("--dataset"));
        assert!(u.contains("[default: 8]"));
        assert!(u.contains("[required]"));
    }
}
