//! Measurement substrate: summary statistics and a micro-benchmark harness
//! (no criterion offline).  `cargo bench` targets use [`Bench`] with
//! `harness = false`.

use std::time::{Duration, Instant};

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Benchmark result for one case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub per_iter: Duration,
    pub summary: Summary, // per-iteration seconds
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.per_iter.as_secs_f64()
    }
}

/// Micro-benchmark runner: warmup, then timed samples.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    pub min_iters: usize,
    pub target_sample_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Bench {
            warmup: 1,
            samples: 5,
            min_iters: 1,
            target_sample_time: Duration::from_millis(200),
            results: Vec::new(),
        }
    }

    pub fn quick() -> Self {
        Bench {
            warmup: 1,
            samples: 3,
            min_iters: 1,
            target_sample_time: Duration::from_millis(50),
            results: Vec::new(),
        }
    }

    /// Time `f`, auto-calibrating the iteration count per sample.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        // calibrate: how many iters fill the target sample time?
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.target_sample_time.as_secs_f64() / once.as_secs_f64()).ceil() as usize)
            .clamp(self.min_iters, 1_000_000);

        let mut per_iter_secs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter_secs.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let summary = Summary::from(&per_iter_secs);
        let result = BenchResult {
            name: name.to_string(),
            iters,
            per_iter: Duration::from_secs_f64(summary.p50),
            summary,
        };
        println!(
            "bench {:<44} {:>12}   (p50 of {} samples x {} iters; ±{:.1}%)",
            result.name,
            fmt_duration(result.per_iter),
            self.samples,
            iters,
            100.0 * result.summary.std / result.summary.mean.max(1e-300),
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Measure one closure once, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.per_iter.as_secs_f64() >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_duration(Duration::from_nanos(5)).ends_with("ns"));
    }

    #[test]
    fn timed_returns_value() {
        let (v, d) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
