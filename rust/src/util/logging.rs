//! Leveled logger with monotonic timestamps (substrate: no `log`/`env_logger`
//! runtime wiring needed; `EMDPAR_LOG` selects the level).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: OnceLock<Instant> = OnceLock::new();

/// Parse one `EMDPAR_LOG` value; `Err` carries the warning emitted for an
/// invalid setting, naming the bad value and the accepted levels.
fn parse_env_value(s: &str) -> Result<Level, String> {
    Level::from_str(s).ok_or_else(|| {
        format!(
            "ignoring invalid EMDPAR_LOG={s:?}; accepted levels: \
             error, warn, info, debug, trace"
        )
    })
}

/// Initialize from the `EMDPAR_LOG` environment variable (idempotent).
/// An unrecognized value keeps the current level and warns instead of
/// silently doing nothing.
pub fn init_from_env() {
    START.get_or_init(Instant::now);
    if let Ok(s) = std::env::var("EMDPAR_LOG") {
        match parse_env_value(&s) {
            Ok(l) => set_level(l),
            Err(msg) => log(Level::Warn, "emdpar::log", &msg),
        }
    }
}

pub fn set_level(l: Level) {
    START.get_or_init(Instant::now);
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), l.tag(), target, msg);
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::from_str("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn invalid_env_value_warns_with_the_bad_value_and_the_accepted_levels() {
        assert_eq!(parse_env_value("Trace"), Ok(Level::Trace));
        let msg = parse_env_value("verbose").unwrap_err();
        assert!(msg.contains("\"verbose\""), "{msg}");
        for level in ["error", "warn", "info", "debug", "trace"] {
            assert!(msg.contains(level), "missing {level} in {msg}");
        }
    }
}
