//! Property-testing mini-framework (substrate: no proptest offline).
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it for
//! many derived seeds and reports the failing seed so a failure reproduces
//! with `check_seed`.  Used by the solver and coordinator test-suites for
//! the Theorem-2 chain, LC-engine equivalence, flow conservation, etc.

use super::rng::Rng;

/// Outcome of a property over one random case.
pub enum Prop {
    /// Property held.
    Ok,
    /// Property failed with an explanation.
    Fail(String),
    /// Case was rejected (precondition not met); not counted.
    Discard,
}

/// Run `prop` over `cases` seeds derived from `base_seed`.  Panics with the
/// failing seed + message on the first failure.
pub fn check<F: FnMut(&mut Rng) -> Prop>(name: &str, base_seed: u64, cases: usize, mut prop: F) {
    let mut ran = 0usize;
    let mut attempts = 0usize;
    let max_attempts = cases * 20;
    while ran < cases && attempts < max_attempts {
        let seed = base_seed.wrapping_add(attempts as u64).wrapping_mul(0x9E3779B97F4A7C15);
        attempts += 1;
        let mut rng = Rng::new(seed);
        match prop(&mut rng) {
            Prop::Ok => ran += 1,
            Prop::Discard => continue,
            Prop::Fail(msg) => {
                panic!("property '{name}' failed (attempt {attempts}, seed {seed:#x}): {msg}")
            }
        }
    }
    assert!(
        ran >= cases,
        "property '{name}': too many discards ({ran}/{cases} ran in {attempts} attempts)"
    );
}

/// Re-run a single case with an explicit seed (reproduce a failure).
pub fn check_seed<F: FnMut(&mut Rng) -> Prop>(name: &str, seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    if let Prop::Fail(msg) = prop(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Assert-style helper: turn a boolean + message into a [`Prop`].
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Prop {
    if cond {
        Prop::Ok
    } else {
        Prop::Fail(msg())
    }
}

/// Chain several sub-checks; first failure wins.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return $crate::util::prop::Prop::Fail(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-ok", 1, 50, |_rng| {
            count += 1;
            Prop::Ok
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 2, 10, |rng| {
            let x = rng.f64();
            ensure(x < 0.9, || format!("x = {x}"))
        });
    }

    #[test]
    fn discards_do_not_count() {
        let mut ran = 0;
        check("half-discarded", 3, 20, |rng| {
            if rng.chance(0.5) {
                return Prop::Discard;
            }
            ran += 1;
            Prop::Ok
        });
        assert_eq!(ran, 20);
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn all_discards_is_an_error() {
        check("all-discarded", 4, 10, |_| Prop::Discard);
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first = Vec::new();
        check("record", 5, 5, |rng| {
            first.push(rng.next_u64());
            Prop::Ok
        });
        let mut second = Vec::new();
        check("record", 5, 5, |rng| {
            second.push(rng.next_u64());
            Prop::Ok
        });
        assert_eq!(first, second);
    }
}
