//! # emdpar — low-complexity data-parallel Earth Mover's Distance approximations
//!
//! Rust + JAX/Pallas reproduction of Atasu & Mittelholzer, *"Low-Complexity
//! Data-Parallel Earth Mover's Distance Approximations"* (ICML 2019): the
//! OMR / ICT / ACT lower bounds on EMD and the linear-complexity batched
//! LC-RWMD / LC-ACT similarity-search pipeline.
//!
//! Start with [`prelude`]: it re-exports the unified distance API — the
//! canonical [`core::Method`] enum, the [`core::Distance`] /
//! [`core::BatchDistance`] traits, the [`core::MethodRegistry`] that maps
//! every method (including Sinkhorn and exact EMD) to boxed trait objects,
//! the crate-wide [`core::EmdError`], and the [`builder::EngineBuilder`]
//! that assembles the engine stack (dataset → params → backend → build).
//!
//! Layering (see DESIGN.md):
//! * [`core`] — histograms, vocabulary embeddings, CSR database matrix,
//!   and the unified distance API (`Method`, `Distance`, `BatchDistance`,
//!   `MethodRegistry`, `EmdError`).
//! * [`exact`] — exact EMD (min-cost-flow) ground truth.
//! * [`approx`] — per-pair approximations: BoW-adjusted, RWMD, OMR, ICT,
//!   ACT, Sinkhorn, BoW cosine, WCD.
//! * [`lc`] — the paper's contribution: linear-complexity data-parallel
//!   LC-RWMD / LC-ACT engines (multithreaded CPU), with per-pair fallback
//!   so every method serves through one interface.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); gated behind the `pjrt` feature.
//! * [`index`] — IVF pruning index over document WCD centroids: sublinear
//!   candidate selection in front of the LC engines (`EMDX` persistence).
//! * [`shard`] — sharded live corpus: per-shard engines + IVF behind a
//!   fan-out / top-ℓ-merge route, incremental ingestion, `EMDX` v2
//!   manifest persistence.
//! * [`coordinator`] — the serving layer: the query planner
//!   (`SearchRequest` → `QueryPlan` → `SearchResponse`), batching,
//!   sharding, cascades, index-pruned top-ℓ search.
//! * [`serve`] — the async serving runtime: poll(2) event-loop reactors,
//!   admission control with deadlines, and a zero-copy wire path; the
//!   legacy thread-per-connection `Server` stays as a compatibility shim.
//! * [`remote`] — distributed corpus: `emdpar node` shard servers over
//!   dataset slices, a topology manifest, and the hedged, deadline-aware
//!   fan-out RPC client the coordinator merges bit-identically.
//! * [`obs`] — observability: the lock-free span tracer every execute
//!   path records into, Chrome trace-event export, and Prometheus text
//!   exposition (`metrics`/`trace` wire ops, `--metrics-addr`).
//! * [`builder`] — `EngineBuilder`, the one place configuration becomes
//!   running engines.
//! * [`data`] — synthetic MNIST-like / 20News-like dataset generators.
//! * [`eval`] — precision@top-ℓ evaluation and experiment harness.

pub mod approx;
pub mod builder;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod eval;
pub mod exact;
pub mod index;
pub mod lc;
pub mod obs;
pub mod remote;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod util;

/// The unified API surface: everything needed to select a method, build an
/// engine, and run searches.
pub mod prelude {
    pub use crate::builder::EngineBuilder;
    pub use crate::config::{
        Backend, Config, DatasetSpec, IndexParams, RemoteParams, ServeParams, ShardParams,
    };
    pub use crate::coordinator::{
        cascade_search, cascade_search_pruned, CascadeResult, CascadeSpec, QueryPlan, QueryStats,
        SearchEngine, SearchRequest, SearchResponse, SearchResult, Server, Stage,
    };
    pub use crate::core::{
        BatchDistance, CompressedKind, Dataset, Distance, EmdError, EmdResult, Embeddings,
        F16Tier, Histogram, Method, MethodRegistry, Metric, METHOD_SYNTAX,
    };
    pub use crate::index::{pruned_search, pruned_search_batch, IvfIndex, PrunedSearch};
    pub use crate::obs::{SpanName, SpanRec, TraceCollector, TraceSession};
    pub use crate::remote::{spawn_node, NodeHandle, RemoteFleet, Topology};
    pub use crate::serve::ReactorServer;
    pub use crate::lc::{
        BatchPlanner, EngineParams, KernelBackend, LcBatch, LcEngine, PlanScratch,
    };
    pub use crate::shard::{AppendOutcome, ShardStat, ShardedCorpus, ShardedSearch};
}
