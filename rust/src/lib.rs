//! # emdpar — low-complexity data-parallel Earth Mover's Distance approximations
//!
//! Rust + JAX/Pallas reproduction of Atasu & Mittelholzer, *"Low-Complexity
//! Data-Parallel Earth Mover's Distance Approximations"* (ICML 2019): the
//! OMR / ICT / ACT lower bounds on EMD and the linear-complexity batched
//! LC-RWMD / LC-ACT similarity-search pipeline.
//!
//! Layering (see DESIGN.md):
//! * [`core`] — histograms, vocabulary embeddings, CSR database matrix.
//! * [`exact`] — exact EMD (min-cost-flow) ground truth.
//! * [`approx`] — per-pair approximations: RWMD, OMR, ICT, ACT, Sinkhorn,
//!   BoW cosine, WCD.
//! * [`lc`] — the paper's contribution: linear-complexity data-parallel
//!   LC-RWMD / LC-ACT engines (multithreaded CPU).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the serving layer: batching, sharding, top-ℓ search.
//! * [`data`] — synthetic MNIST-like / 20News-like dataset generators.
//! * [`eval`] — precision@top-ℓ evaluation and experiment harness.

pub mod approx;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod eval;
pub mod exact;
pub mod lc;
pub mod runtime;
pub mod util;
