//! Artifact-backed LC engine: the same Phase-1 / Phase-2 pipeline as
//! [`crate::lc`], but executed from the AOT-compiled JAX/Pallas HLO via
//! PJRT.  Shapes are static per artifact, so queries and database shards
//! are padded/tiled to the manifest's menu:
//!
//! * vocabulary rows beyond the dataset's v: zero coordinates — harmless,
//!   their X columns are always zero;
//! * query bins beyond h: coordinates pushed `PAD_OFFSET` away with weight
//!   0, so they never enter a real top-k (enforced by `k <= h_real`);
//! * database rows beyond n: zero rows, cost exactly 0, trimmed on return.

use crate::core::{Dataset, EmdError, EmdResult, Histogram};
use crate::emd_ensure;

use super::executor::Executor;
use super::manifest::Entry;

/// Far-away coordinate for padded query bins.
const PAD_OFFSET: f32 = 1.0e4;

/// A dataset bound to an artifact profile, with densified tiles.
pub struct ArtifactEngine<'a> {
    exec: &'a Executor,
    dataset: &'a Dataset,
    profile: String,
    /// padded vocabulary buffer (v_art * m)
    v_buf: Vec<f32>,
    /// densified database tiles, each (n_art * v_art)
    tiles: Vec<Vec<f32>>,
    pub v_art: usize,
    pub h_art: usize,
    pub n_art: usize,
    pub m: usize,
}

impl<'a> ArtifactEngine<'a> {
    /// Bind `dataset` to `profile` artifacts from `exec`'s manifest.
    pub fn new(exec: &'a Executor, dataset: &'a Dataset, profile: &str) -> EmdResult<Self> {
        let spec = exec
            .manifest()
            .artifacts
            .values()
            .find(|a| a.profile == profile && a.entry == Entry::Fused)
            .ok_or_else(|| EmdError::artifact(format!("profile '{profile}' not in manifest")))?;
        let (v_art, h_art, n_art, m) = (spec.v, spec.h, spec.n, spec.m);
        let v = dataset.embeddings.num_vectors();
        emd_ensure!(v <= v_art, artifact, "dataset vocab {v} exceeds artifact v {v_art}");
        emd_ensure!(
            dataset.embeddings.dim() == m,
            artifact,
            "dataset dim {} != artifact m {m}",
            dataset.embeddings.dim()
        );

        // padded vocabulary (zero rows beyond v)
        let mut v_buf = vec![0.0f32; v_art * m];
        v_buf[..v * m].copy_from_slice(dataset.embeddings.as_slice());

        // densified database tiles
        let n = dataset.len();
        let tiles_needed = n.div_ceil(n_art);
        let mut tiles = Vec::with_capacity(tiles_needed);
        for t in 0..tiles_needed {
            let start = t * n_art;
            let end = start + n_art;
            let mut tile = vec![0.0f32; n_art * v_art];
            // scatter CSR rows into the padded-width tile
            for (r, u) in (start..end.min(n)).enumerate() {
                let (idx, w) = dataset.matrix.row(u);
                let row = &mut tile[r * v_art..(r + 1) * v_art];
                for (&i, &x) in idx.iter().zip(w) {
                    row[i as usize] = x;
                }
            }
            tiles.push(tile);
        }

        Ok(ArtifactEngine {
            exec,
            dataset,
            profile: profile.to_string(),
            v_buf,
            tiles,
            v_art,
            h_art,
            n_art,
            m,
        })
    }

    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Pad a query histogram to (h_art) coordinates + weights.
    fn pad_query(&self, query: &Histogram) -> EmdResult<(Vec<f32>, Vec<f32>, usize)> {
        let qn = query.normalized();
        let h = qn.len();
        emd_ensure!(h > 0, artifact, "empty query");
        emd_ensure!(h <= self.h_art, artifact, "query h {h} exceeds artifact h {}", self.h_art);
        let mut q_buf = vec![PAD_OFFSET; self.h_art * self.m];
        let mut qw_buf = vec![0.0f32; self.h_art];
        for (j, (i, w)) in qn.iter().enumerate() {
            q_buf[j * self.m..(j + 1) * self.m]
                .copy_from_slice(self.dataset.embeddings.row(i as usize));
            qw_buf[j] = w;
        }
        Ok((q_buf, qw_buf, h))
    }

    /// ACT-(k-1) direction-A bounds for every database row, via the
    /// phase1-once + phase2-per-tile artifact pipeline.  With `symmetric`,
    /// also runs the direction-B RWMD artifact and takes the max.
    pub fn distances(&self, query: &Histogram, k: usize, symmetric: bool) -> EmdResult<Vec<f32>> {
        let (q_buf, qw_buf, h_real) = self.pad_query(query)?;
        emd_ensure!(
            k <= h_real,
            artifact,
            "k={k} exceeds query support {h_real}; padded bins would enter the top-k"
        );
        let p1 = self
            .exec
            .manifest()
            .find(&self.profile, Entry::Phase1, k)
            .ok_or_else(|| {
                EmdError::artifact(format!("no phase1 artifact for profile {} k={k}", self.profile))
            })?
            .name
            .clone();
        let p2 = self
            .exec
            .manifest()
            .find(&self.profile, Entry::Phase2, k)
            .ok_or_else(|| {
                EmdError::artifact(format!("no phase2 artifact for profile {} k={k}", self.profile))
            })?
            .name
            .clone();

        let outs = self.exec.run(
            &p1,
            &[
                (&self.v_buf, &[self.v_art, self.m]),
                (&q_buf, &[self.h_art, self.m]),
                (&qw_buf, &[self.h_art]),
            ],
        )?;
        let (d, z, w) = (&outs[0], &outs[1], &outs[2]);

        let n = self.dataset.len();
        let mut result = Vec::with_capacity(n);
        for (t, tile) in self.tiles.iter().enumerate() {
            let ta = self.exec.run1(
                &p2,
                &[
                    (tile, &[self.n_art, self.v_art]),
                    (&z.data, &[self.v_art, k]),
                    (&w.data, &[self.v_art, k]),
                ],
            )?;
            let take = (n - t * self.n_art).min(self.n_art);
            result.extend_from_slice(&ta.data[..take]);
        }

        if symmetric {
            let rb = self
                .exec
                .manifest()
                .find(&self.profile, Entry::RwmdB, 1)
                .ok_or_else(|| {
                    EmdError::artifact(format!("no rwmd_b artifact for profile {}", self.profile))
                })?
                .name
                .clone();
            let mut pos = 0usize;
            for tile in &self.tiles {
                let tb = self.exec.run1(
                    &rb,
                    &[
                        (tile, &[self.n_art, self.v_art]),
                        (&d.data, &[self.v_art, self.h_art]),
                        (&qw_buf, &[self.h_art]),
                    ],
                )?;
                let take = (n - pos).min(self.n_art);
                for (slot, &b) in result[pos..pos + take].iter_mut().zip(&tb.data[..take]) {
                    if b > *slot {
                        *slot = b;
                    }
                }
                pos += take;
            }
        }
        Ok(result)
    }

    /// Single-call fused pipeline on the first tile only — used by the
    /// quickstart and by equivalence tests.
    pub fn distances_fused_tile(
        &self,
        query: &Histogram,
        k: usize,
        tile: usize,
    ) -> EmdResult<(Vec<f32>, Vec<f32>)> {
        let (q_buf, qw_buf, h_real) = self.pad_query(query)?;
        emd_ensure!(k <= h_real, artifact, "k={k} exceeds query support {h_real}");
        let fused = self
            .exec
            .manifest()
            .find(&self.profile, Entry::Fused, k)
            .ok_or_else(|| {
                EmdError::artifact(format!("no fused artifact for profile {} k={k}", self.profile))
            })?
            .name
            .clone();
        let outs = self.exec.run(
            &fused,
            &[
                (&self.v_buf, &[self.v_art, self.m]),
                (&q_buf, &[self.h_art, self.m]),
                (&qw_buf, &[self.h_art]),
                (&self.tiles[tile], &[self.n_art, self.v_art]),
            ],
        )?;
        Ok((outs[0].data.clone(), outs[1].data.clone()))
    }
}
