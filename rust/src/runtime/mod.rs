//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute the LC-ACT pipeline from Rust.
//! Python never runs on the request path — `make artifacts` is the only
//! Python invocation, at build time.

pub mod engine;
pub mod executor;
pub mod manifest;

pub use engine::ArtifactEngine;
pub use executor::{Executor, Tensor};
pub use manifest::{ArtifactSpec, Entry, Manifest};
