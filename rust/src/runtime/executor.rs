//! PJRT executor: load HLO-text artifacts, compile them once on the CPU
//! client, and run them with f32 buffers.
//!
//! This is the only module that touches the `xla` crate, and the dependency
//! is gated behind the **`pjrt` cargo feature** so the default build is
//! dependency-free (the driver/CI environment has no crates.io access).
//! Without the feature, [`Executor::new`] validates the artifact directory
//! and then reports [`crate::core::EmdError::Artifact`]; every caller in
//! the stack already degrades gracefully (skips the artifact path with a
//! message).  To use the real runtime, add the vendored `xla` crate as a
//! dependency and build with `--features pjrt`.
//!
//! HLO **text** is the interchange format (`HloModuleProto::from_text_file`
//! reassigns instruction ids; serialized jax>=0.5 protos are rejected by
//! xla_extension 0.5.1 — see DESIGN.md).

use crate::core::{EmdError, EmdResult};

use super::manifest::{ArtifactSpec, Manifest};

/// An f32 tensor result from an artifact execution.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use super::{ArtifactSpec, EmdError, EmdResult, Manifest, Tensor};

    /// A compiled-executable cache over one PJRT client.
    pub struct Executor {
        client: xla::PjRtClient,
        manifest: Manifest,
        compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Executor {
        /// Create a CPU PJRT client and attach the artifact manifest.
        pub fn new(artifact_dir: &Path) -> EmdResult<Executor> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| EmdError::artifact(format!("creating PJRT CPU client: {e}")))?;
            Ok(Executor { client, manifest, compiled: Mutex::new(HashMap::new()) })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an artifact by name.
        fn ensure_compiled(&self, name: &str) -> EmdResult<()> {
            {
                let cache = self.compiled.lock().unwrap();
                if cache.contains_key(name) {
                    return Ok(());
                }
            }
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| EmdError::artifact(format!("unknown artifact '{name}'")))?;
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| EmdError::artifact(format!("non-utf8 artifact path {:?}", spec.file)))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| EmdError::artifact(format!("parsing HLO text {path}: {e}")))?;
            let computation = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&computation)
                .map_err(|e| EmdError::artifact(format!("compiling artifact '{name}': {e}")))?;
            self.compiled.lock().unwrap().insert(name.to_string(), exe);
            Ok(())
        }

        /// Number of artifacts compiled so far (diagnostics).
        pub fn compiled_count(&self) -> usize {
            self.compiled.lock().unwrap().len()
        }

        /// Execute an artifact on f32 inputs.  `inputs` are (data, dims)
        /// pairs matching the manifest's declared parameter order; returns
        /// the output tuple decomposed into tensors.
        pub fn run(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> EmdResult<Vec<Tensor>> {
            self.ensure_compiled(name)?;
            let spec = &self.manifest.artifacts[name];

            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = xla::Literal::vec1(data);
                    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims_i64)
                        .map_err(|e| EmdError::artifact(format!("reshaping input to {dims:?}: {e}")))
                })
                .collect::<EmdResult<_>>()?;

            let cache = self.compiled.lock().unwrap();
            let exe = &cache[name];
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| EmdError::artifact(format!("executing '{name}': {e}")))?;
            let mut out_lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| EmdError::artifact(format!("copying result to host: {e}")))?;
            drop(cache);

            // aot.py lowers with return_tuple=True: always a tuple, even arity 1
            let parts = out_lit
                .decompose_tuple()
                .map_err(|e| EmdError::artifact(format!("decomposing result tuple: {e}")))?;
            if parts.len() != spec.entry.arity() {
                return Err(EmdError::artifact(format!(
                    "artifact '{name}' returned {} outputs, manifest says {}",
                    parts.len(),
                    spec.entry.arity()
                )));
            }
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit
                        .array_shape()
                        .map_err(|e| EmdError::artifact(format!("result shape: {e}")))?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit
                        .to_vec::<f32>()
                        .map_err(|e| EmdError::artifact(format!("result to_vec: {e}")))?;
                    Ok(Tensor { data, dims })
                })
                .collect()
        }

        /// Convenience: run and require exactly one output.
        pub fn run1(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> EmdResult<Tensor> {
            let mut out = self.run(name, inputs)?;
            if out.len() != 1 {
                return Err(EmdError::artifact(format!("expected 1 output, got {}", out.len())));
            }
            Ok(out.remove(0))
        }

        /// Direct access to an artifact spec.
        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.manifest.artifacts.get(name)
        }
    }

    // PJRT client handles are internally synchronized; the Mutex above
    // guards only our cache map.
    unsafe impl Sync for Executor {}
    unsafe impl Send for Executor {}
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use super::{ArtifactSpec, EmdError, EmdResult, Manifest, Tensor};

    const UNAVAILABLE: &str =
        "PJRT runtime not compiled in: rebuild with `--features pjrt` (requires the `xla` crate)";

    /// Offline stub: validates the artifact directory, then reports the
    /// runtime as unavailable.  Keeps the public surface identical so the
    /// rest of the stack compiles unchanged.
    pub struct Executor {
        manifest: Manifest,
    }

    impl Executor {
        pub fn new(artifact_dir: &Path) -> EmdResult<Executor> {
            // still surface manifest problems first — the more actionable error
            let _manifest = Manifest::load(artifact_dir)?;
            Err(EmdError::artifact(UNAVAILABLE))
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn compiled_count(&self) -> usize {
            0
        }

        pub fn run(&self, _name: &str, _inputs: &[(&[f32], &[usize])]) -> EmdResult<Vec<Tensor>> {
            Err(EmdError::artifact(UNAVAILABLE))
        }

        pub fn run1(&self, _name: &str, _inputs: &[(&[f32], &[usize])]) -> EmdResult<Tensor> {
            Err(EmdError::artifact(UNAVAILABLE))
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.manifest.artifacts.get(name)
        }
    }
}

pub use imp::Executor;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_dir_is_an_error_not_a_panic() {
        let dir = std::env::temp_dir().join("emdpar_no_artifacts_here");
        std::fs::remove_dir_all(&dir).ok();
        let Err(err) = Executor::new(&dir) else {
            panic!("must fail without artifacts");
        };
        assert!(matches!(err, EmdError::Artifact(_)), "{err}");
    }
}
