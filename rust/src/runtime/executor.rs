//! PJRT executor: load HLO-text artifacts, compile them once on the CPU
//! client, and run them with f32 buffers.
//!
//! This is the only module that touches the `xla` crate.  HLO **text** is
//! the interchange format (`HloModuleProto::from_text_file` reassigns
//! instruction ids; serialized jax>=0.5 protos are rejected by
//! xla_extension 0.5.1 — see DESIGN.md).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};

/// A compiled-executable cache over one PJRT client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

/// An f32 tensor result from an artifact execution.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Executor {
    /// Create a CPU PJRT client and attach the artifact manifest.
    pub fn new(artifact_dir: &Path) -> Result<Executor> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Executor { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        {
            let cache = self.compiled.lock().unwrap();
            if cache.contains_key(name) {
                return Ok(());
            }
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", spec.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&computation)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.compiled.lock().unwrap().insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of artifacts compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }

    /// Execute an artifact on f32 inputs.  `inputs` are (data, dims) pairs
    /// matching the manifest's declared parameter order; returns the output
    /// tuple decomposed into tensors.
    pub fn run(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let spec = &self.manifest.artifacts[name];

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64)
                    .with_context(|| format!("reshaping input to {dims:?}"))
            })
            .collect::<Result<_>>()?;

        let cache = self.compiled.lock().unwrap();
        let exe = &cache[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{name}'"))?;
        let mut out_lit = result[0][0]
            .to_literal_sync()
            .context("copying result to host")?;
        drop(cache);

        // aot.py lowers with return_tuple=True: always a tuple, even arity 1
        let parts = out_lit.decompose_tuple().context("decomposing result tuple")?;
        anyhow::ensure!(
            parts.len() == spec.entry.arity(),
            "artifact '{name}' returned {} outputs, manifest says {}",
            parts.len(),
            spec.entry.arity()
        );
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("result shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().context("result to_vec")?;
                Ok(Tensor { data, dims })
            })
            .collect()
    }

    /// Convenience: run and require exactly one output.
    pub fn run1(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Tensor> {
        let mut out = self.run(name, inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.remove(0))
    }

    /// Direct access to an artifact spec.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.artifacts.get(name)
    }
}

// PJRT client handles are internally synchronized; the Mutex above guards
// only our cache map.
unsafe impl Sync for Executor {}
unsafe impl Send for Executor {}
