//! Artifact manifest: the shape menu `python/compile/aot.py` emits next to
//! the HLO text files (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::core::{EmdError, EmdResult};
use crate::util::json::Json;

/// Which pipeline stage an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Entry {
    /// (V, Q, qw) -> (D, Z, W)
    Phase1,
    /// (X, Z, W) -> t
    Phase2,
    /// (V, Q, qw, X) -> (t_a, t_b)
    Fused,
    /// (X, D, qw) -> t
    RwmdB,
}

impl Entry {
    fn parse(s: &str) -> EmdResult<Entry> {
        match s {
            "phase1" => Ok(Entry::Phase1),
            "phase2" => Ok(Entry::Phase2),
            "fused" => Ok(Entry::Fused),
            "rwmd_b" => Ok(Entry::RwmdB),
            other => {
                Err(EmdError::parse("artifact entry kind", other, "phase1 | phase2 | fused | rwmd_b"))
            }
        }
    }

    /// Number of outputs in the result tuple.
    pub fn arity(self) -> usize {
        match self {
            Entry::Phase1 => 3,
            Entry::Phase2 | Entry::RwmdB => 1,
            Entry::Fused => 2,
        }
    }
}

/// One artifact's static configuration.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub entry: Entry,
    pub profile: String,
    pub file: PathBuf,
    pub v: usize,
    pub h: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> EmdResult<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            EmdError::artifact(format!("reading {path:?} (run `make artifacts`): {e}"))
        })?;
        let json =
            Json::parse(&text).map_err(|e| EmdError::json(format!("parsing {path:?}: {e}")))?;
        if json.get("format").and_then(Json::as_str) != Some("hlo-text-v1") {
            return Err(EmdError::artifact(format!("unsupported manifest format in {path:?}")));
        }
        let mut artifacts = BTreeMap::new();
        let entries = json
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| EmdError::artifact("manifest missing 'artifacts' object"))?;
        for (name, e) in entries {
            let get = |key: &str| -> EmdResult<usize> {
                e.get(key).and_then(Json::as_usize).ok_or_else(|| {
                    EmdError::artifact(format!("artifact '{name}' missing integer '{key}'"))
                })
            };
            let spec = ArtifactSpec {
                name: name.clone(),
                entry: Entry::parse(
                    e.get("entry")
                        .and_then(Json::as_str)
                        .ok_or_else(|| EmdError::artifact(format!("artifact '{name}' missing 'entry'")))?,
                )?,
                profile: e
                    .get("profile")
                    .and_then(Json::as_str)
                    .unwrap_or("default")
                    .to_string(),
                file: dir.join(
                    e.get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| EmdError::artifact(format!("artifact '{name}' missing 'file'")))?,
                ),
                v: get("v")?,
                h: get("h")?,
                m: get("m")?,
                n: get("n")?,
                k: get("k")?,
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find the artifact for `entry` in `profile` with the given k.
    pub fn find(&self, profile: &str, entry: Entry, k: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| a.profile == profile && a.entry == entry && (a.k == k || entry == Entry::RwmdB))
    }

    /// Profiles able to host a dataset of shape (v, m) with queries up to h
    /// bins, sorted by padding waste (fewest padded vocabulary rows first).
    pub fn fitting_profiles(&self, v: usize, m: usize, h: usize) -> Vec<String> {
        let mut fits: Vec<(usize, String)> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for a in self.artifacts.values() {
            if a.entry == Entry::Fused
                && a.v >= v
                && a.m == m
                && a.h >= h
                && seen.insert(a.profile.clone())
            {
                fits.push((a.v - v, a.profile.clone()));
            }
        }
        fits.sort();
        fits.into_iter().map(|(_, p)| p).collect()
    }

    /// Available k values for a profile's fused/phase1 artifacts.
    pub fn ks_for(&self, profile: &str) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.profile == profile && a.entry == Entry::Fused)
            .map(|a| a.k)
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let body = r#"{
  "format": "hlo-text-v1",
  "artifacts": {
    "dev_fused_k2": {"entry": "fused", "profile": "dev", "file": "dev_fused_k2.hlo.txt",
                      "v": 256, "h": 64, "m": 16, "n": 128, "k": 2,
                      "inputs": [], "outputs": []},
    "dev_phase1_k2": {"entry": "phase1", "profile": "dev", "file": "dev_phase1_k2.hlo.txt",
                      "v": 256, "h": 64, "m": 16, "n": 128, "k": 2,
                      "inputs": [], "outputs": []},
    "dev_rwmd_b": {"entry": "rwmd_b", "profile": "dev", "file": "dev_rwmd_b.hlo.txt",
                   "v": 256, "h": 64, "m": 16, "n": 128, "k": 1,
                   "inputs": [], "outputs": []}
  }
}"#;
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("emdpar_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.find("dev", Entry::Fused, 2).unwrap();
        assert_eq!(a.v, 256);
        assert!(m.find("dev", Entry::Fused, 99).is_none());
        assert!(m.find("dev", Entry::RwmdB, 1).is_some());
        assert_eq!(m.ks_for("dev"), vec![2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fitting_profiles_respects_shapes() {
        let dir = std::env::temp_dir().join("emdpar_manifest_test2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.fitting_profiles(200, 16, 50), vec!["dev".to_string()]);
        assert!(m.fitting_profiles(300, 16, 50).is_empty()); // v too big
        assert!(m.fitting_profiles(200, 8, 50).is_empty()); // m mismatch
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("emdpar_manifest_none");
        std::fs::remove_dir_all(&dir).ok();
        assert!(Manifest::load(&dir).is_err());
    }
}
