//! TCP line-protocol server: newline-delimited JSON requests/responses.
//!
//! Request (the wire form of a [`SearchRequest`], parsed by
//! [`SearchRequest::from_json`]):
//! ```json
//! {"op": "search", "method": "act-1", "l": 5,
//!  "query": [[vocab_idx, weight], ...]}
//! {"op": "search_id", "method": "rwmd", "l": 5, "id": 17, "nprobe": 4}
//! {"op": "search_id", "id": 3, "l": 5,
//!  "cascade": {"rerank": "emd", "overfetch": 8, "certified": true}}
//! {"op": "add_docs", "docs": [[[vocab_idx, weight], ...], ...],
//!  "labels": [0, 1]}
//! {"op": "stats"}
//! {"op": "ping"}
//! ```
//! `"nprobe"` is optional: with an IVF index configured it overrides the
//! per-request probe width (`nprobe >= nlist` forces an exhaustive sweep);
//! without an index it is ignored.  `"cascade"` requests a two-stage plan
//! (LC-RWMD prefilter → dominating rerank; `"rerank"` may also be given as
//! the string shorthand `"cascade": "emd"`); the response then carries
//! `"certified"` (the per-query Theorem-2 exactness certificate), and the
//! `stats` op reports `cascade_queries` / `reranked_total`.
//! `{"op": "add_docs"}` appends documents to a sharded live corpus
//! (`"labels"` optional, one per doc) and answers
//! `{"ok": true, "added": k, "ids": [...], "opened_shards": o, "n": total}`;
//! appended docs are immediately searchable.  `{"op": "stats"}` reports the
//! index shape plus pruning counters when an index is active, and per-shard
//! document counts / index shapes (`"shards"`) when the corpus is sharded.
//! Response (one line): `{"ok": true, "hits": [[dist, id, label], ...]}` or
//! `{"ok": false, "error": "..."}`.
//!
//! Accepted connections are handed to a worker pool; inside a connection
//! requests are pipelined FIFO.  Queries flow through the dynamic batcher
//! so concurrent clients share batch dispatches: jobs are grouped by
//! [`SearchRequest::group_key`] — the planner-resolved
//! `(method, ℓ, nprobe, cascade)` — so batchmates that resolve to the same
//! plan share one grouped dispatch.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::config::Backend;
use crate::core::{EmdError, EmdResult, Histogram};
use crate::emd_ensure;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::batcher::{next_batch, BatchPolicy, Pending};
use super::engine::SearchEngine;
use super::plan::{parse_histogram, GroupKey, SearchRequest};

/// A search job travelling through the batcher: one single-query request
/// plus its precomputed grouping key.
struct Job {
    req: SearchRequest,
    key: GroupKey,
}

type JobResult = Result<Json, String>;

/// The running server.
pub struct Server {
    engine: Arc<SearchEngine>,
    listener: TcpListener,
    batch_tx: Sender<Pending<Job, JobResult>>,
    pool: ThreadPool,
}

impl Server {
    /// Bind and spawn the batch-dispatch thread.  `addr` may use port 0 for
    /// an ephemeral port (tests); see [`Server::local_addr`].
    pub fn bind(engine: SearchEngine, addr: &str) -> EmdResult<Server> {
        let engine = Arc::new(engine);
        let listener = TcpListener::bind(addr)?;
        let policy = BatchPolicy {
            max_batch: engine.config().max_batch,
            linger: std::time::Duration::from_millis(engine.config().linger_ms),
        };
        let (batch_tx, batch_rx) = channel::<Pending<Job, JobResult>>();
        {
            let engine = Arc::clone(&engine);
            std::thread::spawn(move || {
                while let Some(batch) = next_batch(&batch_rx, policy) {
                    // group the drained batch by the planner's GroupKey so
                    // each group flows through one grouped plan execution;
                    // responses go back per-job over their own channels, so
                    // grouping never reorders anything a client can observe.
                    // Note: Metrics::batches counts plan executions (one per
                    // key per drained batch, plus per-query retries when a
                    // group fails wholesale), not drained batches
                    let mut groups: Vec<(GroupKey, Vec<Pending<Job, JobResult>>)> = Vec::new();
                    for pending in batch {
                        let key = pending.query.key;
                        match groups.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, members)) => members.push(pending),
                            None => groups.push((key, vec![pending])),
                        }
                    }
                    for (key, members) in groups {
                        let (queries, responders): (Vec<Histogram>, Vec<_>) = members
                            .into_iter()
                            .map(|p| {
                                let mut qs = p.query.req.into_queries();
                                (qs.pop().expect("one query per job"), p.respond)
                            })
                            .unzip();
                        let per_query = |q: &Histogram| {
                            let single = key.request(vec![q.clone()]);
                            engine
                                .execute(&single)
                                .map(|mut resp| {
                                    let cert = resp.stats.certified.first().copied();
                                    let res = resp
                                        .results
                                        .pop()
                                        .expect("one query in, one result out");
                                    search_result_json(&res, cert)
                                })
                                .map_err(|e| e.to_string())
                        };
                        // per-job results buffer: the native grouped plan
                        // either succeeds for everyone or fails before any
                        // query is scored (then each job is evaluated
                        // individually once); the artifact backend plans
                        // per query anyway, so it dispatches per job from
                        // the start — one failing query neither fails its
                        // batchmates nor forces re-runs
                        let results: Vec<JobResult> = if engine.config().backend
                            == Backend::Artifact
                        {
                            queries.iter().map(per_query).collect()
                        } else {
                            let group_req = key.request(queries);
                            match engine.execute(&group_req) {
                                Ok(resp) => {
                                    let certs = resp.stats.certified;
                                    resp.results
                                        .into_iter()
                                        .enumerate()
                                        .map(|(i, res)| {
                                            Ok(search_result_json(
                                                &res,
                                                certs.get(i).copied(),
                                            ))
                                        })
                                        .collect()
                                }
                                Err(_) => {
                                    group_req.queries().iter().map(per_query).collect()
                                }
                            }
                        };
                        for (out, respond) in results.into_iter().zip(responders) {
                            let _ = respond.send(out);
                        }
                    }
                }
            });
        }
        let pool = ThreadPool::new(engine.config().threads.max(2));
        Ok(Server { engine, listener, batch_tx, pool })
    }

    pub fn local_addr(&self) -> EmdResult<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop; blocks forever (run in a dedicated thread if needed).
    pub fn serve(&self) -> EmdResult<()> {
        crate::log_info!(
            "server",
            "listening on {} (method default {})",
            self.local_addr()?,
            self.engine.config().method.name()
        );
        for stream in self.listener.incoming() {
            let stream = stream?;
            let engine = Arc::clone(&self.engine);
            let tx = self.batch_tx.clone();
            self.pool.execute(move || {
                if let Err(e) = handle_connection(stream, engine.as_ref(), &tx) {
                    crate::log_debug!("server", "connection ended: {e}");
                }
            });
        }
        Ok(())
    }

    /// Accept exactly `count` connections then return (test harness).
    pub fn serve_n(&self, count: usize) -> EmdResult<()> {
        for _ in 0..count {
            let (stream, _) = self.listener.accept()?;
            let engine = Arc::clone(&self.engine);
            let tx = self.batch_tx.clone();
            self.pool.execute(move || {
                let _ = handle_connection(stream, engine.as_ref(), &tx);
            });
        }
        self.pool.wait_idle();
        Ok(())
    }
}

/// Serialize one search result as the protocol's success payload.
/// `certified` is the per-query cascade certificate (cascade requests
/// only).
fn search_result_json(res: &super::engine::SearchResult, certified: Option<bool>) -> Json {
    let mut map: std::collections::BTreeMap<String, Json> = [
        ("ok".to_string(), Json::Bool(true)),
        (
            "hits".to_string(),
            Json::Arr(
                res.hits
                    .iter()
                    .zip(&res.labels)
                    .map(|(&(d, id), &lab)| {
                        Json::Arr(vec![
                            Json::Num(d as f64),
                            Json::Num(id as f64),
                            Json::Num(lab as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]
    .into_iter()
    .collect();
    if let Some(c) = certified {
        map.insert("certified".to_string(), Json::Bool(c));
    }
    Json::Obj(map)
}

fn handle_connection(
    stream: TcpStream,
    engine: &SearchEngine,
    batch_tx: &Sender<Pending<Job, JobResult>>,
) -> EmdResult<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match handle_request(trimmed, engine, batch_tx) {
            Ok(json) => json,
            Err(e) => {
                engine.metrics().record_error();
                Json::obj(vec![("ok", false.into()), ("error", e.to_string().into())])
            }
        };
        writer.write_all(response.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn handle_request(
    line: &str,
    engine: &SearchEngine,
    batch_tx: &Sender<Pending<Job, JobResult>>,
) -> EmdResult<Json> {
    let req = Json::parse(line).map_err(|e| EmdError::protocol(format!("bad json: {e}")))?;
    match req.get("op").and_then(Json::as_str).unwrap_or("search") {
        "ping" => Ok(Json::obj(vec![("ok", true.into()), ("pong", true.into())])),
        "stats" => {
            let mut j = engine.metrics().to_json();
            if let Json::Obj(map) = &mut j {
                map.insert("ok".into(), Json::Bool(true));
                map.insert("n".into(), Json::Num(engine.num_docs() as f64));
                if let Some(stats) = engine.shard_stats() {
                    // per-shard doc counts + index shapes so operators can
                    // see skew after appends
                    map.insert(
                        "shards".into(),
                        Json::Arr(
                            stats
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("docs", s.docs.into()),
                                        ("appended", s.appended.into()),
                                        ("nlist", s.nlist.unwrap_or(0).into()),
                                        ("min_list", s.min_list.into()),
                                        ("max_list", s.max_list.into()),
                                    ])
                                })
                                .collect(),
                        ),
                    );
                }
                if let Some(ix) = engine.index() {
                    let sizes = ix.list_sizes();
                    map.insert(
                        "index".into(),
                        Json::obj(vec![
                            ("nlist", ix.nlist().into()),
                            ("points", ix.num_points().into()),
                            ("dim", ix.dim().into()),
                            (
                                "nprobe_default",
                                engine
                                    .config()
                                    .index
                                    .map(|p| p.nprobe)
                                    .unwrap_or(0)
                                    .into(),
                            ),
                            (
                                "max_list",
                                sizes.iter().copied().max().unwrap_or(0).into(),
                            ),
                            (
                                "min_list",
                                sizes.iter().copied().min().unwrap_or(0).into(),
                            ),
                        ]),
                    );
                }
            }
            Ok(j)
        }
        "add_docs" => {
            let docs_json = req
                .get("docs")
                .and_then(Json::as_arr)
                .ok_or_else(|| EmdError::protocol("missing 'docs' (array of [[idx, w], ...])"))?;
            emd_ensure!(!docs_json.is_empty(), protocol, "empty 'docs'");
            let docs = docs_json
                .iter()
                .map(parse_histogram)
                .collect::<EmdResult<Vec<Histogram>>>()?;
            let labels = match req.get("labels").and_then(Json::as_arr) {
                Some(arr) => {
                    let mut out = Vec::with_capacity(arr.len());
                    for a in arr {
                        out.push(
                            a.as_usize().ok_or_else(|| EmdError::protocol("bad label"))? as u16,
                        );
                    }
                    out
                }
                None => Vec::new(),
            };
            let outcome = engine.add_docs(&docs, &labels)?;
            Ok(Json::obj(vec![
                ("ok", true.into()),
                ("added", outcome.ids.len().into()),
                (
                    "ids",
                    Json::Arr(outcome.ids.iter().map(|&g| Json::Num(g as f64)).collect()),
                ),
                ("opened_shards", outcome.opened.into()),
                ("n", engine.num_docs().into()),
            ]))
        }
        "search" | "search_id" => {
            // the request object is the wire form of a SearchRequest; only
            // the 'id' shorthand needs the server (it can see the corpus)
            let mut request = SearchRequest::from_json(&req)?;
            if let Some(id) = req.get("id").and_then(Json::as_usize) {
                emd_ensure!(id < engine.num_docs(), protocol, "id {id} out of range");
                request.set_queries(vec![engine.doc_histogram(id)?]);
            }
            emd_ensure!(!request.queries().is_empty(), protocol, "missing 'query' (or 'id')");
            // the batcher model is one query per request: pipelined
            // requests with equal group keys share one grouped dispatch
            emd_ensure!(
                request.queries().len() == 1,
                protocol,
                "one query per request: send multiple pipelined requests and the \
                 batcher groups them into one dispatch"
            );
            emd_ensure!(!request.queries()[0].is_empty(), protocol, "empty query");
            // validate the plan up front so a bad combination (inadmissible
            // rerank, cascade on the artifact backend) errors on this
            // connection instead of inside the dispatcher
            engine.plan(&request)?;
            // the planner-resolved grouping key: batchmates resolving to
            // the same plan share one grouped dispatch
            let key = request.group_key(engine);

            // send through the dynamic batcher and wait for the reply
            let (tx, rx) = channel();
            batch_tx
                .send(Pending {
                    query: Job { req: request, key },
                    respond: tx,
                    enqueued: Instant::now(),
                })
                .map_err(|_| EmdError::msg("internal error: dispatcher gone"))?;
            match rx.recv().map_err(|_| EmdError::msg("internal error: dispatcher dropped reply"))? {
                Ok(json) => Ok(json),
                Err(e) => Err(EmdError::msg(e)),
            }
        }
        other => Err(EmdError::protocol(format!("unknown op '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec};

    fn test_engine() -> SearchEngine {
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        })
        .unwrap()
    }

    fn roundtrip(lines: &[String]) -> Vec<Json> {
        let server = Server::bind(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let lines = lines.to_vec();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in lines {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        client.join().unwrap()
    }

    #[test]
    fn ping_and_stats() {
        let out = roundtrip(&["{\"op\": \"ping\"}".into(), "{\"op\": \"stats\"}".into()]);
        assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("n").and_then(Json::as_usize), Some(30));
    }

    #[test]
    fn search_by_id_returns_self_first() {
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 3, \"l\": 4, \"method\": \"act-1\"}".into()
        ]);
        let hits = out[0].get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits.len(), 4);
        let first = hits[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(3)); // itself
        assert!(first[0].as_f64().unwrap() < 1e-5);
    }

    #[test]
    fn bad_request_reports_error() {
        let out = roundtrip(&[
            "{not json".into(),
            "{\"op\": \"search\", \"query\": []}".into(),
            "{\"op\": \"nope\"}".into(),
        ]);
        for o in &out {
            assert_eq!(o.get("ok"), Some(&Json::Bool(false)), "{o:?}");
            assert!(o.get("error").is_some());
        }
    }

    #[test]
    fn comparator_methods_served_over_tcp() {
        // Sinkhorn / exact EMD are first-class protocol methods now
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 2, \"l\": 3, \"method\": \"emd\"}".into(),
            "{\"op\": \"search_id\", \"id\": 2, \"l\": 3, \"method\": \"sinkhorn\"}".into(),
        ]);
        for o in &out {
            assert_eq!(o.get("ok"), Some(&Json::Bool(true)), "{o:?}");
            assert_eq!(o.get("hits").and_then(Json::as_arr).unwrap().len(), 3);
        }
        // exact EMD ranks the query itself first
        let first = out[0].get("hits").and_then(Json::as_arr).unwrap()[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(2));
    }

    #[test]
    fn cascade_requests_served_over_tcp() {
        let out = roundtrip(&[
            // full-coverage certified cascade: overfetch 16 x l 3 >= n, so
            // the certificate must hold
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \
             \"cascade\": {\"rerank\": \"emd\", \"overfetch\": 16, \"certified\": true}}"
                .into(),
            // string shorthand for the rerank method
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \"cascade\": \"act-3\"}".into(),
            // inadmissible rerank is a clean per-request error
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \"cascade\": \"bow\"}".into(),
        ]);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)), "{:?}", out[0]);
        let hits = out[0].get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(4), "finds itself");
        assert_eq!(out[0].get("certified"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(true)), "{:?}", out[1]);
        assert!(out[1].get("certified").is_some(), "cascade responses report the certificate");
        assert_eq!(out[2].get("ok"), Some(&Json::Bool(false)));
        assert!(out[2].get("error").is_some());
    }

    #[test]
    fn nprobe_request_and_index_stats() {
        use crate::config::IndexParams;
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 48, vocab: 200, dim: 8, seed: 12 },
            threads: 2,
            linger_ms: 1,
            index: Some(IndexParams {
                nlist: 6,
                nprobe: 2,
                train_iters: 6,
                seed: 4,
                min_points_per_list: 1,
            }),
            ..Default::default()
        })
        .unwrap();
        let server = Server::bind(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in [
                // pruned (configured default nprobe = 2)
                "{\"op\": \"search_id\", \"id\": 5, \"l\": 3, \"method\": \"rwmd\"}",
                // per-request exhaustive override
                "{\"op\": \"search_id\", \"id\": 5, \"l\": 3, \"method\": \"rwmd\", \"nprobe\": 6}",
                "{\"op\": \"stats\"}",
            ] {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        let out = client.join().unwrap();
        for o in &out[..2] {
            assert_eq!(o.get("ok"), Some(&Json::Bool(true)), "{o:?}");
            let hits = o.get("hits").and_then(Json::as_arr).unwrap();
            assert_eq!(hits.len(), 3);
            // the query is a database row: itself first on both routes
            assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(5));
        }
        let stats = &out[2];
        let index = stats.get("index").expect("stats reports the index shape");
        assert_eq!(index.get("nlist").and_then(Json::as_usize), Some(6));
        assert_eq!(index.get("points").and_then(Json::as_usize), Some(48));
        // exactly one of the two searches went through the pruned route
        assert_eq!(stats.get("index_queries").and_then(Json::as_usize), Some(1));
        assert!(stats.get("pruned_fraction").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn add_docs_and_sharded_stats_over_tcp() {
        use crate::config::{IndexParams, ShardParams};
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 15 },
            threads: 2,
            linger_ms: 1,
            sharded: Some(ShardParams { shards: 2, max_docs_per_shard: 1 << 20 }),
            index: Some(IndexParams {
                nlist: 4,
                nprobe: 4,
                train_iters: 5,
                seed: 2,
                min_points_per_list: 1,
            }),
            ..Default::default()
        })
        .unwrap();
        let server = Server::bind(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in [
                // append two docs with distinct single/dual-coordinate
                // supports, then search one of them back by id
                "{\"op\": \"add_docs\", \"docs\": [[[2, 0.6], [9, 0.4]], [[11, 1.0]]], \
                 \"labels\": [5, 6]}",
                "{\"op\": \"search_id\", \"id\": 40, \"l\": 3, \"method\": \"rwmd\"}",
                "{\"op\": \"stats\"}",
                // labels length mismatch is a clean protocol error
                "{\"op\": \"add_docs\", \"docs\": [[[1, 1.0]]], \"labels\": [1, 2]}",
            ] {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        let out = client.join().unwrap();

        let added = &out[0];
        assert_eq!(added.get("ok"), Some(&Json::Bool(true)), "{added:?}");
        assert_eq!(added.get("added").and_then(Json::as_usize), Some(2));
        assert_eq!(added.get("n").and_then(Json::as_usize), Some(42));
        let ids = added.get("ids").and_then(Json::as_arr).unwrap();
        assert_eq!(ids[0].as_usize(), Some(40));
        assert_eq!(ids[1].as_usize(), Some(41));

        let hits = out[1].get("hits").and_then(Json::as_arr).unwrap();
        let first = hits[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(40), "appended doc finds itself");
        assert_eq!(first[2].as_usize(), Some(5), "appended label served");

        let stats = &out[2];
        assert_eq!(stats.get("n").and_then(Json::as_usize), Some(42));
        let shards = stats.get("shards").and_then(Json::as_arr).expect("per-shard stats");
        assert_eq!(shards.len(), 2);
        let docs: usize =
            shards.iter().map(|s| s.get("docs").and_then(Json::as_usize).unwrap()).sum();
        assert_eq!(docs, 42);
        let appended: usize = shards
            .iter()
            .map(|s| s.get("appended").and_then(Json::as_usize).unwrap())
            .sum();
        assert_eq!(appended, 2, "operators can see append skew");
        assert!(shards.iter().all(|s| {
            s.get("nlist").and_then(Json::as_usize).unwrap() >= 1
        }));

        assert_eq!(out[3].get("ok"), Some(&Json::Bool(false)));
        assert!(out[3].get("error").is_some());
    }

    #[test]
    fn explicit_query_histogram() {
        let out = roundtrip(&[
            "{\"op\": \"search\", \"l\": 2, \"query\": [[0, 0.5], [3, 0.5]], \"method\": \"rwmd\"}"
                .into(),
        ]);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out[0].get("hits").and_then(Json::as_arr).unwrap().len(), 2);
    }
}
