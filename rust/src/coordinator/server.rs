//! TCP line-protocol server: newline-delimited JSON requests/responses.
//!
//! Request (the wire form of a [`SearchRequest`], parsed by
//! [`SearchRequest::from_json`]):
//! ```json
//! {"op": "search", "method": "act-1", "l": 5,
//!  "query": [[vocab_idx, weight], ...]}
//! {"op": "search_id", "method": "rwmd", "l": 5, "id": 17, "nprobe": 4}
//! {"op": "search_id", "id": 3, "l": 5,
//!  "cascade": {"rerank": "emd", "overfetch": 8, "certified": true}}
//! {"op": "add_docs", "docs": [[[vocab_idx, weight], ...], ...],
//!  "labels": [0, 1]}
//! {"op": "stats"}
//! {"op": "stats", "reset": true}
//! {"op": "metrics"}
//! {"op": "telemetry"}
//! {"op": "trace"}
//! {"op": "ping"}
//! ```
//! `"nprobe"` is optional: with an IVF index configured it overrides the
//! per-request probe width (`nprobe >= nlist` forces an exhaustive sweep);
//! without an index it is ignored.  `"cascade"` requests a two-stage plan
//! (LC-RWMD prefilter → dominating rerank; `"rerank"` may also be given as
//! the string shorthand `"cascade": "emd"`); the response then carries
//! `"certified"` (the per-query Theorem-2 exactness certificate), and the
//! `stats` op reports `cascade_queries` / `reranked_total`.
//! `{"op": "add_docs"}` appends documents to a sharded live corpus
//! (`"labels"` optional, one per doc) and answers
//! `{"ok": true, "added": k, "ids": [...], "opened_shards": o, "n": total}`;
//! appended docs are immediately searchable.  `{"op": "stats"}` reports the
//! index shape plus pruning counters when an index is active, per-shard
//! document counts / index shapes (`"shards"`) when the corpus is sharded,
//! and the serving histograms / admission counters.
//! `{"op": "stats", "reset": true}` additionally zeroes every counter and
//! latency histogram after snapshotting (the response reports the
//! *post-reset* state, so a scrape-and-reset client sees zeros).
//! `{"op": "metrics"}` answers `{"ok": true, "metrics": "..."}` with the
//! Prometheus text-format (0.0.4) exposition of the same counters — the
//! line-protocol twin of `emdpar serve --metrics-addr`'s `GET /metrics`.
//! `{"op": "telemetry"}` answers `{"ok": true, "telemetry": {...},
//! "audit": {...}}`: the sliding-window per-workload rates (QPS,
//! shed/deadline counts, per-stage micros, latency percentiles, probe /
//! candidate / rerank fractions keyed by the resolved [`GroupKey`]) plus
//! the online recall-audit estimates; `emdpar telemetry` wraps it.
//! `{"op": "trace"}` answers the collector ring as Chrome trace-event JSON
//! (`{"ok": true, "dropped": n, "traceEvents": [...]}`) that loads directly
//! into `chrome://tracing` / Perfetto; `emdpar trace dump` wraps it.  A
//! grown `dropped` count (the ring wrapped since the last export) logs one
//! WARN per burst so operators notice undersized rings without log spam.
//! Search requests additionally accept `"trace": true` — the response then
//! carries `"trace": [...]`, the per-stage span timeline of the executing
//! plan (see [`crate::obs`]) — and `"deadline_ms"`: a per-request
//! budget (overriding the server's `serve.deadline_ms` default; 0 disables)
//! after which the job is shed with `{"ok": false, "error": "deadline
//! exceeded"}` instead of burning compute.
//! Response (one line): `{"ok": true, "hits": [[dist, id, label], ...]}` or
//! `{"ok": false, "error": "..."}`; the reactor runtime may also answer
//! `{"ok": false, "error": "overloaded", "retry_after_ms": n}` under
//! admission shed.
//!
//! This `Server` is the legacy thread-per-connection front end, kept as a
//! compatibility shim and as the benchmark baseline; the event-loop runtime
//! lives in [`crate::serve`].  Both share the request decode
//! ([`process_line`]) and the compute bridge
//! ([`crate::serve::bridge::spawn_dispatcher`]), so their responses are
//! byte-identical.  Inside a connection requests are pipelined FIFO;
//! request lines are length-capped (`serve.max_line_bytes`) and a
//! malformed, oversized, or non-UTF-8 line answers a structured error
//! without tearing down the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::core::{EmdError, EmdResult, Histogram};
use crate::emd_ensure;
use crate::serve::bridge::{spawn_dispatcher, Job, JobResult};
use crate::serve::wire::{self, Decoded};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::batcher::Pending;
use super::engine::SearchEngine;
use super::plan::{parse_histogram, GroupKey, SearchRequest};

/// `{"ok":true,"pong":true}` — the tree serialization of the ping reply
/// (asserted byte-identical in the tests below).
const PING_LINE: &[u8] = b"{\"ok\":true,\"pong\":true}";

/// What one request line turned into.
pub(crate) enum Handled {
    /// Blank line: no response at all.
    Empty,
    /// A complete response line (success or structured error), no newline.
    Line(Vec<u8>),
    /// A validated single-query search for the compute bridge.
    Search { req: SearchRequest, key: GroupKey, deadline: Option<Instant> },
}

/// Decode one raw request line into a response or a dispatchable search —
/// the single request path both servers share.  Tries the zero-copy lexer
/// first and falls back to the tree codec on anything unusual, so output
/// stays byte-identical to the tree path.  Protocol errors are counted and
/// answered here; only valid searches escape to the batcher.
pub(crate) fn process_line(
    raw: &[u8],
    engine: &SearchEngine,
    default_deadline_ms: u64,
) -> Handled {
    let Ok(text) = std::str::from_utf8(raw) else {
        engine.metrics().record_error();
        return Handled::Line(wire::error_line(
            &EmdError::protocol("invalid utf-8 in request line").to_string(),
        ));
    };
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return Handled::Empty;
    }
    let result = match wire::decode_line(trimmed) {
        Decoded::Ping => Ok(Handled::Line(PING_LINE.to_vec())),
        Decoded::Stats { reset } => {
            if reset {
                engine.metrics().reset();
            }
            Ok(Handled::Line(stats_json(engine).to_string_compact().into_bytes()))
        }
        Decoded::Search { req, id, deadline_ms } => {
            finish_search(req, id, deadline_ms, engine, default_deadline_ms)
        }
        Decoded::Fallback => handle_cold(trimmed, engine, default_deadline_ms),
    };
    match result {
        Ok(h) => h,
        Err(e) => {
            engine.metrics().record_error();
            Handled::Line(wire::error_line(&e.to_string()))
        }
    }
}

/// The tree-codec request path: cold ops (`add_docs`), multi-query forms,
/// escape-laden payloads, and every malformed line (so the tree parser's
/// error messages stay canonical).
fn handle_cold(
    line: &str,
    engine: &SearchEngine,
    default_deadline_ms: u64,
) -> EmdResult<Handled> {
    let req = Json::parse(line).map_err(|e| EmdError::protocol(format!("bad json: {e}")))?;
    match req.get("op").and_then(Json::as_str).unwrap_or("search") {
        "ping" => Ok(Handled::Line(PING_LINE.to_vec())),
        "stats" => {
            if req.get("reset").and_then(Json::as_bool) == Some(true) {
                engine.metrics().reset();
            }
            Ok(Handled::Line(stats_json(engine).to_string_compact().into_bytes()))
        }
        "metrics" => Ok(Handled::Line(metrics_json(engine).to_string_compact().into_bytes())),
        "telemetry" => {
            Ok(Handled::Line(telemetry_json(engine).to_string_compact().into_bytes()))
        }
        "trace" => Ok(Handled::Line(trace_json(engine).to_string_compact().into_bytes())),
        "add_docs" => {
            Ok(Handled::Line(add_docs_json(&req, engine)?.to_string_compact().into_bytes()))
        }
        "search" | "search_id" => {
            // the request object is the wire form of a SearchRequest; only
            // the 'id' shorthand needs the server (it can see the corpus)
            let request = SearchRequest::from_json(&req)?;
            let id = req.get("id").and_then(Json::as_usize);
            let deadline_ms = req.get("deadline_ms").and_then(Json::as_usize).map(|x| x as u64);
            finish_search(request, id, deadline_ms, engine, default_deadline_ms)
        }
        other => Err(EmdError::protocol(format!("unknown op '{other}'"))),
    }
}

/// Resolve the `id` shorthand, validate, plan, and stamp the deadline.
fn finish_search(
    mut request: SearchRequest,
    id: Option<usize>,
    deadline_ms: Option<u64>,
    engine: &SearchEngine,
    default_deadline_ms: u64,
) -> EmdResult<Handled> {
    if let Some(id) = id {
        emd_ensure!(id < engine.num_docs(), protocol, "id {id} out of range");
        request.set_queries(vec![engine.doc_histogram(id)?]);
    }
    emd_ensure!(!request.queries().is_empty(), protocol, "missing 'query' (or 'id')");
    // the batcher model is one query per request: pipelined
    // requests with equal group keys share one grouped dispatch
    emd_ensure!(
        request.queries().len() == 1,
        protocol,
        "one query per request: send multiple pipelined requests and the \
         batcher groups them into one dispatch"
    );
    emd_ensure!(!request.queries()[0].is_empty(), protocol, "empty query");
    // validate the plan up front so a bad combination (inadmissible
    // rerank, cascade on the artifact backend) errors on this
    // connection instead of inside the dispatcher
    engine.plan(&request)?;
    // the planner-resolved grouping key: batchmates resolving to
    // the same plan share one grouped dispatch
    let key = request.group_key(engine);
    let ms = deadline_ms.unwrap_or(default_deadline_ms);
    let deadline = if ms == 0 { None } else { Some(Instant::now() + Duration::from_millis(ms)) };
    Ok(Handled::Search { req: request, key, deadline })
}

/// The `stats` payload: metrics snapshot + corpus/index/shard shape.
fn stats_json(engine: &SearchEngine) -> Json {
    let mut j = engine.metrics().to_json();
    if let Json::Obj(map) = &mut j {
        map.insert("ok".into(), Json::Bool(true));
        map.insert("n".into(), Json::Num(engine.num_docs() as f64));
        if let Some(stats) = engine.shard_stats() {
            // per-shard doc counts + index shapes so operators can
            // see skew after appends
            map.insert(
                "shards".into(),
                Json::Arr(
                    stats
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("docs", s.docs.into()),
                                ("appended", s.appended.into()),
                                ("nlist", s.nlist.unwrap_or(0).into()),
                                ("min_list", s.min_list.into()),
                                ("max_list", s.max_list.into()),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        if let Some(ix) = engine.index() {
            let sizes = ix.list_sizes();
            map.insert(
                "index".into(),
                Json::obj(vec![
                    ("nlist", ix.nlist().into()),
                    ("points", ix.num_points().into()),
                    ("dim", ix.dim().into()),
                    (
                        "nprobe_default",
                        engine.config().index.map(|p| p.nprobe).unwrap_or(0).into(),
                    ),
                    ("max_list", sizes.iter().copied().max().unwrap_or(0).into()),
                    ("min_list", sizes.iter().copied().min().unwrap_or(0).into()),
                ]),
            );
        }
    }
    j
}

/// The `metrics` op: Prometheus text exposition carried over the line
/// protocol (the HTTP listener serves the same bytes at `GET /metrics`).
fn metrics_json(engine: &SearchEngine) -> Json {
    let text = crate::obs::prom::render_engine(engine);
    Json::obj(vec![("ok", true.into()), ("metrics", Json::Str(text))])
}

/// The `telemetry` op: the sliding-window workload aggregates plus the
/// online recall-audit estimates (`emdpar telemetry` wraps this line).  A
/// remote fan-out coordinator additionally reports per-shard connectivity
/// (`connected` / `degraded` / `down` with per-replica reachability).
fn telemetry_json(engine: &SearchEngine) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("ok", true.into()),
        ("telemetry", engine.telemetry().snapshot().to_json()),
        ("audit", engine.auditor().to_json()),
    ];
    if let Some(fleet) = engine.remote_fleet() {
        pairs.push(("remote", fleet.status_json()));
    }
    Json::obj(pairs)
}

/// The `trace` op: the span ring as Chrome trace-event JSON.  Extra
/// top-level keys (`ok`, `dropped`) are ignored by trace viewers, so the
/// response line loads into `chrome://tracing` unmodified.
fn trace_json(engine: &SearchEngine) -> Json {
    let snap = engine.tracer().snapshot();
    engine.tracer().warn_on_new_drops(snap.dropped);
    crate::obs::chrome::render(&snap.spans, snap.dropped)
}

/// The `add_docs` op: append documents to the sharded live corpus.
fn add_docs_json(req: &Json, engine: &SearchEngine) -> EmdResult<Json> {
    let docs_json = req
        .get("docs")
        .and_then(Json::as_arr)
        .ok_or_else(|| EmdError::protocol("missing 'docs' (array of [[idx, w], ...])"))?;
    emd_ensure!(!docs_json.is_empty(), protocol, "empty 'docs'");
    let docs =
        docs_json.iter().map(parse_histogram).collect::<EmdResult<Vec<Histogram>>>()?;
    let labels = match req.get("labels").and_then(Json::as_arr) {
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for a in arr {
                out.push(a.as_usize().ok_or_else(|| EmdError::protocol("bad label"))? as u16);
            }
            out
        }
        None => Vec::new(),
    };
    let outcome = engine.add_docs(&docs, &labels)?;
    Ok(Json::obj(vec![
        ("ok", true.into()),
        ("added", outcome.ids.len().into()),
        ("ids", Json::Arr(outcome.ids.iter().map(|&g| Json::Num(g as f64)).collect())),
        ("opened_shards", outcome.opened.into()),
        ("n", engine.num_docs().into()),
    ]))
}

/// The running server (legacy thread-per-connection front end).
pub struct Server {
    engine: Arc<SearchEngine>,
    listener: TcpListener,
    batch_tx: Sender<Pending<Job, JobResult>>,
    pool: ThreadPool,
}

impl Server {
    /// Bind and spawn the shared batch-dispatch thread.  `addr` may use
    /// port 0 for an ephemeral port (tests); see [`Server::local_addr`].
    pub fn bind(engine: SearchEngine, addr: &str) -> EmdResult<Server> {
        let engine = Arc::new(engine);
        let listener = TcpListener::bind(addr)?;
        let batch_tx = spawn_dispatcher(Arc::clone(&engine));
        let pool = ThreadPool::new(engine.config().threads.max(2));
        Ok(Server { engine, listener, batch_tx, pool })
    }

    pub fn local_addr(&self) -> EmdResult<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The engine this server fronts (metrics/health listener wiring).
    pub fn engine(&self) -> &Arc<SearchEngine> {
        &self.engine
    }

    /// Accept loop; blocks forever (run in a dedicated thread if needed).
    pub fn serve(&self) -> EmdResult<()> {
        crate::log_info!(
            "server",
            "listening on {} (method default {})",
            self.local_addr()?,
            self.engine.config().method.name()
        );
        for stream in self.listener.incoming() {
            let stream = stream?;
            let engine = Arc::clone(&self.engine);
            let tx = self.batch_tx.clone();
            self.pool.execute(move || {
                if let Err(e) = handle_connection(stream, engine.as_ref(), &tx) {
                    crate::log_debug!("server", "connection ended: {e}");
                }
            });
        }
        Ok(())
    }

    /// Accept exactly `count` connections then return (test harness).
    pub fn serve_n(&self, count: usize) -> EmdResult<()> {
        for _ in 0..count {
            let (stream, _) = self.listener.accept()?;
            let engine = Arc::clone(&self.engine);
            let tx = self.batch_tx.clone();
            self.pool.execute(move || {
                let _ = handle_connection(stream, engine.as_ref(), &tx);
            });
        }
        self.pool.wait_idle();
        Ok(())
    }
}

enum LineRead {
    /// Clean end of stream (no buffered bytes).
    Eof,
    /// One line in `buf` (newline stripped; possibly EOF-terminated).
    Line,
    /// The line exceeded the cap; its bytes were discarded.
    Oversized,
}

/// Read one newline-terminated request line with a hard length cap.
/// Over-cap lines are discarded chunk-by-chunk (bounded memory) and
/// reported as [`LineRead::Oversized`] once their newline (or EOF)
/// arrives.
fn read_request_line(
    reader: &mut impl BufRead,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut discarding = false;
    loop {
        let available = reader.fill_buf()?;
        if available.is_empty() {
            // EOF: a partial line still counts as a request, like read_line
            return Ok(if discarding {
                LineRead::Oversized
            } else if buf.is_empty() {
                LineRead::Eof
            } else {
                LineRead::Line
            });
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if !discarding {
                    buf.extend_from_slice(&available[..pos]);
                }
                reader.consume(pos + 1);
                return Ok(if discarding || buf.len() > cap {
                    LineRead::Oversized
                } else {
                    LineRead::Line
                });
            }
            None => {
                let n = available.len();
                if !discarding {
                    buf.extend_from_slice(available);
                    if buf.len() > cap {
                        discarding = true;
                        buf.clear();
                    }
                }
                reader.consume(n);
            }
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    engine: &SearchEngine,
    batch_tx: &Sender<Pending<Job, JobResult>>,
) -> EmdResult<()> {
    let serve = engine.config().serve;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        let response: Vec<u8> = match read_request_line(&mut reader, &mut buf, serve.max_line_bytes)?
        {
            LineRead::Eof => return Ok(()), // client closed
            LineRead::Oversized => {
                engine.metrics().record_error();
                wire::error_line(
                    &EmdError::protocol(format!(
                        "request line exceeds {} bytes",
                        serve.max_line_bytes
                    ))
                    .to_string(),
                )
            }
            LineRead::Line => match process_line(&buf, engine, serve.deadline_ms) {
                Handled::Empty => continue,
                Handled::Line(bytes) => bytes,
                Handled::Search { req, key, deadline } => {
                    // send through the dynamic batcher and wait for the
                    // reply (legacy blocking path: no admission permit, no
                    // wire completion)
                    let (tx, rx) = channel();
                    let job = Job { req, key, deadline, wire: None, permit: None };
                    let sent = batch_tx
                        .send(Pending { query: job, respond: tx, enqueued: Instant::now() });
                    let outcome = match sent {
                        Err(_) => Err(wire::DISPATCHER_GONE_MSG.to_string()),
                        Ok(()) => rx
                            .recv()
                            .unwrap_or_else(|_| Err(wire::DISPATCHER_DROPPED_MSG.to_string())),
                    };
                    match outcome {
                        Ok(line) => line,
                        Err(e) => {
                            engine.metrics().record_error();
                            wire::error_line(&e)
                        }
                    }
                }
            },
        };
        writer.write_all(&response)?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec, ServeParams};

    fn test_engine() -> SearchEngine {
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        })
        .unwrap()
    }

    fn roundtrip(lines: &[String]) -> Vec<Json> {
        roundtrip_on(test_engine(), lines)
    }

    fn roundtrip_on(engine: SearchEngine, lines: &[String]) -> Vec<Json> {
        let server = Server::bind(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let lines = lines.to_vec();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in lines {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        client.join().unwrap()
    }

    #[test]
    fn ping_and_stats() {
        let out = roundtrip(&["{\"op\": \"ping\"}".into(), "{\"op\": \"stats\"}".into()]);
        assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("n").and_then(Json::as_usize), Some(30));
    }

    #[test]
    fn metrics_trace_and_reset_ops() {
        let out = roundtrip(&[
            // a traced search first so the ring has spans and the counters
            // have something to reset
            "{\"op\": \"search_id\", \"id\": 1, \"l\": 2, \"trace\": true}".into(),
            "{\"op\": \"metrics\"}".into(),
            "{\"op\": \"trace\"}".into(),
            "{\"op\": \"stats\", \"reset\": true}".into(),
            "{\"op\": \"stats\"}".into(),
        ]);
        // traced search embeds its per-stage timeline
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)), "{:?}", out[0]);
        let tl = out[0].get("trace").and_then(Json::as_arr).expect("timeline embedded");
        assert_eq!(tl[0].get("name").and_then(Json::as_str), Some("request"));
        assert!(tl.len() >= 2, "root plus at least one stage span: {tl:?}");
        // metrics: Prometheus text riding in a JSON string
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(true)));
        let text = out[1].get("metrics").and_then(Json::as_str).unwrap();
        assert!(text.contains("emdpar_queries_total 1"), "{text}");
        assert!(text.contains("emdpar_trace_spans_total"), "{text}");
        // trace: chrome trace-event export carrying the search's spans
        let events = out[2].get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!events.is_empty(), "ring holds the traced search's spans");
        assert!(out[2].get("dropped").and_then(Json::as_usize).is_some());
        // reset zeroes the counters; both replies are post-reset snapshots
        assert_eq!(out[3].get("queries").and_then(Json::as_usize), Some(0));
        assert_eq!(out[4].get("queries").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn telemetry_op_reports_workloads_and_audit() {
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 1, \"l\": 2}".into(),
            "{\"op\": \"search_id\", \"id\": 2, \"l\": 2}".into(),
            "{\"op\": \"telemetry\"}".into(),
        ]);
        assert_eq!(out[2].get("ok"), Some(&Json::Bool(true)), "{:?}", out[2]);
        let tel = out[2].get("telemetry").expect("telemetry payload");
        let workloads = tel.get("workloads").and_then(Json::as_arr).unwrap();
        assert!(!workloads.is_empty(), "searches landed in the window: {tel:?}");
        let w = &workloads[0];
        assert_eq!(w.get("queries").and_then(Json::as_usize), Some(2));
        assert!(w.get("qps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(w.get("label").and_then(Json::as_str).unwrap().contains("_l2"));
        // auditing is off by default: the estimate store is empty but present
        let audit = out[2].get("audit").expect("audit payload");
        assert_eq!(audit.get("sample").and_then(Json::as_usize), Some(0));
        assert_eq!(audit.get("workloads").and_then(Json::as_arr).unwrap().len(), 0);
    }

    #[test]
    fn untraced_search_response_has_no_trace_field() {
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 1, \"l\": 2}".into(),
        ]);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)));
        assert!(out[0].get("trace").is_none(), "{:?}", out[0]);
    }

    #[test]
    fn ping_line_matches_tree_serializer() {
        let tree = Json::obj(vec![("ok", true.into()), ("pong", true.into())]);
        assert_eq!(PING_LINE, tree.to_string_compact().as_bytes());
    }

    #[test]
    fn search_by_id_returns_self_first() {
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 3, \"l\": 4, \"method\": \"act-1\"}".into()
        ]);
        let hits = out[0].get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits.len(), 4);
        let first = hits[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(3)); // itself
        assert!(first[0].as_f64().unwrap() < 1e-5);
    }

    #[test]
    fn bad_request_reports_error() {
        let out = roundtrip(&[
            "{not json".into(),
            "{\"op\": \"search\", \"query\": []}".into(),
            "{\"op\": \"nope\"}".into(),
        ]);
        for o in &out {
            assert_eq!(o.get("ok"), Some(&Json::Bool(false)), "{o:?}");
            assert!(o.get("error").is_some());
        }
    }

    #[test]
    fn comparator_methods_served_over_tcp() {
        // Sinkhorn / exact EMD are first-class protocol methods now
        let out = roundtrip(&[
            "{\"op\": \"search_id\", \"id\": 2, \"l\": 3, \"method\": \"emd\"}".into(),
            "{\"op\": \"search_id\", \"id\": 2, \"l\": 3, \"method\": \"sinkhorn\"}".into(),
        ]);
        for o in &out {
            assert_eq!(o.get("ok"), Some(&Json::Bool(true)), "{o:?}");
            assert_eq!(o.get("hits").and_then(Json::as_arr).unwrap().len(), 3);
        }
        // exact EMD ranks the query itself first
        let first = out[0].get("hits").and_then(Json::as_arr).unwrap()[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(2));
    }

    #[test]
    fn cascade_requests_served_over_tcp() {
        let out = roundtrip(&[
            // full-coverage certified cascade: overfetch 16 x l 3 >= n, so
            // the certificate must hold
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \
             \"cascade\": {\"rerank\": \"emd\", \"overfetch\": 16, \"certified\": true}}"
                .into(),
            // string shorthand for the rerank method
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \"cascade\": \"act-3\"}".into(),
            // inadmissible rerank is a clean per-request error
            "{\"op\": \"search_id\", \"id\": 4, \"l\": 3, \"cascade\": \"bow\"}".into(),
        ]);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)), "{:?}", out[0]);
        let hits = out[0].get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(4), "finds itself");
        assert_eq!(out[0].get("certified"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(true)), "{:?}", out[1]);
        assert!(out[1].get("certified").is_some(), "cascade responses report the certificate");
        assert_eq!(out[2].get("ok"), Some(&Json::Bool(false)));
        assert!(out[2].get("error").is_some());
    }

    #[test]
    fn nprobe_request_and_index_stats() {
        use crate::config::IndexParams;
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 48, vocab: 200, dim: 8, seed: 12 },
            threads: 2,
            linger_ms: 1,
            index: Some(IndexParams {
                nlist: 6,
                nprobe: 2,
                train_iters: 6,
                seed: 4,
                min_points_per_list: 1,
            }),
            ..Default::default()
        })
        .unwrap();
        let server = Server::bind(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in [
                // pruned (configured default nprobe = 2)
                "{\"op\": \"search_id\", \"id\": 5, \"l\": 3, \"method\": \"rwmd\"}",
                // per-request exhaustive override
                "{\"op\": \"search_id\", \"id\": 5, \"l\": 3, \"method\": \"rwmd\", \"nprobe\": 6}",
                "{\"op\": \"stats\"}",
            ] {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        let out = client.join().unwrap();
        for o in &out[..2] {
            assert_eq!(o.get("ok"), Some(&Json::Bool(true)), "{o:?}");
            let hits = o.get("hits").and_then(Json::as_arr).unwrap();
            assert_eq!(hits.len(), 3);
            // the query is a database row: itself first on both routes
            assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(5));
        }
        let stats = &out[2];
        let index = stats.get("index").expect("stats reports the index shape");
        assert_eq!(index.get("nlist").and_then(Json::as_usize), Some(6));
        assert_eq!(index.get("points").and_then(Json::as_usize), Some(48));
        // exactly one of the two searches went through the pruned route
        assert_eq!(stats.get("index_queries").and_then(Json::as_usize), Some(1));
        assert!(stats.get("pruned_fraction").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn add_docs_and_sharded_stats_over_tcp() {
        use crate::config::{IndexParams, ShardParams};
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 15 },
            threads: 2,
            linger_ms: 1,
            sharded: Some(ShardParams { shards: 2, max_docs_per_shard: 1 << 20 }),
            index: Some(IndexParams {
                nlist: 4,
                nprobe: 4,
                train_iters: 5,
                seed: 2,
                min_points_per_list: 1,
            }),
            ..Default::default()
        })
        .unwrap();
        let server = Server::bind(engine, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = Vec::new();
            let mut w = stream;
            for line in [
                // append two docs with distinct single/dual-coordinate
                // supports, then search one of them back by id
                "{\"op\": \"add_docs\", \"docs\": [[[2, 0.6], [9, 0.4]], [[11, 1.0]]], \
                 \"labels\": [5, 6]}",
                "{\"op\": \"search_id\", \"id\": 40, \"l\": 3, \"method\": \"rwmd\"}",
                "{\"op\": \"stats\"}",
                // labels length mismatch is a clean protocol error
                "{\"op\": \"add_docs\", \"docs\": [[[1, 1.0]]], \"labels\": [1, 2]}",
            ] {
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                w.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        let out = client.join().unwrap();

        let added = &out[0];
        assert_eq!(added.get("ok"), Some(&Json::Bool(true)), "{added:?}");
        assert_eq!(added.get("added").and_then(Json::as_usize), Some(2));
        assert_eq!(added.get("n").and_then(Json::as_usize), Some(42));
        let ids = added.get("ids").and_then(Json::as_arr).unwrap();
        assert_eq!(ids[0].as_usize(), Some(40));
        assert_eq!(ids[1].as_usize(), Some(41));

        let hits = out[1].get("hits").and_then(Json::as_arr).unwrap();
        let first = hits[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(40), "appended doc finds itself");
        assert_eq!(first[2].as_usize(), Some(5), "appended label served");

        let stats = &out[2];
        assert_eq!(stats.get("n").and_then(Json::as_usize), Some(42));
        let shards = stats.get("shards").and_then(Json::as_arr).expect("per-shard stats");
        assert_eq!(shards.len(), 2);
        let docs: usize =
            shards.iter().map(|s| s.get("docs").and_then(Json::as_usize).unwrap()).sum();
        assert_eq!(docs, 42);
        let appended: usize = shards
            .iter()
            .map(|s| s.get("appended").and_then(Json::as_usize).unwrap())
            .sum();
        assert_eq!(appended, 2, "operators can see append skew");
        assert!(shards.iter().all(|s| {
            s.get("nlist").and_then(Json::as_usize).unwrap() >= 1
        }));

        assert_eq!(out[3].get("ok"), Some(&Json::Bool(false)));
        assert!(out[3].get("error").is_some());
    }

    #[test]
    fn explicit_query_histogram() {
        let out = roundtrip(&[
            "{\"op\": \"search\", \"l\": 2, \"query\": [[0, 0.5], [3, 0.5]], \"method\": \"rwmd\"}"
                .into(),
        ]);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out[0].get("hits").and_then(Json::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn oversized_request_line_keeps_connection_alive() {
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
            threads: 2,
            linger_ms: 1,
            serve: ServeParams { max_line_bytes: 256, ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        let big = format!("{{\"op\": \"ping\", \"pad\": \"{}\"}}", "x".repeat(4096));
        let out = roundtrip_on(
            engine,
            &["{\"op\": \"ping\"}".into(), big, "{\"op\": \"ping\"}".into()],
        );
        assert_eq!(out.len(), 3, "one response per request, in order");
        assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(false)));
        let err = out[1].get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("exceeds 256 bytes"), "{err}");
        assert_eq!(
            out[2].get("pong"),
            Some(&Json::Bool(true)),
            "the pipelined successor survives the oversized line"
        );
    }

    #[test]
    fn invalid_utf8_keeps_connection_alive() {
        let server = Server::bind(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream;
            w.write_all(b"{\"op\": \"ping\" \xff\xfe}\n").unwrap();
            w.write_all(b"{\"op\": \"ping\"}\n").unwrap();
            w.flush().unwrap();
            let mut out = Vec::new();
            for _ in 0..2 {
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                out.push(Json::parse(resp.trim()).unwrap());
            }
            out
        });
        server.serve_n(1).unwrap();
        let out = client.join().unwrap();
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(false)));
        assert!(out[0]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("invalid utf-8"));
        assert_eq!(out[1].get("pong"), Some(&Json::Bool(true)), "connection survives");
    }

    #[test]
    fn per_request_deadline_expires_cleanly() {
        // a 50ms linger holds the job in the batcher well past a 1ms
        // deadline, so the dispatcher must shed it at dequeue
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
            threads: 2,
            linger_ms: 50,
            max_batch: 64,
            ..Default::default()
        })
        .unwrap();
        let out = roundtrip_on(
            engine,
            &["{\"op\": \"search_id\", \"id\": 1, \"l\": 3, \"deadline_ms\": 1}".into()],
        );
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            out[0].get("error").and_then(Json::as_str),
            Some("deadline exceeded"),
            "{:?}",
            out[0]
        );
    }

    #[test]
    fn read_request_line_caps_and_recovers() {
        use std::io::Cursor;
        let mut input = Vec::new();
        input.extend_from_slice(b"short\n");
        input.extend_from_slice(&vec![b'y'; 10_000]);
        input.push(b'\n');
        input.extend_from_slice(b"after\n");
        input.extend_from_slice(b"tail-without-newline");
        let mut reader = Cursor::new(input);
        let mut buf = Vec::new();
        assert!(matches!(read_request_line(&mut reader, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"short");
        assert!(matches!(
            read_request_line(&mut reader, &mut buf, 64).unwrap(),
            LineRead::Oversized
        ));
        assert!(matches!(read_request_line(&mut reader, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"after");
        assert!(matches!(read_request_line(&mut reader, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"tail-without-newline");
        assert!(matches!(read_request_line(&mut reader, &mut buf, 64).unwrap(), LineRead::Eof));
    }
}
