//! Top-ℓ result accumulation and shard merging.
//!
//! Each database shard produces partial results; [`TopL`] keeps the ℓ best
//! (distance, id) pairs seen so far and merges with other accumulators.
//! Ordering: ascending distance, ties broken by lower id — consistent with
//! the rest of the stack so shard count never changes results.

use crate::util::threadpool::{parallel_for, SyncSlice};

/// Bounded best-ℓ accumulator (insertion into a sorted small vec; ℓ is
/// small so this beats a heap in practice and keeps deterministic order).
#[derive(Debug, Clone)]
pub struct TopL {
    l: usize,
    entries: Vec<(f32, usize)>,
}

impl TopL {
    pub fn new(l: usize) -> TopL {
        TopL { l: l.max(1), entries: Vec::with_capacity(l + 1) }
    }

    #[inline]
    fn rank(e: &(f32, usize)) -> (f32, usize) {
        *e
    }

    /// Offer one candidate.
    #[inline]
    pub fn push(&mut self, distance: f32, id: usize) {
        let cand = (distance, id);
        if self.entries.len() == self.l {
            let worst = *self.entries.last().unwrap();
            if (cand.0, cand.1) >= (worst.0, worst.1) {
                return;
            }
        }
        let pos = self
            .entries
            .partition_point(|e| (Self::rank(e).0, Self::rank(e).1) <= (cand.0, cand.1));
        self.entries.insert(pos, cand);
        if self.entries.len() > self.l {
            self.entries.pop();
        }
    }

    /// Offer a whole distance slice with ids `base..base+len`.
    pub fn push_slice(&mut self, distances: &[f32], base: usize) {
        for (off, &d) in distances.iter().enumerate() {
            self.push(d, base + off);
        }
    }

    /// Merge another accumulator into this one.
    pub fn merge(&mut self, other: &TopL) {
        for &(d, id) in &other.entries {
            self.push(d, id);
        }
    }

    /// Sorted (distance, id) results, best first.
    pub fn into_sorted(self) -> Vec<(f32, usize)> {
        self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Current worst accepted distance (pruning threshold for shards).
    pub fn threshold(&self) -> Option<f32> {
        if self.entries.len() == self.l {
            self.entries.last().map(|e| e.0)
        } else {
            None
        }
    }
}

/// K-way merge of per-shard accumulators into one accumulator per query,
/// data-parallel over the queries of a batch (each query row's merge is
/// independent of its neighbors).  `shard_accs[s][q]` is shard `s`'s
/// accumulator for query `q`; every shard must carry `queries` accumulators.
/// Shards are merged in shard order on exactly one worker per query, so the
/// result is bit-identical for every thread count (and to a serial merge).
pub fn merge_query_rows(
    shard_accs: &[Vec<TopL>],
    queries: usize,
    l: usize,
    threads: usize,
) -> Vec<TopL> {
    debug_assert!(
        shard_accs.iter().all(|s| s.len() == queries),
        "every shard must have one accumulator per query"
    );
    let mut out = vec![TopL::new(l); queries];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(queries, threads, |start, end| {
            for q in start..end {
                let mut acc = TopL::new(l);
                for shard in shard_accs {
                    acc.merge(&shard[q]);
                }
                // SAFETY: query row q is owned by exactly this chunk.
                unsafe { slots.write(q, acc) };
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn keeps_best_l_sorted() {
        let mut t = TopL::new(3);
        for (d, id) in [(5.0, 0), (1.0, 1), (3.0, 2), (2.0, 3), (4.0, 4)] {
            t.push(d, id);
        }
        assert_eq!(t.into_sorted(), vec![(1.0, 1), (2.0, 3), (3.0, 2)]);
    }

    #[test]
    fn tie_break_lower_id() {
        let mut t = TopL::new(2);
        t.push(1.0, 7);
        t.push(1.0, 3);
        t.push(1.0, 5);
        assert_eq!(t.into_sorted(), vec![(1.0, 3), (1.0, 5)]);
    }

    #[test]
    fn merge_equals_bulk() {
        check("topl-merge", 3, 50, |rng: &mut Rng| {
            let n = 40;
            let l = 5;
            let xs: Vec<f32> = (0..n).map(|_| (rng.below(12) as f32) / 3.0).collect();
            // sharded
            let mut a = TopL::new(l);
            let mut b = TopL::new(l);
            a.push_slice(&xs[..n / 2], 0);
            b.push_slice(&xs[n / 2..], n / 2);
            a.merge(&b);
            // bulk
            let mut bulk = TopL::new(l);
            bulk.push_slice(&xs, 0);
            ensure(a.clone().into_sorted() == bulk.into_sorted(), || {
                format!("shard {:?}", a.into_sorted())
            })
        });
    }

    #[test]
    fn parallel_merge_equals_serial() {
        check("topl-merge-parallel", 3, 25, |rng: &mut Rng| {
            let shards = 1 + rng.below(5);
            let queries = 1 + rng.below(9);
            let l = 1 + rng.below(6);
            let accs: Vec<Vec<TopL>> = (0..shards)
                .map(|s| {
                    (0..queries)
                        .map(|_| {
                            let mut t = TopL::new(l);
                            for _ in 0..rng.below(20) {
                                t.push((rng.below(9) as f32) / 2.0, s * 1000 + rng.below(100));
                            }
                            t
                        })
                        .collect()
                })
                .collect();
            // serial reference: merge shard-by-shard per query on one thread
            let serial: Vec<Vec<(f32, usize)>> = (0..queries)
                .map(|q| {
                    let mut acc = TopL::new(l);
                    for shard in &accs {
                        acc.merge(&shard[q]);
                    }
                    acc.into_sorted()
                })
                .collect();
            for threads in [1usize, 4] {
                let par = merge_query_rows(&accs, queries, l, threads);
                let got: Vec<Vec<(f32, usize)>> =
                    par.into_iter().map(TopL::into_sorted).collect();
                if got != serial {
                    return ensure(false, || {
                        format!("threads {threads}: {got:?} != {serial:?}")
                    });
                }
            }
            ensure(true, String::new)
        });
    }

    #[test]
    fn merge_query_rows_handles_empty_shard_set() {
        let merged = merge_query_rows(&[], 3, 4, 2);
        assert_eq!(merged.len(), 3);
        assert!(merged.iter().all(TopL::is_empty));
    }

    #[test]
    fn threshold_only_when_full() {
        let mut t = TopL::new(2);
        assert_eq!(t.threshold(), None);
        t.push(3.0, 0);
        assert_eq!(t.threshold(), None);
        t.push(1.0, 1);
        assert_eq!(t.threshold(), Some(3.0));
        t.push(0.5, 2);
        assert_eq!(t.threshold(), Some(1.0));
    }
}
