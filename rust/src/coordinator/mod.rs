//! The L3 coordinator: query planner + search-engine façade, dynamic
//! batcher, shard router, top-ℓ merging, metrics and the TCP line-protocol
//! server.  This is the serving layer a downstream user deploys; Python
//! never runs here.
//!
//! The one serving entry point is a [`SearchRequest`] executed through
//! [`SearchEngine::execute`] ([`plan`]); the legacy `search*`/`cascade*`
//! functions are delegating shims kept for compatibility.

pub mod batcher;
pub mod cascade;
pub mod engine;
pub mod metrics;
pub mod plan;
pub mod router;
pub mod server;
pub mod topl;

pub use batcher::{next_batch, BatchPolicy, Pending};
pub use cascade::{
    admissible_rerank, cascade_search, cascade_search_pruned, provably_dominates_rwmd,
    CascadeResult,
};
pub use engine::{SearchEngine, SearchResult};
pub use metrics::Metrics;
pub use plan::{
    CascadeSpec, GroupKey, QueryPlan, QueryStats, SearchRequest, SearchResponse, Stage,
};
pub use router::Router;
pub use server::Server;
pub use topl::{merge_query_rows, TopL};
