//! Dynamic query batcher: collect queries until `max_batch` is reached or
//! the oldest has lingered `linger`, then dispatch the whole batch (the
//! serving-throughput trick of vLLM-style routers, applied to similarity
//! queries: one Phase-1 per query, Phase-2 sweeps can share database tiles).

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// A queued unit of work.
pub struct Pending<Q, R> {
    pub query: Q,
    pub respond: Sender<R>,
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, linger: Duration::from_millis(2) }
    }
}

/// Drain one batch from `rx` according to `policy`.
///
/// Blocks for the first item (or returns `None` when the channel is closed),
/// then keeps accepting items until the batch is full or the first item's
/// linger budget expires.
pub fn next_batch<Q, R>(
    rx: &Receiver<Pending<Q, R>>,
    policy: BatchPolicy,
) -> Option<Vec<Pending<Q, R>>> {
    let first = rx.recv().ok()?;
    let deadline = first.enqueued + policy.linger;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending(q: usize) -> (Pending<usize, usize>, Receiver<usize>) {
        let (tx, rx) = channel();
        (Pending { query: q, respond: tx, enqueued: Instant::now() }, rx)
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let (tx, rx) = channel();
        for i in 0..5 {
            let (p, _keep) = pending(i);
            std::mem::forget(_keep);
            tx.send(p).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, linger: Duration::from_secs(10) };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(t0.elapsed() < Duration::from_secs(1), "should not wait for linger");
        let batch2 = next_batch(&rx, policy).unwrap();
        assert_eq!(batch2.len(), 2); // remaining after linger expiry
    }

    #[test]
    fn linger_expires_partial_batch() {
        let (tx, rx) = channel();
        let (p, _keep) = pending(0);
        std::mem::forget(_keep);
        tx.send(p).unwrap();
        let policy = BatchPolicy { max_batch: 8, linger: Duration::from_millis(10) };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn expired_linger_dispatches_immediately() {
        // the first drained item's linger budget is already spent (it sat in
        // the channel longer than the policy allows): the batch must
        // dispatch at once, without waiting on the queued followers
        let (tx, rx) = channel();
        let (mut first, _keep) = pending(0);
        std::mem::forget(_keep);
        first.enqueued = Instant::now() - Duration::from_millis(50);
        tx.send(first).unwrap();
        for i in 1..4 {
            let (p, keep) = pending(i);
            std::mem::forget(keep);
            tx.send(p).unwrap();
        }
        let policy = BatchPolicy { max_batch: 8, linger: Duration::from_millis(10) };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 1, "expired first item dispatches alone");
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "expired deadline must not linger again"
        );
        // the followers are still queued for the next drain
        let rest = next_batch(&rx, policy).unwrap();
        assert_eq!(rest.len(), 3);
    }

    #[test]
    fn max_batch_one_never_lingers() {
        let (tx, rx) = channel();
        let (p, _keep) = pending(7);
        std::mem::forget(_keep);
        tx.send(p).unwrap();
        // a 10s linger would blow the assertion below if max_batch = 1
        // waited at all
        let policy = BatchPolicy { max_batch: 1, linger: Duration::from_secs(10) };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "max_batch=1 must dispatch immediately");
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<Pending<usize, usize>>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn preserves_fifo_order() {
        let (tx, rx) = channel();
        for i in 0..4 {
            let (p, _keep) = pending(i);
            std::mem::forget(_keep);
            tx.send(p).unwrap();
        }
        let batch =
            next_batch(&rx, BatchPolicy { max_batch: 4, linger: Duration::from_millis(1) })
                .unwrap();
        let qs: Vec<usize> = batch.iter().map(|p| p.query).collect();
        assert_eq!(qs, vec![0, 1, 2, 3]);
    }
}
