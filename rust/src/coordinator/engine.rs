//! Search-engine façade: one object that owns the dataset, answers top-ℓ
//! queries through either backend (native CPU LC engine or the PJRT
//! artifact runtime), and records metrics.  This is what the server, the
//! CLI and the examples all drive.  Construct it through
//! [`crate::builder::EngineBuilder`] or from a [`Config`].

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{Backend, Config, DatasetSpec, IndexParams, ShardParams};
use crate::core::{Dataset, EmdError, EmdResult, Histogram, Method, MethodRegistry};
use crate::emd_ensure;
use crate::index::{dataset_fingerprint, load_index_for, sidecar_path, IvfIndex};
use crate::lc::{EngineParams, LcEngine};
use crate::obs::TraceCollector;
use crate::remote::{RemoteFleet, Topology};
use crate::runtime::{ArtifactEngine, Executor};
use crate::shard::{
    append_segment, clear_segments, load_manifest_for, reconstruct, replay_segments,
    save_manifest, segments_dir, AppendOutcome, ShardStat, ShardedCorpus,
};

use super::metrics::Metrics;
use super::plan::{self, QueryPlan, SearchRequest, SearchResponse};
use super::router::Router;
use super::topl::TopL;

/// A single query's result.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// (distance, database id), best first.
    pub hits: Vec<(f32, usize)>,
    /// label of each hit (convenience for evaluation clients).
    pub labels: Vec<u16>,
}

/// The coordinator-owned search engine.
pub struct SearchEngine {
    dataset: Arc<Dataset>,
    config: Config,
    metrics: Arc<Metrics>,
    router: Router,
    /// cached native engine (precomputed norms/centroids) — building it per
    /// query would redo O(nnz·m) work on the request path
    native: Arc<LcEngine>,
    /// trained IVF pruning index (native backend with `config.index` set);
    /// loaded from the dataset's `EMDX` sidecar when one matches, trained
    /// from the engine's WCD centroids otherwise.  `None` when the engine
    /// is sharded — each shard owns its own index instead.
    index: Option<Arc<IvfIndex>>,
    /// sharded live corpus (`config.sharded` on the native backend): the
    /// fan-out route replaces the monolithic sweep and the corpus accepts
    /// appended documents behind the write lock
    sharded: Option<RwLock<ShardedCorpus>>,
    /// remote shard fleet (`config.remote`): the shard fan-out stage
    /// dispatches over TCP to `emdpar node` replicas instead of the
    /// in-process shard engines, with hedging and per-shard deadlines
    remote: Option<Arc<RemoteFleet>>,
    /// fingerprint of the persisted base dataset that `EMDX` v3 append
    /// segments chain onto; refreshed when [`SearchEngine::persist_shards`]
    /// folds the segments into a rewritten base (0 when nothing on disk)
    base_fingerprint: AtomicU64,
    executor: Option<Executor>,
    artifact_profile: Option<String>,
    /// shared span ring every traced execute (and the reactor's conn
    /// read/write phases) records into
    tracer: Arc<TraceCollector>,
    /// sliding-window per-workload telemetry store the serving bridge
    /// records into (armed iff `config.serve.telemetry_window_ms > 0`)
    telemetry: Arc<crate::obs::agg::Telemetry>,
    /// online recall auditor (sampling off when
    /// `config.serve.audit_sample == 0`); the bridge spawns its worker
    auditor: Arc<crate::obs::audit::Auditor>,
    /// slow-query log threshold in µs (0 = off); `EMDPAR_SLOW_QUERY_US`
    /// overrides `config.serve.slow_query_us` at construction
    slow_query_us: u64,
}

impl SearchEngine {
    /// Build from a config (loads/generates the dataset; connects the PJRT
    /// runtime when `backend = artifact`).
    pub fn from_config(config: Config) -> EmdResult<SearchEngine> {
        let dataset = Arc::new(config.load_dataset()?);
        Self::with_dataset(config, dataset)
    }

    /// Build around an existing dataset (used by tests and examples).
    pub fn with_dataset(config: Config, dataset: Arc<Dataset>) -> EmdResult<SearchEngine> {
        let router = Router::new(dataset.len(), config.shards);
        let (executor, artifact_profile) = if config.backend == Backend::Artifact {
            let exec = Executor::new(&config.artifact_dir)?;
            let profile = match &config.artifact_profile {
                Some(p) => p.clone(),
                None => {
                    // auto-select: smallest profile that fits the dataset
                    let stats = dataset.stats();
                    // queries can be as large as the widest histogram
                    let hmax = (0..dataset.len())
                        .map(|u| dataset.matrix.row(u).0.len())
                        .max()
                        .unwrap_or(1);
                    exec.manifest()
                        .fitting_profiles(stats.vocab_size, stats.dim, hmax)
                        .into_iter()
                        .next()
                        .ok_or_else(|| {
                            EmdError::artifact(format!(
                                "no artifact profile fits v={} m={} h<={hmax}; \
                                 regenerate with `make artifacts`",
                                stats.vocab_size, stats.dim
                            ))
                        })?
                }
            };
            (Some(exec), Some(profile))
        } else {
            (None, None)
        };
        let engine_params = EngineParams {
            metric: config.metric,
            threads: config.threads,
            symmetric: config.symmetric,
            batch_block: config.batch_block,
            kernel: config.kernel,
            compressed: config.compressed,
        };
        let native = Arc::new(LcEngine::new(Arc::clone(&dataset), engine_params));
        let sharded = match (&config.sharded, config.backend) {
            (Some(sp), Backend::Native) => {
                Some(RwLock::new(Self::build_shards(&config, sp, &dataset, engine_params)?))
            }
            _ => None,
        };
        let remote = match &config.remote {
            Some(rp) => {
                let lock = sharded.as_ref().ok_or_else(|| {
                    EmdError::config(
                        "remote fan-out requires the sharded corpus (set 'shard' in the config)",
                    )
                })?;
                let topo = Topology::from_file(Path::new(&rp.topology))?;
                let corpus_shards = lock.read().unwrap().num_shards();
                emd_ensure!(
                    topo.num_shards() == corpus_shards,
                    config,
                    "topology {} declares {} shards but the corpus has {}",
                    rp.topology,
                    topo.num_shards(),
                    corpus_shards
                );
                Some(Arc::new(RemoteFleet::new(&topo, rp.clone())))
            }
            None => None,
        };
        // appends chain onto the persisted base by fingerprint; only a
        // file-backed sharded corpus has a base on disk
        let base_fingerprint = match (&sharded, Self::segment_base(&config.dataset)) {
            (Some(_), Some(_)) => dataset_fingerprint(&dataset),
            _ => 0,
        };
        // a sharded engine trains per-shard indexes instead of one global one
        let index = match (&config.index, config.backend, &sharded) {
            (Some(params), Backend::Native, None) => {
                let mut ix = Self::build_index(&config, params, &dataset, &native)?;
                // compressed residency extends to the coarse quantizer: probe
                // against f16 centroids when the engine's stage 1 is f16 too
                if config.compressed != crate::core::CompressedKind::Off {
                    ix.enable_compressed_centroids();
                }
                Some(Arc::new(ix))
            }
            _ => None,
        };
        let slow_query_us = std::env::var("EMDPAR_SLOW_QUERY_US")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(config.serve.slow_query_us);
        let tracer = Arc::new(TraceCollector::new(config.serve.trace_buffer));
        if slow_query_us > 0 {
            // arm ambient collection so even untraced requests land spans
            // for the slow-query log to report
            tracer.set_enabled(true);
        }
        let telemetry =
            Arc::new(crate::obs::agg::Telemetry::new(config.serve.telemetry_window_ms));
        let auditor = Arc::new(crate::obs::audit::Auditor::new(config.serve.audit_sample));
        Ok(SearchEngine {
            dataset,
            config,
            metrics: Arc::new(Metrics::new()),
            router,
            native,
            index,
            sharded,
            remote,
            base_fingerprint: AtomicU64::new(base_fingerprint),
            executor,
            artifact_profile,
            tracer,
            telemetry,
            auditor,
            slow_query_us,
        })
    }

    /// Build the sharded live corpus, then replay the dataset's `EMDX`
    /// **v3** append-segment chain (documents appended since the base file
    /// was last rewritten) through the deterministic append placement.  A
    /// stale or broken chain is a hard error — silently dropping persisted
    /// appends would be data loss; the operator removes the segment
    /// directory to accept it.
    fn build_shards(
        config: &Config,
        sp: &ShardParams,
        dataset: &Dataset,
        engine_params: EngineParams,
    ) -> EmdResult<ShardedCorpus> {
        let mut corpus = Self::base_shards(config, sp, dataset, engine_params)?;
        if let Some(base) = Self::segment_base(&config.dataset) {
            let dir = segments_dir(&base);
            let replayed = replay_segments(&mut corpus, &dir, dataset_fingerprint(dataset))?;
            if replayed > 0 {
                crate::log_info!(
                    "shard",
                    "replayed {replayed} appended docs from {dir:?} ({} live docs)",
                    corpus.len()
                );
            }
        }
        Ok(corpus)
    }

    /// The base corpus before segment replay: the dataset's `EMDX` **v2**
    /// shard manifest when it exists and matches the dataset's fingerprint
    /// (a restarted server reloads the same live layout and per-shard
    /// indexes); otherwise a fresh partition from the config.
    fn base_shards(
        config: &Config,
        sp: &ShardParams,
        dataset: &Dataset,
        engine_params: EngineParams,
    ) -> EmdResult<ShardedCorpus> {
        if let DatasetSpec::File(path) = &config.dataset {
            let sidecar = sidecar_path(path);
            if sidecar.exists() {
                let fingerprint = dataset_fingerprint(dataset);
                match load_manifest_for(&sidecar, fingerprint).and_then(|man| {
                    reconstruct(
                        dataset,
                        &man,
                        Some(sp.max_docs_per_shard),
                        engine_params,
                        config.index.as_ref(),
                    )
                }) {
                    Ok(corpus) => {
                        crate::log_info!(
                            "shard",
                            "loaded {:?}: {} shards over {} docs",
                            sidecar,
                            corpus.num_shards(),
                            corpus.len()
                        );
                        return Ok(corpus);
                    }
                    Err(e) => {
                        crate::log_info!("shard", "manifest {sidecar:?} rejected ({e}); rebuilding")
                    }
                }
            }
        }
        ShardedCorpus::build(dataset, *sp, engine_params, config.index.as_ref())
    }

    /// The on-disk base that append segments chain onto: the dataset file
    /// itself, or a per-slice sibling for node slices (`data.bin.s2of4` for
    /// shard 2 of 4) so every node of a shared base file keeps its own
    /// segment directory.  `None` for synthetic datasets — nothing on disk
    /// to persist against.
    fn segment_base(dataset: &DatasetSpec) -> Option<PathBuf> {
        match dataset {
            DatasetSpec::File(path) => Some(path.clone()),
            DatasetSpec::Slice { file, shard, of } => {
                let mut name = match file.file_name() {
                    Some(n) => n.to_string_lossy().into_owned(),
                    None => "dataset".to_string(),
                };
                name.push_str(&format!(".s{shard}of{of}"));
                Some(file.with_file_name(name))
            }
            _ => None,
        }
    }

    /// Load the dataset's `EMDX` sidecar when it exists and matches the
    /// dataset's fingerprint; otherwise train a fresh index from the native
    /// engine's WCD centroid table.
    fn build_index(
        config: &Config,
        params: &IndexParams,
        dataset: &Dataset,
        native: &LcEngine,
    ) -> EmdResult<IvfIndex> {
        let fingerprint = dataset_fingerprint(dataset);
        if let DatasetSpec::File(path) = &config.dataset {
            let sidecar = sidecar_path(path);
            if sidecar.exists() {
                match load_index_for(&sidecar, fingerprint) {
                    Ok(ix) => {
                        crate::log_info!(
                            "index",
                            "loaded {:?}: {} lists over {} docs",
                            sidecar,
                            ix.nlist(),
                            ix.num_points()
                        );
                        return Ok(ix);
                    }
                    Err(e) => {
                        crate::log_info!("index", "sidecar {sidecar:?} rejected ({e}); retraining")
                    }
                }
            }
        }
        IvfIndex::train(
            native.wcd_centroids(),
            dataset.embeddings.dim(),
            params,
            config.threads,
            fingerprint,
        )
    }

    /// The dataset the engine was *built* around.  A sharded engine's live
    /// corpus can outgrow this snapshot through appends — serving paths
    /// should use [`SearchEngine::num_docs`] / [`SearchEngine::doc_histogram`].
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// Documents currently searchable (the live corpus size when sharded).
    pub fn num_docs(&self) -> usize {
        match &self.sharded {
            Some(lock) => lock.read().unwrap().len(),
            None => self.dataset.len(),
        }
    }

    /// The histogram of document `id` in the live corpus.
    pub fn doc_histogram(&self, id: usize) -> EmdResult<Histogram> {
        match &self.sharded {
            Some(lock) => {
                let corpus = lock.read().unwrap();
                emd_ensure!(
                    id < corpus.len(),
                    config,
                    "doc id {id} out of range ({} docs)",
                    corpus.len()
                );
                Ok(corpus.histogram(id))
            }
            None => {
                emd_ensure!(
                    id < self.dataset.len(),
                    config,
                    "doc id {id} out of range ({} docs)",
                    self.dataset.len()
                );
                Ok(self.dataset.histogram(id))
            }
        }
    }

    /// The label of document `id` in the live corpus (the sharded corpus
    /// when configured — appended documents included — else the dataset).
    pub fn doc_label(&self, id: usize) -> EmdResult<u16> {
        match &self.sharded {
            Some(lock) => {
                let corpus = lock.read().unwrap();
                emd_ensure!(
                    id < corpus.len(),
                    config,
                    "doc id {id} out of range ({} docs)",
                    corpus.len()
                );
                Ok(corpus.label(id))
            }
            None => {
                emd_ensure!(
                    id < self.dataset.len(),
                    config,
                    "doc id {id} out of range ({} docs)",
                    self.dataset.len()
                );
                Ok(self.dataset.labels[id])
            }
        }
    }

    /// Per-shard shape snapshot (`None` when the engine is not sharded).
    pub fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        Some(self.sharded.as_ref()?.read().unwrap().shard_stats())
    }

    /// Append documents to the sharded live corpus: each lands in the
    /// smallest shard (or a fresh shard past the configured size
    /// threshold), joins that shard's already-trained IVF centroids without
    /// retraining, and becomes immediately searchable.  File-backed (and
    /// slice-backed) datasets persist the batch as one `O(batch)` `EMDX`
    /// v3 append segment — the base dataset file is **not** rewritten; a
    /// restart replays the segment chain.  `labels` may be empty (label 0)
    /// or one per document.
    ///
    /// If persistence fails (e.g. disk full) the documents are **already
    /// live in memory** — the returned error says so explicitly; do not
    /// blindly retry the append (that would insert duplicates under new
    /// ids), fix the disk and call [`SearchEngine::persist_shards`].
    pub fn add_docs(&self, docs: &[Histogram], labels: &[u16]) -> EmdResult<AppendOutcome> {
        let lock = self.sharded.as_ref().ok_or_else(|| {
            EmdError::unsupported(
                "add_docs requires a sharded corpus (set 'shard' in the config or \
                 EngineBuilder::sharded)",
            )
        })?;
        // the segment write stays under the corpus write lock so concurrent
        // appends land segments in placement order — an interleaved chain
        // would fail the base_global continuity check on replay
        let (outcome, persisted) = {
            let mut corpus = lock.write().unwrap();
            let base_global = corpus.len();
            let outcome = corpus.append(docs, labels)?;
            let persisted = self.persist_append(docs, labels, base_global);
            (outcome, persisted)
        };
        if let Err(e) = persisted {
            return Err(EmdError::io(format!(
                "appended {} docs (ids {:?}) into the live corpus but persisting the \
                 append segment failed: {e}; the documents ARE searchable in this \
                 process — do not retry the append, repair the disk and call \
                 persist_shards",
                outcome.ids.len(),
                outcome.ids
            )));
        }
        Ok(outcome)
    }

    /// Persist one accepted append batch as an `EMDX` v3 segment chained
    /// onto the current base fingerprint.  `O(batch)` disk work; the base
    /// dataset file is never touched.  `Ok(false)` when there is no on-disk
    /// base (synthetic dataset).
    fn persist_append(
        &self,
        docs: &[Histogram],
        labels: &[u16],
        base_global: usize,
    ) -> EmdResult<bool> {
        let base = match Self::segment_base(&self.config.dataset) {
            Some(base) => base,
            None => return Ok(false),
        };
        append_segment(
            &segments_dir(&base),
            self.base_fingerprint.load(Ordering::Relaxed),
            base_global,
            docs,
            labels,
        )?;
        Ok(true)
    }

    /// Fold the sharded live corpus into its file-backed base: rewrite the
    /// `EMD1` dataset (appended documents included, existing rows
    /// bit-exact) and the `EMDX` v2 shard manifest, then clear the append
    /// segments the rewrite absorbed.  Returns `false` when the engine is
    /// not sharded or the dataset is not file-backed (slice-backed nodes
    /// never rewrite the shared base file — their appends live in the
    /// per-slice segment chain).
    pub fn persist_shards(&self) -> EmdResult<bool> {
        let (lock, path) = match (&self.sharded, &self.config.dataset) {
            (Some(lock), DatasetSpec::File(path)) => (lock, path.clone()),
            _ => return Ok(false),
        };
        let corpus = lock.read().unwrap();
        let full = corpus.to_dataset(self.dataset.name.clone());
        crate::data::save(&full, &path)?;
        let fingerprint = dataset_fingerprint(&full);
        save_manifest(&corpus, fingerprint, &sidecar_path(&path))?;
        // the rewrite absorbed every appended batch: the segment chain is
        // stale by construction, and future appends chain onto the new base
        clear_segments(&segments_dir(&path))?;
        self.base_fingerprint.store(fingerprint, Ordering::Relaxed);
        Ok(true)
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The engine's shared span ring (borrowed; see
    /// [`SearchEngine::tracer_arc`] for a clonable handle).
    pub fn tracer(&self) -> &TraceCollector {
        &self.tracer
    }

    /// Clonable handle to the span ring (the reactor path holds one).
    pub fn tracer_arc(&self) -> Arc<TraceCollector> {
        Arc::clone(&self.tracer)
    }

    /// The per-workload sliding-window telemetry store (borrowed).
    pub fn telemetry(&self) -> &crate::obs::agg::Telemetry {
        &self.telemetry
    }

    /// Clonable handle to the telemetry store (the metrics listener and
    /// shutdown flush hold one).
    pub fn telemetry_arc(&self) -> Arc<crate::obs::agg::Telemetry> {
        Arc::clone(&self.telemetry)
    }

    /// The online recall auditor (borrowed).
    pub fn auditor(&self) -> &crate::obs::audit::Auditor {
        &self.auditor
    }

    /// Clonable handle to the auditor (the replay worker holds one).
    pub fn auditor_arc(&self) -> Arc<crate::obs::audit::Auditor> {
        Arc::clone(&self.auditor)
    }

    /// Readiness for `/readyz`: the corpus is loaded and every configured
    /// pruning index is trained.  (Admission saturation is layered on by
    /// the serving runtime, which owns the in-flight budget.)
    pub fn ready(&self) -> bool {
        if self.num_docs() == 0 {
            return false;
        }
        if self.config.index.is_none() {
            return true;
        }
        match &self.sharded {
            // every shard of an index-configured corpus must have trained
            // centroids before pruned routes answer faithfully
            Some(lock) => {
                lock.read().unwrap().shards().iter().all(|s| s.index().is_some())
            }
            None => self.index.is_some(),
        }
    }

    /// Slow-query log threshold in µs (0 = disabled).
    pub fn slow_query_us(&self) -> u64 {
        self.slow_query_us
    }

    pub fn config(&self) -> &Config {
        &self.config
    }

    /// The cached native LC engine (shared handle, e.g. for cascades).
    pub fn native(&self) -> Arc<LcEngine> {
        Arc::clone(&self.native)
    }

    /// The trained IVF pruning index, when one is configured.
    pub fn index(&self) -> Option<Arc<IvfIndex>> {
        self.index.clone()
    }

    /// Resolve a request's probe width to its effective value: the
    /// configured default fills a missing value, and anything `>= nlist`
    /// collapses to exactly `nlist` (the exhaustive route).  `None` when no
    /// index is configured.  The single source of truth for nprobe
    /// semantics — the server's batch-grouping key uses it too, so TCP
    /// clients and direct API callers always route identically.
    pub fn effective_nprobe(&self, nprobe: Option<usize>) -> Option<usize> {
        if let Some(lock) = &self.sharded {
            // sharded route: clamp against the widest shard's list count
            // (each shard clamps further at probe time)
            return lock
                .read()
                .unwrap()
                .effective_nprobe(nprobe, self.config.index.as_ref().map(|p| p.nprobe));
        }
        let index = self.index.as_deref()?;
        Some(
            nprobe
                .or_else(|| self.config.index.as_ref().map(|p| p.nprobe))
                .unwrap_or(1)
                .max(1)
                .min(index.nlist()),
        )
    }

    /// Resolve the pruning route for a request: the index plus the
    /// effective probe width.  `None` means exhaustive — no index, or the
    /// effective `nprobe` covers every list anyway.
    pub(crate) fn pruning_route(&self, nprobe: Option<usize>) -> Option<(&IvfIndex, usize)> {
        let np = self.effective_nprobe(nprobe)?;
        let index = self.index.as_deref()?;
        if np >= index.nlist() {
            None
        } else {
            Some((index, np))
        }
    }

    /// A registry configured with this engine's ground metric.
    pub fn registry(&self) -> MethodRegistry {
        self.native.registry()
    }

    /// The native engine by reference (planner-internal fast path).
    pub(crate) fn native_ref(&self) -> &LcEngine {
        &self.native
    }

    /// The sharded live corpus, when configured (planner-internal).
    pub(crate) fn sharded_corpus(&self) -> Option<&RwLock<ShardedCorpus>> {
        self.sharded.as_ref()
    }

    /// The remote shard fleet, when `config.remote` is set (the planner
    /// dispatches the fan-out stage through it; the serving surfaces
    /// report its health).
    pub fn remote_fleet(&self) -> Option<&Arc<RemoteFleet>> {
        self.remote.as_ref()
    }

    /// Full distance row for a query under the configured backend.
    pub fn distances(&self, query: &Histogram, method: Method) -> EmdResult<Vec<f32>> {
        match self.config.backend {
            Backend::Native => Ok(self.native.distances(query, method)),
            Backend::Artifact => {
                let exec = self.executor.as_ref().expect("artifact backend has executor");
                let profile = self.artifact_profile.as_deref().unwrap();
                let art = ArtifactEngine::new(exec, &self.dataset, profile)?;
                let k = match method {
                    Method::Rwmd => 1,
                    Method::Act { k } => k,
                    other => {
                        return Err(EmdError::unsupported(format!(
                            "artifact backend supports RWMD/ACT, not {}",
                            other.name()
                        )))
                    }
                };
                art.distances(query, k, self.config.symmetric)
            }
        }
    }

    /// Build the execution plan for a request without running it: resolved
    /// parameters plus the stage DAG
    /// (`Prune → Score → [ShardFanout + Merge] → [CascadeRerank]`).
    pub fn plan(&self, request: &SearchRequest) -> EmdResult<QueryPlan> {
        plan::plan(self, request)
    }

    /// Plan and execute one [`SearchRequest`] — **the** serving entry
    /// point.  Index pruning, shard fan-out and cascade rerank compose in
    /// any combination; the legacy `search*` methods below are thin
    /// delegating shims over this.
    pub fn execute(&self, request: &SearchRequest) -> EmdResult<SearchResponse> {
        plan::execute(self, request)
    }

    /// Rank one distance row: top-ℓ with shard-merge.  The shard-wise
    /// accumulation exercises the same merge path the distributed router
    /// uses; results are shard-count-invariant.
    pub(crate) fn rank_row(&self, row: &[f32], l: usize) -> SearchResult {
        let mut acc = TopL::new(l);
        for shard in self.router.shards() {
            let mut local = TopL::new(l);
            local.push_slice(&row[shard.clone()], shard.start);
            acc.merge(&local);
        }
        let hits = acc.into_sorted();
        let labels = hits.iter().map(|&(_, id)| self.dataset.labels[id]).collect();
        SearchResult { hits, labels }
    }

    /// Build the [`SearchRequest`] a legacy `(method, l, nprobe)` call
    /// describes (the shims below all funnel through this).
    fn legacy_request(
        &self,
        queries: Vec<Histogram>,
        method: Method,
        l: usize,
        nprobe: Option<usize>,
    ) -> SearchRequest {
        let mut req = SearchRequest::batch(queries).method(method).topl(l);
        if let Some(np) = nprobe {
            req = req.nprobe(np);
        }
        req
    }

    /// Top-ℓ search with shard-merge.  Goes through the IVF pruning index
    /// when one is configured.
    #[deprecated(
        since = "0.3.0",
        note = "construct a SearchRequest and call SearchEngine::execute"
    )]
    pub fn search(&self, query: &Histogram, method: Method, l: usize) -> EmdResult<SearchResult> {
        self.search_opts(query, method, l, None)
    }

    /// Top-ℓ search with an optional per-request probe width.
    /// `nprobe = None` uses the configured index default; `Some(np)` with
    /// `np >= nlist` (or no index at all) falls back to the exhaustive
    /// sweep.  A delegating shim over [`SearchEngine::execute`]; results are
    /// bit-identical to the planner's.
    #[deprecated(
        since = "0.3.0",
        note = "construct a SearchRequest and call SearchEngine::execute"
    )]
    pub fn search_opts(
        &self,
        query: &Histogram,
        method: Method,
        l: usize,
        nprobe: Option<usize>,
    ) -> EmdResult<SearchResult> {
        let req = self.legacy_request(vec![query.clone()], method, l, nprobe);
        let mut resp = self.execute(&req)?;
        Ok(resp.results.pop().expect("one query in, one result out"))
    }

    /// Batched search (one grouped dispatch through the multi-query
    /// kernels); a delegating shim over [`SearchEngine::execute`].
    #[deprecated(
        since = "0.3.0",
        note = "construct a SearchRequest and call SearchEngine::execute"
    )]
    pub fn search_batch(
        &self,
        queries: &[Histogram],
        method: Method,
        l: usize,
    ) -> EmdResult<Vec<SearchResult>> {
        self.search_batch_opts(queries, method, l, None)
    }

    /// Batched search with an optional per-request probe width; a
    /// delegating shim over [`SearchEngine::execute`].
    #[deprecated(
        since = "0.3.0",
        note = "construct a SearchRequest and call SearchEngine::execute"
    )]
    pub fn search_batch_opts(
        &self,
        queries: &[Histogram],
        method: Method,
        l: usize,
        nprobe: Option<usize>,
    ) -> EmdResult<Vec<SearchResult>> {
        let req = self.legacy_request(queries.to_vec(), method, l, nprobe);
        Ok(self.execute(&req)?.results)
    }

    /// Per-job batched search for grouped dispatch: every job is evaluated
    /// **at most once** on the native backend (the planner's grouped call
    /// either succeeds for everyone or fails before any query is scored, in
    /// which case each job is evaluated individually once), and each job's
    /// outcome lands in its own slot of the returned buffer.  A delegating
    /// shim over [`SearchEngine::execute`].
    #[deprecated(
        since = "0.3.0",
        note = "construct a SearchRequest and call SearchEngine::execute"
    )]
    pub fn search_batch_results(
        &self,
        queries: &[Histogram],
        method: Method,
        l: usize,
        nprobe: Option<usize>,
    ) -> Vec<EmdResult<SearchResult>> {
        if queries.is_empty() {
            return Vec::new();
        }
        let per_query = |q: &Histogram| {
            let single = self.legacy_request(vec![q.clone()], method, l, nprobe);
            self.execute(&single)
                .map(|mut r| r.results.pop().expect("one query in, one result out"))
        };
        // the artifact runtime plans per query anyway: evaluate per job
        // from the start so one query outside the compiled profile fails
        // alone instead of discarding and re-running its batchmates
        if self.config.backend == Backend::Artifact {
            return queries.iter().map(per_query).collect();
        }
        let req = self.legacy_request(queries.to_vec(), method, l, nprobe);
        match self.execute(&req) {
            Ok(resp) => resp.results.into_iter().map(Ok).collect(),
            // the grouped dispatch failed as a whole before scoring anything
            // (e.g. an empty query in the probe stage): evaluate per job
            Err(_) => queries.iter().map(per_query).collect(),
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy shims are exercised deliberately here
mod tests {
    use super::*;
    use crate::config::DatasetSpec;

    fn engine() -> SearchEngine {
        let config = Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 200, dim: 8, seed: 3 },
            threads: 2,
            shards: 3,
            ..Default::default()
        };
        SearchEngine::from_config(config).unwrap()
    }

    #[test]
    fn search_returns_sorted_hits_excluding_nothing() {
        let eng = engine();
        let q = eng.dataset().histogram(0);
        let res = eng.search(&q, Method::Act { k: 2 }, 5).unwrap();
        assert_eq!(res.hits.len(), 5);
        assert!(res.hits.windows(2).all(|w| w[0].0 <= w[1].0));
        // the query is in the database: best hit must be itself at ~0
        assert_eq!(res.hits[0].1, 0);
        assert!(res.hits[0].0.abs() < 1e-5);
        assert_eq!(res.labels.len(), 5);
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let mk = |shards| {
            let config = Config {
                dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 4 },
                threads: 1,
                shards,
                ..Default::default()
            };
            SearchEngine::from_config(config).unwrap()
        };
        let a = mk(1);
        let b = mk(7);
        let q = a.dataset().histogram(5);
        let ra = a.search(&q, Method::Rwmd, 4).unwrap();
        let rb = b.search(&q, Method::Rwmd, 4).unwrap();
        assert_eq!(ra.hits, rb.hits);
    }

    #[test]
    fn metrics_accumulate() {
        let eng = engine();
        let q = eng.dataset().histogram(1);
        eng.search(&q, Method::Rwmd, 3).unwrap();
        eng.search(&q, Method::Rwmd, 3).unwrap();
        let m = eng.metrics();
        assert_eq!(m.queries.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(
            m.distance_evals.load(std::sync::atomic::Ordering::Relaxed),
            2 * 40
        );
    }

    #[test]
    fn index_routes_and_falls_back_consistently() {
        let mk = |index: Option<IndexParams>| {
            let config = Config {
                dataset: DatasetSpec::SynthText { n: 60, vocab: 250, dim: 10, seed: 8 },
                threads: 2,
                index,
                ..Default::default()
            };
            SearchEngine::from_config(config).unwrap()
        };
        let plain = mk(None);
        assert!(plain.index().is_none());
        let indexed = mk(Some(IndexParams {
            nlist: 6,
            nprobe: 2,
            train_iters: 6,
            seed: 3,
            min_points_per_list: 1,
        }));
        let ix = indexed.index().expect("index trained");
        assert_eq!(ix.num_points(), 60);

        let q = plain.dataset().histogram(4);
        // nprobe >= nlist falls back to the exhaustive sweep: identical hits
        let exhaustive = plain.search(&q, Method::Rwmd, 5).unwrap();
        let full_probe = indexed.search_opts(&q, Method::Rwmd, 5, Some(ix.nlist())).unwrap();
        assert_eq!(exhaustive.hits, full_probe.hits);

        // the pruned route scores fewer candidates and records probe metrics
        let pruned = indexed.search_opts(&q, Method::Rwmd, 5, Some(2)).unwrap();
        assert_eq!(pruned.hits.len(), 5);
        assert_eq!(pruned.hits[0].1, 4, "a database query finds itself");
        let m = indexed.metrics();
        assert_eq!(m.index_queries.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(m.pruned_fraction() > 0.0, "nprobe 2 of 6 lists must prune");

        // batched pruned search equals per-query pruned search
        let queries: Vec<_> = (0..4).map(|u| plain.dataset().histogram(u)).collect();
        let batch = indexed
            .search_batch_opts(&queries, Method::Act { k: 2 }, 4, Some(2))
            .unwrap();
        for (q, got) in queries.iter().zip(&batch) {
            let single = indexed.search_opts(q, Method::Act { k: 2 }, 4, Some(2)).unwrap();
            assert_eq!(got.hits, single.hits);
        }
    }

    #[test]
    fn sharded_route_matches_monolithic_and_accepts_appends() {
        let ds = Arc::new(
            Config {
                dataset: DatasetSpec::SynthText { n: 48, vocab: 220, dim: 8, seed: 21 },
                ..Default::default()
            }
            .load_dataset()
            .unwrap(),
        );
        let mono =
            SearchEngine::with_dataset(Config { threads: 2, ..Default::default() }, Arc::clone(&ds))
                .unwrap();
        let sharded = SearchEngine::with_dataset(
            Config {
                threads: 2,
                sharded: Some(ShardParams { shards: 3, max_docs_per_shard: 1 << 20 }),
                ..Default::default()
            },
            Arc::clone(&ds),
        )
        .unwrap();
        assert!(sharded.index().is_none(), "per-shard indexes replace the global one");
        assert_eq!(sharded.num_docs(), 48);
        let queries: Vec<_> = (0..3).map(|u| ds.histogram(u * 5)).collect();
        for method in [Method::Rwmd, Method::Act { k: 2 }] {
            let a = mono.search_batch(&queries, method, 6).unwrap();
            let b = sharded.search_batch(&queries, method, 6).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.hits, y.hits, "{method}");
                assert_eq!(x.labels, y.labels, "{method}");
            }
        }
        // merge metrics tick on the fan-out route
        assert!(
            sharded.metrics().shard_batches.load(std::sync::atomic::Ordering::Relaxed) > 0
        );
        // appends are immediately searchable; the monolithic engine refuses.
        // The appended doc's support is distinct from every generated doc,
        // so only the doc itself reaches distance 0
        let doc = Histogram::from_pairs(vec![(2, 0.7), (5, 0.3)]);
        assert!(mono.add_docs(std::slice::from_ref(&doc), &[3]).is_err());
        let out = sharded.add_docs(std::slice::from_ref(&doc), &[3]).unwrap();
        assert_eq!(out.ids, vec![48]);
        assert_eq!(sharded.num_docs(), 49);
        let res = sharded
            .search(&sharded.doc_histogram(48).unwrap(), Method::Rwmd, 4)
            .unwrap();
        assert_eq!(res.hits[0].1, 48, "an appended doc finds itself");
        assert_eq!(res.labels[0], 3);
        assert_eq!(sharded.shard_stats().unwrap().len(), 3);
    }

    #[test]
    fn search_batch_results_buffers_per_job() {
        let eng = engine();
        let queries: Vec<_> = (0..3).map(|u| eng.dataset().histogram(u)).collect();
        let results = eng.search_batch_results(&queries, Method::Rwmd, 4, None);
        assert_eq!(results.len(), 3);
        for (q, r) in queries.iter().zip(results) {
            let want = eng.search(q, Method::Rwmd, 4).unwrap();
            assert_eq!(r.unwrap().hits, want.hits);
        }
    }

    #[test]
    fn quadratic_comparators_are_searchable() {
        // Sinkhorn and exact EMD answer top-ℓ queries through the same
        // engine entry point as the LC methods.
        let config = Config {
            dataset: DatasetSpec::SynthText { n: 16, vocab: 100, dim: 6, seed: 5 },
            threads: 2,
            ..Default::default()
        };
        let eng = SearchEngine::from_config(config).unwrap();
        let q = eng.dataset().histogram(3);
        for method in [Method::Exact, Method::Sinkhorn, Method::Ict] {
            let res = eng.search(&q, method, 4).unwrap();
            assert_eq!(res.hits.len(), 4, "{method}");
            assert!(res.hits.windows(2).all(|w| w[0].0 <= w[1].0), "{method}");
        }
        // exact EMD must rank the query itself first
        let res = eng.search(&q, Method::Exact, 4).unwrap();
        assert_eq!(res.hits[0].1, 3);
    }
}
