//! Search-engine façade: one object that owns the dataset, answers top-ℓ
//! queries through either backend (native CPU LC engine or the PJRT
//! artifact runtime), and records metrics.  This is what the server, the
//! CLI and the examples all drive.  Construct it through
//! [`crate::builder::EngineBuilder`] or from a [`Config`].

use std::sync::Arc;
use std::time::Instant;

use crate::config::{Backend, Config};
use crate::core::{Dataset, EmdError, EmdResult, Histogram, Method, MethodRegistry};
use crate::lc::{EngineParams, LcEngine};
use crate::runtime::{ArtifactEngine, Executor};

use super::metrics::Metrics;
use super::router::Router;
use super::topl::TopL;

/// A single query's result.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// (distance, database id), best first.
    pub hits: Vec<(f32, usize)>,
    /// label of each hit (convenience for evaluation clients).
    pub labels: Vec<u16>,
}

/// The coordinator-owned search engine.
pub struct SearchEngine {
    dataset: Arc<Dataset>,
    config: Config,
    metrics: Arc<Metrics>,
    router: Router,
    /// cached native engine (precomputed norms/centroids) — building it per
    /// query would redo O(nnz·m) work on the request path
    native: Arc<LcEngine>,
    executor: Option<Executor>,
    artifact_profile: Option<String>,
}

impl SearchEngine {
    /// Build from a config (loads/generates the dataset; connects the PJRT
    /// runtime when `backend = artifact`).
    pub fn from_config(config: Config) -> EmdResult<SearchEngine> {
        let dataset = Arc::new(config.load_dataset()?);
        Self::with_dataset(config, dataset)
    }

    /// Build around an existing dataset (used by tests and examples).
    pub fn with_dataset(config: Config, dataset: Arc<Dataset>) -> EmdResult<SearchEngine> {
        let router = Router::new(dataset.len(), config.shards);
        let (executor, artifact_profile) = if config.backend == Backend::Artifact {
            let exec = Executor::new(&config.artifact_dir)?;
            let profile = match &config.artifact_profile {
                Some(p) => p.clone(),
                None => {
                    // auto-select: smallest profile that fits the dataset
                    let stats = dataset.stats();
                    // queries can be as large as the widest histogram
                    let hmax = (0..dataset.len())
                        .map(|u| dataset.matrix.row(u).0.len())
                        .max()
                        .unwrap_or(1);
                    exec.manifest()
                        .fitting_profiles(stats.vocab_size, stats.dim, hmax)
                        .into_iter()
                        .next()
                        .ok_or_else(|| {
                            EmdError::artifact(format!(
                                "no artifact profile fits v={} m={} h<={hmax}; \
                                 regenerate with `make artifacts`",
                                stats.vocab_size, stats.dim
                            ))
                        })?
                }
            };
            (Some(exec), Some(profile))
        } else {
            (None, None)
        };
        let native = Arc::new(LcEngine::new(
            Arc::clone(&dataset),
            EngineParams {
                metric: config.metric,
                threads: config.threads,
                symmetric: config.symmetric,
                batch_block: config.batch_block,
            },
        ));
        Ok(SearchEngine {
            dataset,
            config,
            metrics: Arc::new(Metrics::new()),
            router,
            native,
            executor,
            artifact_profile,
        })
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn config(&self) -> &Config {
        &self.config
    }

    /// The cached native LC engine (shared handle, e.g. for cascades).
    pub fn native(&self) -> Arc<LcEngine> {
        Arc::clone(&self.native)
    }

    /// A registry configured with this engine's ground metric.
    pub fn registry(&self) -> MethodRegistry {
        self.native.registry()
    }

    /// Full distance row for a query under the configured backend.
    pub fn distances(&self, query: &Histogram, method: Method) -> EmdResult<Vec<f32>> {
        match self.config.backend {
            Backend::Native => Ok(self.native.distances(query, method)),
            Backend::Artifact => {
                let exec = self.executor.as_ref().expect("artifact backend has executor");
                let profile = self.artifact_profile.as_deref().unwrap();
                let art = ArtifactEngine::new(exec, &self.dataset, profile)?;
                let k = match method {
                    Method::Rwmd => 1,
                    Method::Act { k } => k,
                    other => {
                        return Err(EmdError::unsupported(format!(
                            "artifact backend supports RWMD/ACT, not {}",
                            other.name()
                        )))
                    }
                };
                art.distances(query, k, self.config.symmetric)
            }
        }
    }

    /// Rank one distance row: top-ℓ with shard-merge.  The shard-wise
    /// accumulation exercises the same merge path the distributed router
    /// uses; results are shard-count-invariant.
    fn rank_row(&self, row: &[f32], l: usize) -> SearchResult {
        let mut acc = TopL::new(l);
        for shard in self.router.shards() {
            let mut local = TopL::new(l);
            local.push_slice(&row[shard.clone()], shard.start);
            acc.merge(&local);
        }
        let hits = acc.into_sorted();
        let labels = hits.iter().map(|&(_, id)| self.dataset.labels[id]).collect();
        SearchResult { hits, labels }
    }

    /// Top-ℓ search with shard-merge (the request-path entry point).
    pub fn search(&self, query: &Histogram, method: Method, l: usize) -> EmdResult<SearchResult> {
        let t0 = Instant::now();
        let row = self.distances(query, method)?;
        let result = self.rank_row(&row, l);
        self.metrics.record_query(t0.elapsed(), row.len());
        Ok(result)
    }

    /// Batched search (dispatched by the dynamic batcher / server).  On the
    /// native backend the whole batch flows through the engine's multi-query
    /// Phase-1 kernel ([`LcEngine::distances_batch`]) — one vocabulary pass
    /// per query block instead of one per query; results are bit-identical
    /// to per-query [`SearchEngine::search`].
    pub fn search_batch(
        &self,
        queries: &[Histogram],
        method: Method,
        l: usize,
    ) -> EmdResult<Vec<SearchResult>> {
        self.metrics.record_batch();
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        match self.config.backend {
            Backend::Native => {
                let t0 = Instant::now();
                let n = self.dataset.len();
                let flat = self.native.distances_batch(queries, method);
                let results: Vec<SearchResult> = (0..queries.len())
                    .map(|i| self.rank_row(&flat[i * n..(i + 1) * n], l))
                    .collect();
                // per-query latency = the batch's amortized share of the
                // full dispatch (distances + ranking), comparable to the
                // per-query path's measurement
                let per_query = t0.elapsed() / queries.len() as u32;
                for _ in 0..queries.len() {
                    self.metrics.record_query(per_query, n);
                }
                Ok(results)
            }
            // the artifact runtime plans per query; fall back to the
            // single-query path
            Backend::Artifact => queries.iter().map(|q| self.search(q, method, l)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;

    fn engine() -> SearchEngine {
        let config = Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 200, dim: 8, seed: 3 },
            threads: 2,
            shards: 3,
            ..Default::default()
        };
        SearchEngine::from_config(config).unwrap()
    }

    #[test]
    fn search_returns_sorted_hits_excluding_nothing() {
        let eng = engine();
        let q = eng.dataset().histogram(0);
        let res = eng.search(&q, Method::Act { k: 2 }, 5).unwrap();
        assert_eq!(res.hits.len(), 5);
        assert!(res.hits.windows(2).all(|w| w[0].0 <= w[1].0));
        // the query is in the database: best hit must be itself at ~0
        assert_eq!(res.hits[0].1, 0);
        assert!(res.hits[0].0.abs() < 1e-5);
        assert_eq!(res.labels.len(), 5);
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let mk = |shards| {
            let config = Config {
                dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 4 },
                threads: 1,
                shards,
                ..Default::default()
            };
            SearchEngine::from_config(config).unwrap()
        };
        let a = mk(1);
        let b = mk(7);
        let q = a.dataset().histogram(5);
        let ra = a.search(&q, Method::Rwmd, 4).unwrap();
        let rb = b.search(&q, Method::Rwmd, 4).unwrap();
        assert_eq!(ra.hits, rb.hits);
    }

    #[test]
    fn metrics_accumulate() {
        let eng = engine();
        let q = eng.dataset().histogram(1);
        eng.search(&q, Method::Rwmd, 3).unwrap();
        eng.search(&q, Method::Rwmd, 3).unwrap();
        let m = eng.metrics();
        assert_eq!(m.queries.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(
            m.distance_evals.load(std::sync::atomic::Ordering::Relaxed),
            2 * 40
        );
    }

    #[test]
    fn quadratic_comparators_are_searchable() {
        // Sinkhorn and exact EMD answer top-ℓ queries through the same
        // engine entry point as the LC methods.
        let config = Config {
            dataset: DatasetSpec::SynthText { n: 16, vocab: 100, dim: 6, seed: 5 },
            threads: 2,
            ..Default::default()
        };
        let eng = SearchEngine::from_config(config).unwrap();
        let q = eng.dataset().histogram(3);
        for method in [Method::Exact, Method::Sinkhorn, Method::Ict] {
            let res = eng.search(&q, method, 4).unwrap();
            assert_eq!(res.hits.len(), 4, "{method}");
            assert!(res.hits.windows(2).all(|w| w[0].0 <= w[1].0), "{method}");
        }
        // exact EMD must rank the query itself first
        let res = eng.search(&q, Method::Exact, 4).unwrap();
        assert_eq!(res.hits[0].1, 3);
    }
}
