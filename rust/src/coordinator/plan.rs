//! The query planner: one composable entry point for index × shards ×
//! cascade.
//!
//! A [`SearchRequest`] is the single value type every serving surface
//! (library API, TCP protocol, CLI, benches) constructs; the planner turns
//! it into an explicit [`QueryPlan`] — a small stage DAG of
//! `Prune(IVF) → Score(LC) → [ShardFanout + Merge] → [CascadeRerank]` —
//! and executes it, so index pruning, shard fan-out and bound-certified
//! cascade rerank compose in any combination.  In particular a request with
//! a [`CascadeSpec`] runs over a *sharded* corpus: per-shard stage-1
//! shortlists are merged into a global top-(overfetch·ℓ+1) RWMD shortlist
//! and the survivors are reranked with the dominating method, preserving
//! the bit-identity / certification contract at full probe.
//!
//! The legacy `SearchEngine::search*` methods and the
//! [`crate::coordinator::cascade`] free functions are thin delegating shims
//! over this module.
//!
//! ```no_run
//! use emdpar::prelude::*;
//!
//! let engine = EngineBuilder::new()
//!     .dataset_spec(DatasetSpec::SynthText { n: 1000, vocab: 2000, dim: 32, seed: 1 })
//!     .sharded(ShardParams { shards: 4, max_docs_per_shard: 1 << 20 })
//!     .build_search()?;
//!
//! // cascade over the sharded corpus: RWMD shortlists per shard, global
//! // merge, exact-EMD rerank on the survivors — certified at full probe
//! let request = SearchRequest::query(engine.dataset().histogram(0))
//!     .topl(5)
//!     .cascade(CascadeSpec::new(Method::Exact).overfetch(8).certified(true));
//! let response = engine.execute(&request)?;
//! println!("{}", response.plan.describe());
//! println!("certified: {}", response.stats.certified[0]);
//! for &(distance, id) in &response.results[0].hits {
//!     println!("doc {id}: {distance}");
//! }
//! # Ok::<(), EmdError>(())
//! ```

use std::time::{Duration, Instant};

use crate::config::Backend;
use crate::core::{EmdError, EmdResult, Histogram, Method};
use crate::emd_ensure;
use crate::obs::{SpanName, SpanRec, TraceSession, ROOT_SPAN};
use crate::index::pruned_search_batch_tiered_timed;
use crate::util::json::Json;

use super::cascade::{admissible_rerank, provably_dominates_rwmd, rerank_stage};
use super::engine::{SearchEngine, SearchResult};
use super::TopL;

/// The cascade stage of a request: rerank the stage-1 LC-RWMD survivors
/// with a dominating [`Method`] (ACT-k, ICT, Sinkhorn, exact EMD).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadeSpec {
    /// Stage-2 measure; must dominate the RWMD prefilter
    /// ([`crate::coordinator::cascade::admissible_rerank`]).
    pub rerank: Method,
    /// Stage 1 keeps `overfetch × ℓ` candidates (`None` =
    /// [`crate::config::Config::overfetch`]).
    pub overfetch: Option<usize>,
    /// Demand a *certifiable* plan: stage 1 covers the whole corpus (any
    /// `nprobe` is ignored, every shard probes exhaustively), so the
    /// Theorem-2 certificate — when it holds — is global.  Rejected for
    /// rerank measures with no bound guarantee (Sinkhorn).
    pub certified: bool,
}

impl CascadeSpec {
    pub fn new(rerank: Method) -> CascadeSpec {
        CascadeSpec { rerank, overfetch: None, certified: false }
    }

    /// Stage-1 candidates = `overfetch × ℓ`.
    pub fn overfetch(mut self, overfetch: usize) -> CascadeSpec {
        self.overfetch = Some(overfetch.max(1));
        self
    }

    pub fn certified(mut self, certified: bool) -> CascadeSpec {
        self.certified = certified;
        self
    }

    /// Protocol form: `{"rerank": "emd", "overfetch": 8, "certified": true}`
    /// or the string shorthand `"emd"`.
    pub fn from_json(j: &Json) -> EmdResult<CascadeSpec> {
        if let Some(s) = j.as_str() {
            return Ok(CascadeSpec::new(Method::parse(s)?));
        }
        let rerank = j
            .get("rerank")
            .and_then(Json::as_str)
            .ok_or_else(|| EmdError::protocol("cascade needs 'rerank' (a method name)"))?;
        let mut spec = CascadeSpec::new(Method::parse(rerank)?);
        if let Some(x) = j.get("overfetch").and_then(Json::as_usize) {
            spec.overfetch = Some(x.max(1));
        }
        if let Some(b) = j.get("certified").and_then(Json::as_bool) {
            spec.certified = b;
        }
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("rerank", self.rerank.name().into())];
        if let Some(o) = self.overfetch {
            pairs.push(("overfetch", o.into()));
        }
        pairs.push(("certified", self.certified.into()));
        Json::obj(pairs)
    }
}

/// One composable search request: query/queries, method, top-ℓ, probe
/// width, optional cascade, thread budget.  Unset fields resolve from the
/// engine's [`crate::config::Config`] at plan time.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    queries: Vec<Histogram>,
    /// Distance measure (`None` = config default).  Ignored by cascade
    /// requests: their stage 1 is always LC-RWMD and stage 2 is
    /// [`CascadeSpec::rerank`].
    pub method: Option<Method>,
    /// Results per query (`None` = config `topl`).
    pub l: Option<usize>,
    /// IVF probe width (`None` = configured default; `>= nlist` =
    /// exhaustive).  With a sharded corpus this is the per-shard width.
    pub nprobe: Option<usize>,
    /// Two-stage cascade: LC-RWMD prefilter → dominating rerank.
    pub cascade: Option<CascadeSpec>,
    /// Thread budget for the request's fan-out stages (`None` = the
    /// engine's configured pool).  Kernel-internal parallelism stays on the
    /// engine's own budget.
    pub threads: Option<usize>,
    /// Opt into span tracing: the response embeds its per-stage span
    /// timeline and the spans land in the engine's trace ring.  Neutral to
    /// batch grouping (a traced and an untraced request share a dispatch)
    /// and to results — traced and untraced runs are bit-identical.
    pub trace: bool,
}

impl SearchRequest {
    /// A single-query request.
    pub fn query(query: Histogram) -> SearchRequest {
        SearchRequest::batch(vec![query])
    }

    /// A multi-query request (one grouped dispatch through the multi-query
    /// kernels; results are bit-identical to per-query requests).
    pub fn batch(queries: Vec<Histogram>) -> SearchRequest {
        SearchRequest {
            queries,
            method: None,
            l: None,
            nprobe: None,
            cascade: None,
            threads: None,
            trace: false,
        }
    }

    pub fn method(mut self, method: Method) -> SearchRequest {
        self.method = Some(method);
        self
    }

    pub fn topl(mut self, l: usize) -> SearchRequest {
        self.l = Some(l.max(1));
        self
    }

    pub fn nprobe(mut self, nprobe: usize) -> SearchRequest {
        self.nprobe = Some(nprobe.max(1));
        self
    }

    pub fn cascade(mut self, spec: CascadeSpec) -> SearchRequest {
        self.cascade = Some(spec);
        self
    }

    pub fn threads(mut self, threads: usize) -> SearchRequest {
        self.threads = Some(threads.max(1));
        self
    }

    pub fn trace(mut self, trace: bool) -> SearchRequest {
        self.trace = trace;
        self
    }

    pub fn queries(&self) -> &[Histogram] {
        &self.queries
    }

    /// Append one query (the server's batch-group assembly).
    pub fn push_query(&mut self, query: Histogram) {
        self.queries.push(query);
    }

    /// Replace the query set (the server's `search_id` resolution).
    pub fn set_queries(&mut self, queries: Vec<Histogram>) {
        self.queries = queries;
    }

    /// Take ownership of the query set.
    pub fn into_queries(self) -> Vec<Histogram> {
        self.queries
    }

    /// The batch-grouping key: requests with equal keys resolve to the same
    /// plan parameters, so the server flows them through one grouped
    /// dispatch.  Defaults are resolved against the engine (config defaults
    /// + effective probe width), so a client passing the default explicitly
    /// groups with clients passing nothing.
    pub fn group_key(&self, engine: &SearchEngine) -> GroupKey {
        let config = engine.config();
        let cascade = self.cascade.map(|spec| {
            (spec.rerank, spec.overfetch.unwrap_or(config.overfetch).max(1), spec.certified)
        });
        let certified = cascade.map(|(_, _, c)| c).unwrap_or(false);
        GroupKey {
            method: match cascade {
                // cascade stage 1 is canonical LC-RWMD; `method` is unused
                Some(_) => Method::Rwmd,
                None => self.method.unwrap_or(config.method),
            },
            l: self.l.unwrap_or(config.topl).max(1),
            // fully plan-normalized: a certified cascade ignores any probe
            // width (stage 1 is forced exhaustive), so every such request
            // shares one key regardless of the nprobe it carried
            nprobe: if certified { None } else { engine.effective_nprobe(self.nprobe) },
            cascade,
            // resolved, so clients passing the default explicitly group
            // with clients passing nothing
            threads: Some(self.threads.unwrap_or(config.threads).max(1)),
        }
    }

    /// Parse the TCP protocol's request object (`"query"` = one histogram
    /// as `[[vocab_idx, weight], ...]`, or `"queries"` = an array of them;
    /// the `"id"` form is resolved by the server, which can see the
    /// corpus).  Round-trips with [`SearchRequest::to_json`] bit-exactly:
    /// weights travel as f64, and every f32 is exactly representable.
    pub fn from_json(j: &Json) -> EmdResult<SearchRequest> {
        let mut queries = Vec::new();
        if let Some(q) = j.get("query") {
            queries.push(parse_histogram(q)?);
        } else if let Some(arr) = j.get("queries").and_then(Json::as_arr) {
            for q in arr {
                queries.push(parse_histogram(q)?);
            }
        }
        let mut req = SearchRequest::batch(queries);
        if let Some(s) = j.get("method").and_then(Json::as_str) {
            req.method = Some(Method::parse(s)?);
        }
        if let Some(x) = j.get("l").and_then(Json::as_usize) {
            req.l = Some(x.max(1));
        }
        if let Some(x) = j.get("nprobe").and_then(Json::as_usize) {
            req.nprobe = Some(x.max(1));
        }
        if let Some(c) = j.get("cascade") {
            req.cascade = Some(CascadeSpec::from_json(c)?);
        }
        if let Some(t) = j.get("threads").and_then(Json::as_usize) {
            req.threads = Some(t.max(1));
        }
        if let Some(t) = j.get("trace").and_then(Json::as_bool) {
            req.trace = t;
        }
        Ok(req)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("op", "search".into())];
        if let Some(m) = self.method {
            pairs.push(("method", m.name().into()));
        }
        if let Some(l) = self.l {
            pairs.push(("l", l.into()));
        }
        if let Some(np) = self.nprobe {
            pairs.push(("nprobe", np.into()));
        }
        if let Some(spec) = &self.cascade {
            pairs.push(("cascade", spec.to_json()));
        }
        if let Some(t) = self.threads {
            pairs.push(("threads", t.into()));
        }
        // omitted when false so untraced requests stay byte-identical to
        // the pre-tracing wire format
        if self.trace {
            pairs.push(("trace", true.into()));
        }
        match self.queries.len() {
            1 => pairs.push(("query", histogram_json(&self.queries[0]))),
            _ => pairs.push((
                "queries",
                Json::Arr(self.queries.iter().map(histogram_json).collect()),
            )),
        }
        Json::obj(pairs)
    }
}

/// Batch-grouping key ([`SearchRequest::group_key`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupKey {
    pub method: Method,
    pub l: usize,
    /// Effective probe width (`None` = no index configured).
    pub nprobe: Option<usize>,
    /// `(rerank, overfetch, certified)` for cascade requests.
    pub cascade: Option<(Method, usize, bool)>,
    /// Requested fan-out thread budget; part of the key so a grouped
    /// dispatch honors exactly what each member asked for.
    pub threads: Option<usize>,
}

impl GroupKey {
    /// Rebuild the grouped [`SearchRequest`] this key describes over a
    /// query set — the one place key → request reconstruction lives, so
    /// the server's grouped dispatch can never drop a resolved parameter.
    pub fn request(&self, queries: Vec<Histogram>) -> SearchRequest {
        let mut req = SearchRequest::batch(queries).method(self.method).topl(self.l);
        if let Some(np) = self.nprobe {
            req = req.nprobe(np);
        }
        if let Some((rerank, overfetch, certified)) = self.cascade {
            req = req
                .cascade(CascadeSpec::new(rerank).overfetch(overfetch).certified(certified));
        }
        if let Some(t) = self.threads {
            req = req.threads(t);
        }
        req
    }
}

/// Parse one protocol histogram: an array of `[vocab_idx, weight]` pairs.
pub fn parse_histogram(j: &Json) -> EmdResult<Histogram> {
    let pairs =
        j.as_arr().ok_or_else(|| EmdError::protocol("histogram must be [[idx, w], ...]"))?;
    let mut entries = Vec::with_capacity(pairs.len());
    for p in pairs {
        let pair =
            p.as_arr().ok_or_else(|| EmdError::protocol("histogram entries are [idx, w]"))?;
        emd_ensure!(pair.len() == 2, protocol, "histogram entries are [idx, w]");
        let idx =
            pair[0].as_usize().ok_or_else(|| EmdError::protocol("bad vocab index"))? as u32;
        let w = pair[1].as_f64().ok_or_else(|| EmdError::protocol("bad weight"))? as f32;
        entries.push((idx, w));
    }
    Ok(Histogram::from_pairs(entries))
}

/// Serialize one histogram as the protocol's `[[idx, w], ...]` form.
pub fn histogram_json(h: &Histogram) -> Json {
    Json::Arr(
        h.indices()
            .iter()
            .zip(h.weights())
            .map(|(&i, &w)| Json::Arr(vec![Json::Num(i as f64), Json::Num(w as f64)]))
            .collect(),
    )
}

/// One stage of a [`QueryPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// IVF coarse-quantizer probe selecting candidate lists (`nlist` is the
    /// widest trained list count on the route).
    Prune { nprobe: usize, nlist: usize },
    /// LC scoring of the candidate set through the batched Phase-1/Phase-2
    /// pipeline (`exhaustive` = the whole database, no pruning;
    /// `compressed` = the sweep streams the engine's f16 stage-1 tier, so
    /// scores are approximate until a downstream exact stage rescores).
    Score { method: Method, exhaustive: bool, compressed: bool },
    /// Per-shard local search fanned across the pool, `fanout` shards at a
    /// time (each shard engine runs on its per-shard thread budget).
    ShardFanout { shards: usize, fanout: usize },
    /// Cross-shard k-way top-ℓ merge.
    Merge { l: usize },
    /// Rerank the stage-1 RWMD survivors with the dominating method.
    CascadeRerank { rerank: Method, overfetch: usize, certified: bool },
    /// Exact-f32 rescoring of a compressed stage-1 shortlist: the top
    /// `keep` approximate candidates are rescored through the exact table
    /// and the final top-ℓ is ranked from those exact values — at full
    /// probe with ample `keep` this restores bit-identity with the
    /// uncompressed exhaustive sweep.
    ExactRerank { keep: usize },
}

impl Stage {
    pub fn describe(&self) -> String {
        match self {
            Stage::Prune { nprobe, nlist } => format!("Prune(ivf {nprobe}/{nlist})"),
            Stage::Score { method, exhaustive, compressed } => {
                format!(
                    "Score({}, {}{})",
                    method.name(),
                    if *exhaustive { "exhaustive" } else { "candidates" },
                    if *compressed { ", f16" } else { "" }
                )
            }
            Stage::ShardFanout { shards, fanout } => {
                format!("ShardFanout({shards} shards, width {fanout})")
            }
            Stage::Merge { l } => format!("Merge(top-{l})"),
            Stage::CascadeRerank { rerank, overfetch, certified } => format!(
                "CascadeRerank({}, overfetch {overfetch}{})",
                rerank.name(),
                if *certified { ", certified" } else { "" }
            ),
            Stage::ExactRerank { keep } => format!("ExactRerank(top-{keep}, f32)"),
        }
    }
}

/// An explicit, inspectable execution plan for one request: the stage DAG
/// plus every resolved parameter.  [`SearchEngine::plan`] builds one
/// without executing it; [`SearchEngine::execute`] returns the plan it ran
/// inside the [`SearchResponse`].
#[derive(Debug, Clone, PartialEq)]
pub struct QueryPlan {
    pub stages: Vec<Stage>,
    /// The measure the scoring stage runs (stage 1 = LC-RWMD for cascades).
    pub method: Method,
    /// Final results per query.
    pub l: usize,
    /// Effective probe width (`None` = exhaustive: no index configured, or
    /// a certified cascade forcing full coverage).
    pub nprobe: Option<usize>,
    /// Resolved cascade spec (`overfetch` filled from config).
    pub cascade: Option<CascadeSpec>,
    /// Requested fan-out thread budget (`None` = engine default).
    pub threads: Option<usize>,
    /// Stage 1 streams the engine's f16 compressed tier (exactness is
    /// restored by the `ExactRerank` stage, or surrendered by an
    /// uncertified cascade whose certificate is forced false).
    pub compressed: bool,
}

impl QueryPlan {
    /// Human-readable stage chain, e.g.
    /// `Prune(ivf 2/8) -> Score(RWMD, candidates) -> ShardFanout(4 shards,
    /// width 4) -> Merge(top-10) -> CascadeRerank(EMD, overfetch 8)`.
    pub fn describe(&self) -> String {
        self.stages.iter().map(Stage::describe).collect::<Vec<_>>().join(" -> ")
    }
}

/// Per-request work accounting, summed over the batch's queries.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryStats {
    pub queries: usize,
    /// Inverted lists visited (index-routed stages only).
    pub lists_probed: usize,
    /// Database rows scored by the stage-1 sweep.
    pub candidates_scored: usize,
    /// Candidates rescored by the cascade stage.
    pub reranked: usize,
    /// Cross-shard merge time (the fan-out overhead).
    pub merge_us: u64,
    /// IVF probe time (zero when no pruning stage ran).  Per-stage times
    /// are whole-batch wall-clock, always measured (tracing or not).
    pub prune_us: u64,
    /// Stage-1 scoring time (monolithic routes; zero on the sharded route,
    /// where probe+score run inside each shard's fan-out lane).
    pub score_us: u64,
    /// Sharded fan-out wall time: parallel per-shard probe+score, up to the
    /// start of the cross-shard merge.
    pub fanout_us: u64,
    /// Rerank stage time (cascade or exact-f32 rerank; zero otherwise).
    pub rerank_us: u64,
    /// End-to-end execute time for the whole batch.
    pub total_us: u64,
    /// Per-query exactness certificates (cascade requests only; empty
    /// otherwise).  Aligned with [`SearchResponse::results`].
    pub certified: Vec<bool>,
    /// `true` when the remote fan-out dropped at least one shard from the
    /// merge (deadline or exhausted retries): the results cover the
    /// surviving shards only.  Always `false` on in-process routes; the
    /// wire response carries it as `"partial": true`.
    pub partial: bool,
}

/// Ranked hits plus the executed plan and its work accounting.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    /// One result per request query, in request order.
    pub results: Vec<SearchResult>,
    pub stats: QueryStats,
    /// The plan that produced the results.
    pub plan: QueryPlan,
    /// The request's span timeline ([`SearchRequest::trace`] only):
    /// session-relative, root first.
    pub spans: Option<Vec<SpanRec>>,
}

/// Build the execution plan for `req` without running it: resolve every
/// default against the engine's config, validate the combination, and lay
/// out the stage DAG.
pub fn plan(engine: &SearchEngine, req: &SearchRequest) -> EmdResult<QueryPlan> {
    let config = engine.config();
    let l = req.l.unwrap_or(config.topl).max(1);
    let cascade = match req.cascade {
        Some(spec) => {
            emd_ensure!(
                config.backend == Backend::Native,
                unsupported,
                "cascade search requires the native backend"
            );
            if !admissible_rerank(spec.rerank) {
                return Err(EmdError::unsupported(format!(
                    "rerank method {} does not dominate the RWMD prefilter bound",
                    spec.rerank.name()
                )));
            }
            emd_ensure!(
                !spec.certified || provably_dominates_rwmd(spec.rerank),
                unsupported,
                "rerank method {} cannot be certified: it carries no Theorem-2 bound \
                 guarantee over the RWMD prefilter",
                spec.rerank.name()
            );
            Some(CascadeSpec {
                rerank: spec.rerank,
                overfetch: Some(spec.overfetch.unwrap_or(config.overfetch).max(1)),
                certified: spec.certified,
            })
        }
        None => None,
    };
    let method = match cascade {
        Some(_) => Method::Rwmd,
        None => req.method.unwrap_or(config.method),
    };
    // a certified cascade must see every database row in stage 1
    let force_exhaustive = cascade.map(|c| c.certified).unwrap_or(false);
    let nprobe = if force_exhaustive { None } else { engine.effective_nprobe(req.nprobe) };

    // compressed stage-1 residency: only on the monolithic native route,
    // only for the LC plan methods (the tier feeds Phase 1), and never
    // under a certified cascade — a certificate requires true lower
    // bounds, which f16-quantized scores are not
    let compressed = config.compressed != crate::core::CompressedKind::Off
        && config.backend == Backend::Native
        && engine.sharded_corpus().is_none()
        && engine.native_ref().compressed_active()
        && matches!(method, Method::Rwmd | Method::Omr | Method::Act { .. })
        && !cascade.map(|c| c.certified).unwrap_or(false);

    let mut stages = Vec::new();
    if let Some(lock) = engine.sharded_corpus() {
        let corpus = lock.read().unwrap();
        let pruned = nprobe
            .map(|np| {
                corpus
                    .shards()
                    .iter()
                    .any(|s| s.index().map(|ix| np < ix.nlist()).unwrap_or(false))
            })
            .unwrap_or(false);
        if pruned {
            stages.push(Stage::Prune {
                nprobe: nprobe.unwrap_or(1),
                nlist: corpus.max_nlist().unwrap_or(0),
            });
        }
        stages.push(Stage::Score { method, exhaustive: !pruned, compressed: false });
        let fanout = req
            .threads
            .unwrap_or(config.threads)
            .clamp(1, corpus.num_shards().max(1));
        stages.push(Stage::ShardFanout { shards: corpus.num_shards(), fanout });
        stages.push(Stage::Merge { l });
    } else {
        let route = if force_exhaustive { None } else { engine.pruning_route(req.nprobe) };
        match route {
            Some((index, np)) => {
                stages.push(Stage::Prune { nprobe: np, nlist: index.nlist() });
                stages.push(Stage::Score { method, exhaustive: false, compressed });
            }
            None => stages.push(Stage::Score { method, exhaustive: true, compressed }),
        }
    }
    if let Some(spec) = cascade {
        stages.push(Stage::CascadeRerank {
            rerank: spec.rerank,
            overfetch: spec.overfetch.unwrap_or(config.overfetch).max(1),
            certified: spec.certified,
        });
    } else if compressed {
        // recover exactness: rescore the top overfetch·ℓ approximate
        // candidates through the exact f32 table and rank ℓ from those
        let keep = l
            .saturating_mul(config.overfetch.max(1))
            .max(l)
            .clamp(1, engine.num_docs().max(1));
        stages.push(Stage::ExactRerank { keep });
    }
    Ok(QueryPlan { stages, method, l, nprobe, cascade, threads: req.threads, compressed })
}

/// One query's outcome from the base (stage-1) route.
struct BaseResult {
    result: SearchResult,
    candidates: usize,
    lists_probed: usize,
    pruned: bool,
}

/// A whole batch's base-route outcome.
struct BaseBatch {
    per_query: Vec<BaseResult>,
    /// Cross-shard merge time (sharded route only).
    merge: Option<Duration>,
    /// Corpus size at dispatch time (the coverage denominator).
    n_live: usize,
    /// Remote fan-out dropped at least one shard from the merge.
    partial: bool,
    /// Stage wall-times, always measured (spans are recorded from these
    /// only when a trace session is active).
    timing: BaseTiming,
}

/// Per-stage wall-clock of one base-route dispatch; zero = stage not run.
#[derive(Default)]
struct BaseTiming {
    /// IVF probe (monolithic pruned route).
    prune: Duration,
    /// Stage-1 scoring (monolithic routes).
    score: Duration,
    /// Parallel shard fan-out (sharded route; probe+score run per shard).
    fanout: Duration,
    /// Per-shard lanes: (start offset from fan-out entry, duration).
    shards: Vec<(Duration, Duration)>,
}

/// Run the plan's scoring route: sharded fan-out, IVF-pruned, or exhaustive
/// sweep.  `force_exhaustive` overrides any probe width (certified
/// cascades).  `compressed` routes the native sweep (probe + stage 1)
/// through the engine's f16 residency tier; the caller owns restoring
/// exactness downstream.
fn run_base(
    engine: &SearchEngine,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: Option<usize>,
    force_exhaustive: bool,
    fanout: Option<usize>,
    compressed: bool,
) -> EmdResult<BaseBatch> {
    match engine.config().backend {
        Backend::Artifact => {
            // the artifact runtime plans one query at a time; no index or
            // shards on this backend
            let t0 = Instant::now();
            let n = engine.dataset().len();
            let mut per_query = Vec::with_capacity(queries.len());
            for q in queries {
                let row = engine.distances(q, method)?;
                per_query.push(BaseResult {
                    result: engine.rank_row(&row, l),
                    candidates: n,
                    lists_probed: 0,
                    pruned: false,
                });
            }
            let timing = BaseTiming { score: t0.elapsed(), ..BaseTiming::default() };
            Ok(BaseBatch { per_query, merge: None, n_live: n, partial: false, timing })
        }
        Backend::Native => {
            if let Some(lock) = engine.sharded_corpus() {
                // fan-out route: probe each shard locally, score through the
                // bit-identical subset pipeline, k-way-merge top-ℓ
                let corpus = lock.read().unwrap();
                let np = if force_exhaustive { Some(usize::MAX >> 1) } else { nprobe };
                // remote fleet configured: the same fan-out runs over TCP
                // shard nodes — same merge, same bits at full probe; a
                // shard past its deadline is dropped and marked partial
                let (batch, partial) = match engine.remote_fleet() {
                    Some(fleet) => {
                        let remote = fleet.search_batch(
                            &corpus,
                            queries,
                            method,
                            l,
                            np,
                            &engine.metrics(),
                        )?;
                        (remote.batch, remote.partial)
                    }
                    None => (
                        crate::shard::search_batch_budgeted(
                            &corpus, queries, method, l, np, fanout,
                        )?,
                        false,
                    ),
                };
                let n_live = corpus.len();
                drop(corpus);
                let per_query = batch
                    .results
                    .into_iter()
                    .map(|r| BaseResult {
                        result: SearchResult { hits: r.hits, labels: r.labels },
                        candidates: r.candidates,
                        lists_probed: r.lists_probed,
                        pruned: r.pruned,
                    })
                    .collect();
                let timing = BaseTiming {
                    fanout: batch.fanout_time,
                    shards: batch.shard_times,
                    ..BaseTiming::default()
                };
                return Ok(BaseBatch {
                    per_query,
                    merge: Some(batch.merge_time),
                    n_live,
                    partial,
                    timing,
                });
            }
            let n = engine.dataset().len();
            let route = if force_exhaustive { None } else { engine.pruning_route(nprobe) };
            let mut timing = BaseTiming::default();
            let per_query = match route {
                Some((index, np)) => {
                    let (pruned, t) = pruned_search_batch_tiered_timed(
                        engine.native_ref(),
                        index,
                        queries,
                        method,
                        l,
                        np,
                        compressed,
                    )?;
                    timing.prune = t.probe;
                    timing.score = t.score;
                    pruned
                        .into_iter()
                        .map(|pr| {
                            let labels = pr
                                .hits
                                .iter()
                                .map(|&(_, id)| engine.dataset().labels[id])
                                .collect();
                            BaseResult {
                                result: SearchResult { hits: pr.hits, labels },
                                candidates: pr.candidates,
                                lists_probed: pr.lists_probed,
                                pruned: true,
                            }
                        })
                        .collect()
                }
                None => {
                    let t0 = Instant::now();
                    let flat =
                        engine.native_ref().distances_batch_tiered(queries, method, compressed);
                    let out: Vec<BaseResult> = (0..queries.len())
                        .map(|i| BaseResult {
                            result: engine.rank_row(&flat[i * n..(i + 1) * n], l),
                            candidates: n,
                            lists_probed: 0,
                            pruned: false,
                        })
                        .collect();
                    timing.score = t0.elapsed();
                    out
                }
            };
            Ok(BaseBatch { per_query, merge: None, n_live: n, partial: false, timing })
        }
    }
}

/// Saturating µs of one wall-clock duration.
fn us(d: Duration) -> u64 {
    d.as_micros().min(u128::from(u64::MAX)) as u64
}

/// Record the base route's stage spans: sequential stage layout from `off`
/// (the session-relative µs at which the base dispatch started), with
/// per-shard lanes as children of the fan-out span.
fn record_base_spans(
    s: &mut TraceSession,
    timing: &BaseTiming,
    merge: Option<Duration>,
    off: u64,
) {
    let mut cursor = off;
    if timing.prune != Duration::ZERO {
        s.add(SpanName::Prune, ROOT_SPAN, cursor, us(timing.prune));
        cursor += us(timing.prune);
    }
    if timing.score != Duration::ZERO {
        s.add(SpanName::Score, ROOT_SPAN, cursor, us(timing.score));
        cursor += us(timing.score);
    }
    if !timing.shards.is_empty() || timing.fanout != Duration::ZERO {
        let fan = s.add(SpanName::ShardFanout, ROOT_SPAN, cursor, us(timing.fanout));
        for (i, &(start, dur)) in timing.shards.iter().enumerate() {
            s.add_lane(SpanName::Shard, fan, cursor + us(start), us(dur), i as u16);
        }
        cursor += us(timing.fanout);
    }
    if let Some(m) = merge {
        s.add(SpanName::Merge, ROOT_SPAN, cursor, us(m));
    }
}

/// Plan and execute one request (the one entry point every serving surface
/// funnels through).  Results are bit-identical to the legacy per-route
/// entry points for the same resolved parameters — and to an untraced run
/// of the same request: tracing only reads clocks and appends to a
/// session-local `Vec`.
pub fn execute(engine: &SearchEngine, req: &SearchRequest) -> EmdResult<SearchResponse> {
    let plan = plan(engine, req)?;
    engine.metrics().record_batch();
    let queries = req.queries();
    if queries.is_empty() {
        return Ok(SearchResponse {
            results: Vec::new(),
            stats: QueryStats::default(),
            plan,
            spans: None,
        });
    }
    // span recording is opt-in per request, or armed process-wide by the
    // slow-query log; when off the only cost here is this branch
    let slow_us = engine.slow_query_us();
    let mut session = if req.trace || slow_us > 0 {
        engine.tracer().set_enabled(true);
        Some(TraceSession::start(engine.tracer()))
    } else {
        None
    };
    let mut resp = match plan.cascade {
        Some(spec) => execute_cascade(engine, queries, spec, plan, &mut session),
        None => execute_base(engine, queries, plan, &mut session),
    }?;
    if let Some(s) = session {
        let total = s.now_us();
        let spans = s.finish(engine.tracer());
        if slow_us > 0 && total >= slow_us {
            let breakdown: Vec<String> = spans
                .iter()
                .skip(1) // the root restates `total`
                .map(|sp| format!("{}={}us", sp.name_str(), sp.dur_us))
                .collect();
            crate::log_warn!(
                "emdpar::slow_query",
                "trace {} took {}us (threshold {}us, {} queries): {}",
                spans[0].trace_id,
                total,
                slow_us,
                queries.len(),
                breakdown.join(" ")
            );
        }
        if req.trace {
            resp.spans = Some(spans);
        }
    }
    Ok(resp)
}

fn execute_base(
    engine: &SearchEngine,
    queries: &[Histogram],
    plan: QueryPlan,
    session: &mut Option<TraceSession>,
) -> EmdResult<SearchResponse> {
    let t0 = Instant::now();
    // a compressed plan overfetches `keep` stage-1 candidates so the exact
    // rerank below can rank the final ℓ from exact-f32 values
    let keep = plan.stages.iter().find_map(|s| match s {
        Stage::ExactRerank { keep } => Some(*keep),
        _ => None,
    });
    let fetch = keep.unwrap_or(plan.l);
    let base_off = session.as_ref().map(|s| s.now_us()).unwrap_or(0);
    let base = run_base(
        engine,
        queries,
        plan.method,
        fetch,
        plan.nprobe,
        false,
        plan.threads,
        plan.compressed,
    )?;
    let metrics = engine.metrics();
    let mut stats = QueryStats { queries: queries.len(), ..QueryStats::default() };
    stats.partial = base.partial;
    stats.prune_us = us(base.timing.prune);
    stats.score_us = us(base.timing.score);
    stats.fanout_us = us(base.timing.fanout);
    if let Some(m) = base.merge {
        metrics.record_merge(m);
        stats.merge_us = us(m);
    }
    if let Some(s) = session.as_mut() {
        record_base_spans(s, &base.timing, base.merge, base_off);
    }
    let rerank_off = session.as_ref().map(|s| s.now_us()).unwrap_or(0);
    let rerank_t0 = Instant::now();
    let mut results = Vec::with_capacity(queries.len());
    let mut evals = Vec::with_capacity(queries.len());
    for (r, query) in base.per_query.into_iter().zip(queries) {
        if r.pruned {
            metrics.record_probe(r.lists_probed, r.candidates, base.n_live);
        }
        stats.lists_probed += r.lists_probed;
        stats.candidates_scored += r.candidates;
        let mut evaluated = r.candidates;
        let result = match keep {
            Some(_) => {
                // rescore the approximate shortlist through the exact f32
                // table (ascending ids: one deterministic sub-CSR gather)
                let mut ids: Vec<u32> =
                    r.result.hits.iter().map(|&(_, id)| id as u32).collect();
                ids.sort_unstable();
                let exact = engine.native_ref().distances_batch_subset(
                    std::slice::from_ref(query),
                    plan.method,
                    &ids,
                );
                let mut top = TopL::new(plan.l);
                for (&id, &d) in ids.iter().zip(&exact) {
                    top.push(d, id as usize);
                }
                stats.reranked += ids.len();
                evaluated += ids.len();
                let hits = top.into_sorted();
                let labels =
                    hits.iter().map(|&(_, id)| engine.dataset().labels[id]).collect();
                SearchResult { hits, labels }
            }
            None => r.result,
        };
        evals.push(evaluated);
        results.push(result);
    }
    if keep.is_some() {
        stats.rerank_us = us(rerank_t0.elapsed());
        if let Some(s) = session.as_mut() {
            s.add(SpanName::ExactRerank, ROOT_SPAN, rerank_off, stats.rerank_us);
        }
    }
    // per-query latency = the batch's amortized share of the full dispatch
    let per_query = t0.elapsed() / queries.len() as u32;
    for e in evals {
        metrics.record_query(per_query, e);
    }
    stats.total_us = us(t0.elapsed());
    Ok(SearchResponse { results, stats, plan, spans: None })
}

fn execute_cascade(
    engine: &SearchEngine,
    queries: &[Histogram],
    spec: CascadeSpec,
    plan: QueryPlan,
    session: &mut Option<TraceSession>,
) -> EmdResult<SearchResponse> {
    let t0 = Instant::now();
    let l = plan.l;
    let overfetch = spec.overfetch.unwrap_or(engine.config().overfetch).max(1);
    // clamp against the live corpus so the stage-1 accumulators stay
    // bounded even for overfetch = usize::MAX-ish requests
    let keep = l.saturating_mul(overfetch).clamp(1, engine.num_docs().max(1));
    // stage 1 fetches one extra candidate: the (keep+1)-th best stage-1
    // bound is exactly the tightest *discarded* bound — the certificate's
    // pruned floor — so no separate full-row scan is needed
    let base_off = session.as_ref().map(|s| s.now_us()).unwrap_or(0);
    let base = run_base(
        engine,
        queries,
        Method::Rwmd,
        keep + 1,
        plan.nprobe,
        spec.certified,
        plan.threads,
        plan.compressed,
    )?;

    let metrics = engine.metrics();
    let mut stats = QueryStats { queries: queries.len(), ..QueryStats::default() };
    // a partial fan-out also voids every certificate below: `covers`
    // compares candidates against the full live corpus
    stats.partial = base.partial;
    stats.prune_us = us(base.timing.prune);
    stats.score_us = us(base.timing.score);
    stats.fanout_us = us(base.timing.fanout);
    if let Some(m) = base.merge {
        metrics.record_merge(m);
        stats.merge_us = us(m);
    }
    if let Some(s) = session.as_mut() {
        record_base_spans(s, &base.timing, base.merge, base_off);
    }
    let rerank_off = session.as_ref().map(|s| s.now_us()).unwrap_or(0);
    let rerank_t0 = Instant::now();

    // stage 2: rerank survivors through the registry's boxed object, with
    // documents resolved from the live corpus (sharded) or the dataset.
    // The corpus lock is NOT held across the rerank — a slow exact-EMD
    // stage would otherwise stall concurrent appends (and, behind a
    // writer-preferring RwLock, new queries too); the Arc-backed snapshot
    // stays valid because appends only add ids.
    let dist = engine.registry().distance(spec.rerank);
    let vocab = &engine.dataset().embeddings;
    let view = engine.sharded_corpus().map(|lock| lock.read().unwrap().doc_view());
    let doc = |u: usize| -> Histogram {
        match &view {
            Some(v) => v.histogram(u),
            None => engine.dataset().histogram(u),
        }
    };
    let label = |u: usize| -> u16 {
        match &view {
            Some(v) => v.label(u),
            None => engine.dataset().labels[u],
        }
    };

    let mut results = Vec::with_capacity(queries.len());
    let mut evals = Vec::with_capacity(queries.len());
    for (query, b) in queries.iter().zip(base.per_query) {
        let hits = b.result.hits;
        let (shortlist, pruned_floor) = if hits.len() > keep {
            (&hits[..keep], hits[keep].0)
        } else {
            (&hits[..], f32::INFINITY)
        };
        // f16-quantized stage-1 scores are not true lower bounds, so a
        // compressed cascade can never claim the Theorem-2 certificate
        let covers = b.candidates == base.n_live && !plan.compressed;
        let reranked = rerank_stage(
            vocab,
            dist.as_ref(),
            spec.rerank,
            &query.normalized(),
            l,
            shortlist,
            pruned_floor,
            covers,
            &doc,
        )?;
        if b.pruned {
            metrics.record_probe(b.lists_probed, b.candidates, base.n_live);
        }
        stats.lists_probed += b.lists_probed;
        stats.candidates_scored += b.candidates;
        stats.reranked += reranked.reranked;
        stats.certified.push(reranked.certified);
        evals.push(b.candidates + reranked.reranked);
        let labels = reranked.hits.iter().map(|&(_, id)| label(id)).collect();
        results.push(SearchResult { hits: reranked.hits, labels });
    }
    stats.rerank_us = us(rerank_t0.elapsed());
    if let Some(s) = session.as_mut() {
        s.add(SpanName::CascadeRerank, ROOT_SPAN, rerank_off, stats.rerank_us);
    }
    let per_query = t0.elapsed() / queries.len() as u32;
    for e in evals {
        metrics.record_query(per_query, e);
    }
    metrics.record_cascade(queries.len(), stats.reranked);
    stats.total_us = us(t0.elapsed());
    Ok(SearchResponse { results, stats, plan, spans: None })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec, IndexParams, ShardParams};

    fn engine(index: Option<IndexParams>, sharded: Option<ShardParams>) -> SearchEngine {
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 11 },
            threads: 2,
            index,
            sharded,
            ..Config::default()
        })
        .unwrap()
    }

    fn index_params() -> IndexParams {
        IndexParams { nlist: 4, nprobe: 2, train_iters: 5, seed: 3, min_points_per_list: 1 }
    }

    #[test]
    fn group_key_resolves_defaults() {
        let eng = engine(Some(index_params()), None);
        let q = eng.dataset().histogram(0);
        // explicit defaults group with implicit ones
        let a = SearchRequest::query(q.clone()).group_key(&eng);
        let b = SearchRequest::query(q.clone())
            .method(eng.config().method)
            .topl(eng.config().topl)
            .nprobe(2)
            .group_key(&eng);
        assert_eq!(a, b);
        // a cascade request groups separately, and its method is stage-1 RWMD
        let c = SearchRequest::query(q)
            .cascade(CascadeSpec::new(Method::Exact))
            .group_key(&eng);
        assert_ne!(a, c);
        assert_eq!(c.method, Method::Rwmd);
        assert_eq!(c.cascade, Some((Method::Exact, eng.config().overfetch, false)));
    }

    #[test]
    fn plan_lays_out_the_stage_dag() {
        let eng = engine(Some(index_params()), None);
        let q = eng.dataset().histogram(1);
        let p = eng.plan(&SearchRequest::query(q.clone()).nprobe(2)).unwrap();
        assert!(matches!(p.stages[0], Stage::Prune { nprobe: 2, nlist: 4 }));
        assert!(matches!(p.stages[1], Stage::Score { exhaustive: false, .. }));
        // full probe collapses to the exhaustive route
        let p = eng.plan(&SearchRequest::query(q.clone()).nprobe(64)).unwrap();
        assert!(matches!(p.stages[0], Stage::Score { exhaustive: true, .. }));
        // a certified cascade forces exhaustive stage 1 and appends rerank
        let p = eng
            .plan(
                &SearchRequest::query(q)
                    .cascade(CascadeSpec::new(Method::Exact).certified(true))
                    .nprobe(1),
            )
            .unwrap();
        assert_eq!(p.method, Method::Rwmd);
        assert!(matches!(p.stages[0], Stage::Score { exhaustive: true, .. }));
        assert!(matches!(p.stages.last(), Some(Stage::CascadeRerank { .. })));
        assert!(p.describe().contains("CascadeRerank(EMD"));
    }

    #[test]
    fn sharded_plan_includes_fanout_and_merge() {
        let eng = engine(
            Some(index_params()),
            Some(ShardParams { shards: 2, max_docs_per_shard: 1 << 20 }),
        );
        let q = eng.dataset().histogram(2);
        let p = eng
            .plan(&SearchRequest::query(q).nprobe(1).threads(1).topl(3))
            .unwrap();
        assert!(p.stages.iter().any(|s| matches!(s, Stage::ShardFanout { shards: 2, fanout: 1 })));
        assert!(p.stages.iter().any(|s| matches!(s, Stage::Merge { l: 3 })));
        assert!(p.stages.iter().any(|s| matches!(s, Stage::Prune { .. })));
    }

    #[test]
    fn invalid_cascades_are_rejected_at_plan_time() {
        let eng = engine(None, None);
        let q = eng.dataset().histogram(0);
        // non-dominating rerank
        for bad in [Method::Bow, Method::Wcd, Method::Rwmd, Method::BowAdjusted] {
            let req = SearchRequest::query(q.clone()).cascade(CascadeSpec::new(bad));
            assert!(eng.plan(&req).is_err(), "{bad}");
        }
        // Sinkhorn cannot be certified (no bound guarantee)...
        let req = SearchRequest::query(q.clone())
            .cascade(CascadeSpec::new(Method::Sinkhorn).certified(true));
        assert!(eng.plan(&req).is_err());
        // ...but is admissible uncertified
        let req =
            SearchRequest::query(q).cascade(CascadeSpec::new(Method::Sinkhorn));
        assert!(eng.plan(&req).is_ok());
    }

    #[test]
    fn compressed_plan_marks_stage1_and_appends_exact_rerank() {
        use crate::core::CompressedKind;
        let eng = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 11 },
            threads: 2,
            compressed: CompressedKind::F16,
            ..Config::default()
        })
        .unwrap();
        let q = eng.dataset().histogram(0);
        let p = eng.plan(&SearchRequest::query(q.clone()).method(Method::Rwmd).topl(4)).unwrap();
        assert!(p.compressed);
        assert!(matches!(
            p.stages[0],
            Stage::Score { exhaustive: true, compressed: true, .. }
        ));
        assert!(matches!(p.stages.last(), Some(Stage::ExactRerank { .. })));
        assert!(p.describe().contains("f16"), "{}", p.describe());
        assert!(p.describe().contains("ExactRerank"), "{}", p.describe());
        // non-LC methods serve exact rows from the tiered sweep: the plan
        // is neither compressed nor reranked
        let p = eng.plan(&SearchRequest::query(q.clone()).method(Method::Wcd)).unwrap();
        assert!(!p.compressed);
        assert!(!p.stages.iter().any(|s| matches!(s, Stage::ExactRerank { .. })));
        // a certified cascade demands true lower bounds: never compressed
        let p = eng
            .plan(
                &SearchRequest::query(q)
                    .cascade(CascadeSpec::new(Method::Exact).certified(true)),
            )
            .unwrap();
        assert!(!p.compressed);
        assert!(!p.stages.iter().any(|s| matches!(s, Stage::ExactRerank { .. })));
    }

    #[test]
    fn compressed_execution_restores_exact_results_at_full_probe() {
        use crate::core::CompressedKind;
        let base_cfg = Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 180, dim: 8, seed: 11 },
            threads: 2,
            ..Config::default()
        };
        let exact = SearchEngine::from_config(base_cfg.clone()).unwrap();
        // overfetch 16 × ℓ 5 clamps keep to the whole 40-doc corpus, so the
        // exact rerank provably restores bit-identity with the f32 sweep
        let tiered = SearchEngine::from_config(Config {
            compressed: CompressedKind::F16,
            overfetch: 16,
            ..base_cfg
        })
        .unwrap();
        let queries: Vec<Histogram> =
            [0usize, 7, 23].iter().map(|&u| exact.dataset().histogram(u)).collect();
        let req = SearchRequest::batch(queries.clone()).method(Method::Rwmd).topl(5);
        let want = exact.execute(&req).unwrap();
        let got = tiered.execute(&req).unwrap();
        assert!(got.plan.compressed && !want.plan.compressed);
        assert!(got.stats.reranked > 0);
        for (g, w) in got.results.iter().zip(&want.results) {
            assert_eq!(g.hits, w.hits);
            assert_eq!(g.labels, w.labels);
        }
        // an uncertified cascade over the compressed tier keeps the same
        // hits (full-corpus shortlist, exact rerank) but its certificate is
        // forced false: f16 stage-1 scores are not lower bounds
        let creq = SearchRequest::batch(queries)
            .topl(5)
            .cascade(CascadeSpec::new(Method::Exact).overfetch(16));
        let cwant = exact.execute(&creq).unwrap();
        let cgot = tiered.execute(&creq).unwrap();
        assert!(cgot.plan.compressed);
        for (g, w) in cgot.results.iter().zip(&cwant.results) {
            assert_eq!(g.hits, w.hits);
        }
        assert!(cwant.stats.certified.iter().all(|&c| c));
        assert!(cgot.stats.certified.iter().all(|&c| !c));
    }

    #[test]
    fn request_json_round_trips() {
        let q = Histogram::from_pairs(vec![(3, 0.25), (17, 0.75)]);
        let req = SearchRequest::query(q)
            .method(Method::Act { k: 3 })
            .topl(7)
            .nprobe(4)
            .cascade(CascadeSpec::new(Method::Exact).overfetch(6).certified(true));
        let j = req.to_json();
        let back = SearchRequest::from_json(&Json::parse(&j.to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back, req);
        // multi-query form round-trips too
        let req = SearchRequest::batch(vec![
            Histogram::from_pairs(vec![(0, 1.0)]),
            Histogram::from_pairs(vec![(1, 0.5), (2, 0.5)]),
        ]);
        let j = req.to_json();
        let back = SearchRequest::from_json(&Json::parse(&j.to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back, req);
    }
}
