//! Cascaded top-ℓ search: cheap-lower-bound prefilter → tighter rerank.
//!
//! The paper's Section 3 surveys how EMD lower bounds are used to prune
//! expensive evaluations (and its WMD baseline uses exactly this trick:
//! RWMD prefilter before FastEMD).  This module packages the idea as a
//! coordinator feature over the LC engines: stage 1 scores the whole
//! database with a cheap bound (LC-RWMD), keeps the `l * overfetch` best
//! candidates, and stage 2 re-scores only those with a tighter measure
//! (ACT-k, ICT-quality, or exact EMD).
//!
//! Because every stage-1 measure is a *lower bound* of every stage-2
//! measure (Theorem 2), a candidate can only move *up* in distance during
//! rerank — so with `overfetch` large enough the cascade is exact, and the
//! stage-1 threshold gives a certificate: any document whose stage-1 bound
//! exceeds the final ℓ-th distance could never have entered the top-ℓ.

use anyhow::Result;

use crate::core::{Histogram, Metric};
use crate::exact::emd;
use crate::lc::{LcEngine, Method};

use super::topl::TopL;

/// Rerank measure for stage 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rerank {
    /// LC-ACT with the given k (fast, still a lower bound of EMD).
    Act { k: usize },
    /// Exact EMD (the paper's "WMD" quality level).
    Exact,
}

/// Cascade outcome with work accounting.
#[derive(Debug, Clone)]
pub struct CascadeResult {
    /// (distance, id) under the stage-2 measure, best first.
    pub hits: Vec<(f32, usize)>,
    /// Candidates rescored in stage 2.
    pub reranked: usize,
    /// True when the certificate held: the (overfetch·ℓ)-th stage-1 bound
    /// was above the final ℓ-th stage-2 distance, so no pruned candidate
    /// could have entered the result.
    pub certified: bool,
}

/// Two-stage search: LC-RWMD prefilter, `rerank` on the survivors.
pub fn cascade_search(
    engine: &LcEngine,
    query: &Histogram,
    rerank: Rerank,
    l: usize,
    overfetch: usize,
) -> Result<CascadeResult> {
    let n = engine.dataset().len();
    let l = l.min(n).max(1);
    let keep = (l * overfetch.max(1)).min(n);

    // stage 1: cheap lower bound over everything
    let stage1 = engine.distances(query, Method::Rwmd);
    let mut pre = TopL::new(keep);
    pre.push_slice(&stage1, 0);
    let candidates = pre.into_sorted();
    // the tightest stage-1 bound we *discarded*; anything we return below
    // this value is certified exact
    let pruned_floor = if keep < n {
        let mut rest = f32::INFINITY;
        for (u, &d) in stage1.iter().enumerate() {
            if !candidates.iter().any(|&(_, c)| c == u) && d < rest {
                rest = d;
            }
        }
        rest
    } else {
        f32::INFINITY
    };

    // stage 2: tighter measure on the survivors only
    let mut out = TopL::new(l);
    let mut reranked = 0usize;
    match rerank {
        Rerank::Act { k } => {
            // ACT over the full DB is already linear; but here we only pay
            // the per-pair form for the candidate set, which wins when
            // keep << n and k is large.
            let qn = query.normalized();
            for &(_, u) in &candidates {
                let doc = engine.dataset().histogram(u);
                let d = crate::approx::act_directed(
                    &engine.dataset().embeddings,
                    &doc,
                    &qn,
                    Metric::L2,
                    k,
                ) as f32;
                out.push(d, u);
                reranked += 1;
            }
        }
        Rerank::Exact => {
            for &(lb, u) in &candidates {
                // classic bound pruning: skip when the lower bound already
                // exceeds the current l-th best exact distance
                if let Some(t) = out.threshold() {
                    if lb >= t {
                        continue;
                    }
                }
                let doc = engine.dataset().histogram(u);
                let d = emd(&engine.dataset().embeddings, &query.normalized(), &doc, Metric::L2)
                    as f32;
                out.push(d, u);
                reranked += 1;
            }
        }
    }
    let hits = out.into_sorted();
    let certified = hits.last().map(|&(d, _)| d <= pruned_floor).unwrap_or(true);
    Ok(CascadeResult { hits, reranked, certified })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_mnist, MnistConfig};
    use crate::lc::EngineParams;
    use std::sync::Arc;

    fn engine() -> LcEngine {
        let ds = Arc::new(generate_mnist(&MnistConfig { n: 60, side: 14, ..Default::default() }));
        LcEngine::new(ds, EngineParams { threads: 2, symmetric: false, ..Default::default() })
    }

    #[test]
    fn cascade_exact_matches_bruteforce_emd_ranking() {
        let eng = engine();
        let q = eng.dataset().histogram(0);
        let res = cascade_search(&eng, &q, Rerank::Exact, 3, 8).unwrap();
        assert_eq!(res.hits.len(), 3);
        // brute force
        let mut brute: Vec<(f32, usize)> = (0..eng.dataset().len())
            .map(|u| {
                let d = emd(
                    &eng.dataset().embeddings,
                    &q,
                    &eng.dataset().histogram(u),
                    Metric::L2,
                ) as f32;
                (d, u)
            })
            .collect();
        brute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if res.certified {
            for (got, want) in res.hits.iter().zip(&brute) {
                assert!((got.0 - want.0).abs() < 1e-5, "{:?} vs {:?}", res.hits, &brute[..3]);
            }
        }
        // pruning must actually skip work on clustered data
        assert!(res.reranked <= 3 * 8);
    }

    #[test]
    fn cascade_act_rerank_is_tighter_than_stage1() {
        let eng = engine();
        let q = eng.dataset().histogram(5);
        let stage1 = eng.distances(&q, Method::Rwmd);
        let res = cascade_search(&eng, &q, Rerank::Act { k: 8 }, 4, 4).unwrap();
        for &(d, u) in &res.hits {
            assert!(d + 1e-5 >= stage1[u], "rerank must not go below the lower bound");
        }
    }

    #[test]
    fn overfetch_one_still_returns_l() {
        let eng = engine();
        let q = eng.dataset().histogram(1);
        let res = cascade_search(&eng, &q, Rerank::Act { k: 2 }, 5, 1).unwrap();
        assert_eq!(res.hits.len(), 5);
        assert_eq!(res.reranked, 5);
    }

    #[test]
    fn full_overfetch_is_always_certified() {
        let eng = engine();
        let q = eng.dataset().histogram(2);
        let res = cascade_search(&eng, &q, Rerank::Act { k: 4 }, 3, usize::MAX / 4).unwrap();
        assert!(res.certified);
    }
}
