//! Cascaded top-ℓ search: cheap-lower-bound prefilter → tighter rerank.
//!
//! The paper's Section 3 surveys how EMD lower bounds are used to prune
//! expensive evaluations (and its WMD baseline uses exactly this trick:
//! RWMD prefilter before FastEMD).  This module packages the idea as a
//! coordinator feature over the LC engines: stage 1 scores the whole
//! database with a cheap bound (LC-RWMD), keeps the `l * overfetch` best
//! candidates, and stage 2 re-scores only those with a tighter measure —
//! any canonical [`Method`] that dominates RWMD (ACT-k, ICT, Sinkhorn,
//! exact EMD), resolved through the [`MethodRegistry`] so new measures plug
//! in without touching this file.
//!
//! For the Theorem-2 measures (OMR, ACT-k, ICT, exact EMD) the stage-1
//! measure is a *provable lower bound* of the stage-2 measure, so a
//! candidate can only move *up* in distance during rerank — with
//! `overfetch` large enough the cascade is exact, and the stage-1
//! threshold gives a certificate: any document whose stage-1 bound exceeds
//! the final ℓ-th distance could never have entered the top-ℓ.  Sinkhorn is
//! admissible as a rerank measure but its non-converged plans carry no
//! bound guarantee, so it reranks every candidate and is never certified.

use crate::core::{Distance, Embeddings, EmdError, EmdResult, Histogram, Method};
use crate::index::IvfIndex;
use crate::lc::LcEngine;

use super::topl::TopL;

// The legacy entry points below ([`cascade_search`], [`cascade_search_pruned`])
// are delegating shims over the planner's shared stage implementation
// ([`rerank_stage`]) — the same code path a `SearchRequest` with a
// `CascadeSpec` executes ([`crate::coordinator::plan`]), which additionally
// composes the cascade with IVF pruning and the sharded fan-out.

/// Cascade outcome with work accounting.
#[derive(Debug, Clone)]
pub struct CascadeResult {
    /// (distance, id) under the stage-2 measure, best first.
    pub hits: Vec<(f32, usize)>,
    /// Candidates rescored in stage 2.
    pub reranked: usize,
    /// True when the certificate held: the (overfetch·ℓ)-th stage-1 bound
    /// was above the final ℓ-th stage-2 distance, so no pruned candidate
    /// could have entered the result.
    pub certified: bool,
}

/// Whether `method` is admissible as a stage-2 rerank measure: it must be
/// at least as tight as the stage-1 RWMD prefilter.
pub fn admissible_rerank(method: Method) -> bool {
    match method {
        Method::Omr | Method::Act { .. } | Method::Ict | Method::Sinkhorn | Method::Exact => true,
        Method::Bow | Method::BowAdjusted | Method::Wcd | Method::Rwmd => false,
    }
}

/// Whether the stage-1 RWMD bound provably lower-bounds `method` pointwise
/// (Theorem 2).  Only then are the candidate-skip prune and the exactness
/// certificate sound.  Sinkhorn upper-bounds EMD *at convergence*, but a
/// non-converged plan's cost carries no such guarantee, so Sinkhorn reranks
/// every candidate and never claims a certificate.
pub fn provably_dominates_rwmd(method: Method) -> bool {
    matches!(method, Method::Omr | Method::Act { .. } | Method::Ict | Method::Exact)
}

/// Two-stage search: LC-RWMD prefilter, `rerank` on the survivors.
///
/// The rerank measure is looked up in the engine's [`MethodRegistry`] —
/// Sinkhorn and exact EMD are selected exactly like ACT-k.
pub fn cascade_search(
    engine: &LcEngine,
    query: &Histogram,
    rerank: Method,
    l: usize,
    overfetch: usize,
) -> EmdResult<CascadeResult> {
    if !admissible_rerank(rerank) {
        return Err(EmdError::unsupported(format!(
            "rerank method {} does not dominate the RWMD prefilter bound",
            rerank.name()
        )));
    }
    let n = engine.dataset().len();
    let l = l.min(n).max(1);
    let keep = (l * overfetch.max(1)).min(n);

    // stage 1: cheap lower bound over everything
    let stage1 = engine.distances(query, Method::Rwmd);
    let mut pre = TopL::new(keep);
    pre.push_slice(&stage1, 0);
    let candidates = pre.into_sorted();
    // the tightest stage-1 bound we *discarded*; anything we return below
    // this value is certified exact
    let pruned_floor = if keep < n {
        let mut rest = f32::INFINITY;
        for (u, &d) in stage1.iter().enumerate() {
            if !candidates.iter().any(|&(_, c)| c == u) && d < rest {
                rest = d;
            }
        }
        rest
    } else {
        f32::INFINITY
    };

    // stage 2 on the survivors; stage 1 covered the whole database
    rerank_survivors(engine, query, rerank, l, &candidates, pruned_floor, true)
}

/// The planner's cascade rerank stage, shared by every cascade entry point
/// (the legacy free functions here and [`crate::coordinator::plan`]'s
/// `CascadeRerank` stage): rerank the stage-1 survivors through a boxed
/// [`Distance`] object, bound-prune when the rerank measure provably
/// dominates RWMD, and compute the exactness certificate against the
/// tightest discarded stage-1 bound.
///
/// `doc` resolves a candidate id to its histogram — the monolithic paths
/// read the engine's dataset, the sharded path reads the live corpus —
/// and `covers_database` is whether stage 1 saw every database row (only
/// then can the certificate claim global exactness).  `query` must already
/// be L1-normalized.
#[allow(clippy::too_many_arguments)] // one stage boundary, nine explicit inputs
pub(crate) fn rerank_stage(
    vocab: &Embeddings,
    dist: &dyn Distance,
    rerank: Method,
    query_normalized: &Histogram,
    l: usize,
    candidates: &[(f32, usize)],
    pruned_floor: f32,
    covers_database: bool,
    doc: &dyn Fn(usize) -> Histogram,
) -> EmdResult<CascadeResult> {
    let lower_bounded = provably_dominates_rwmd(rerank);
    let mut out = TopL::new(l);
    let mut reranked = 0usize;
    for &(lb, u) in candidates {
        // classic bound pruning: skip when the stage-1 lower bound already
        // exceeds the current l-th best reranked distance — sound only for
        // measures RWMD provably lower-bounds
        if lower_bounded {
            if let Some(t) = out.threshold() {
                if lb >= t {
                    continue;
                }
            }
        }
        let d = dist.distance(vocab, &doc(u), query_normalized)? as f32;
        out.push(d, u);
        reranked += 1;
    }
    let hits = out.into_sorted();
    let certified = lower_bounded
        && covers_database
        && hits.last().map(|&(d, _)| d <= pruned_floor).unwrap_or(true);
    Ok(CascadeResult { hits, reranked, certified })
}

/// Legacy-shim adapter: [`rerank_stage`] over an [`LcEngine`]'s own dataset
/// and registry.
fn rerank_survivors(
    engine: &LcEngine,
    query: &Histogram,
    rerank: Method,
    l: usize,
    candidates: &[(f32, usize)],
    pruned_floor: f32,
    covers_database: bool,
) -> EmdResult<CascadeResult> {
    let dist = engine.registry().distance(rerank);
    rerank_stage(
        &engine.dataset().embeddings,
        dist.as_ref(),
        rerank,
        &query.normalized(),
        l,
        candidates,
        pruned_floor,
        covers_database,
        &|u| engine.dataset().histogram(u),
    )
}

/// The cascade composed with the IVF pruning index: probe the index for a
/// shortlist, LC-RWMD on the shortlist only, then the tighter rerank on
/// the survivors.  Stage-1 values are bit-identical to the full-sweep
/// cascade for the same pairs ([`LcEngine::distances_batch_subset`]).
///
/// Certificate semantics: the Theorem-2 bound prune is sound *within the
/// probed candidate set*, but a true neighbor in an unprobed list is
/// invisible to both stages — so `certified` is only claimed when the
/// candidate set covered the whole database (`nprobe >= nlist`), in which
/// case this is exactly [`cascade_search`].
pub fn cascade_search_pruned(
    engine: &LcEngine,
    index: &IvfIndex,
    query: &Histogram,
    rerank: Method,
    l: usize,
    overfetch: usize,
    nprobe: usize,
) -> EmdResult<CascadeResult> {
    if !admissible_rerank(rerank) {
        return Err(EmdError::unsupported(format!(
            "rerank method {} does not dominate the RWMD prefilter bound",
            rerank.name()
        )));
    }
    let n = engine.dataset().len();
    // validation + probe via the shared helper, so the cascade can never
    // diverge from pruned_search's probe semantics
    let cands = crate::index::probe_candidates(engine, index, query, nprobe)?;
    let l = l.min(n).max(1);
    let keep = (l * overfetch.max(1)).min(cands.len()).max(1);

    // stage 1: cheap lower bound over the shortlist only
    let stage1 =
        engine.distances_batch_subset(std::slice::from_ref(query), Method::Rwmd, &cands);
    let mut pre = TopL::new(keep);
    for (pos, &id) in cands.iter().enumerate() {
        pre.push(stage1[pos], id as usize);
    }
    let candidates = pre.into_sorted();
    let pruned_floor = if keep < cands.len() {
        let mut rest = f32::INFINITY;
        for (pos, &id) in cands.iter().enumerate() {
            let id = id as usize;
            if !candidates.iter().any(|&(_, c)| c == id) && stage1[pos] < rest {
                rest = stage1[pos];
            }
        }
        rest
    } else {
        f32::INFINITY
    };

    // stage 2: identical to the full cascade, on the shortlist survivors;
    // a global certificate is only possible when the shortlist covered the
    // whole database
    rerank_survivors(engine, query, rerank, l, &candidates, pruned_floor, cands.len() == n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Metric;
    use crate::data::{generate_mnist, MnistConfig};
    use crate::exact::emd;
    use crate::lc::EngineParams;
    use std::sync::Arc;

    fn engine() -> LcEngine {
        let ds = Arc::new(generate_mnist(&MnistConfig { n: 60, side: 14, ..Default::default() }));
        LcEngine::new(ds, EngineParams { threads: 2, symmetric: false, ..Default::default() })
    }

    #[test]
    fn cascade_exact_matches_bruteforce_emd_ranking() {
        let eng = engine();
        let q = eng.dataset().histogram(0);
        let res = cascade_search(&eng, &q, Method::Exact, 3, 8).unwrap();
        assert_eq!(res.hits.len(), 3);
        // brute force
        let mut brute: Vec<(f32, usize)> = (0..eng.dataset().len())
            .map(|u| {
                let d = emd(
                    &eng.dataset().embeddings,
                    &q,
                    &eng.dataset().histogram(u),
                    Metric::L2,
                ) as f32;
                (d, u)
            })
            .collect();
        brute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if res.certified {
            for (got, want) in res.hits.iter().zip(&brute) {
                assert!((got.0 - want.0).abs() < 1e-5, "{:?} vs {:?}", res.hits, &brute[..3]);
            }
        }
        // pruning must actually skip work on clustered data
        assert!(res.reranked <= 3 * 8);
    }

    #[test]
    fn cascade_act_rerank_is_tighter_than_stage1() {
        let eng = engine();
        let q = eng.dataset().histogram(5);
        let stage1 = eng.distances(&q, Method::Rwmd);
        let res = cascade_search(&eng, &q, Method::Act { k: 8 }, 4, 4).unwrap();
        for &(d, u) in &res.hits {
            assert!(d + 1e-5 >= stage1[u], "rerank must not go below the lower bound");
        }
    }

    #[test]
    fn sinkhorn_and_ict_rerank_through_registry() {
        let eng = engine();
        let q = eng.dataset().histogram(3);
        let stage1 = eng.distances(&q, Method::Rwmd);
        for rerank in [Method::Sinkhorn, Method::Ict] {
            let res = cascade_search(&eng, &q, rerank, 3, 4).unwrap();
            assert_eq!(res.hits.len(), 3, "{rerank}");
        }
        // ICT carries the Theorem-2 guarantee: never below the prefilter
        let res = cascade_search(&eng, &q, Method::Ict, 3, 4).unwrap();
        for &(d, u) in &res.hits {
            assert!(d + 1e-4 >= stage1[u], "ICT rerank below stage-1 bound");
        }
        // Sinkhorn has no bound guarantee: every candidate is rescored and
        // no exactness certificate is claimed
        let res = cascade_search(&eng, &q, Method::Sinkhorn, 3, 4).unwrap();
        assert_eq!(res.reranked, 3 * 4);
        assert!(!res.certified);
    }

    #[test]
    fn non_dominating_rerank_is_rejected() {
        let eng = engine();
        let q = eng.dataset().histogram(4);
        for bad in [Method::Bow, Method::Wcd, Method::Rwmd, Method::BowAdjusted] {
            assert!(cascade_search(&eng, &q, bad, 3, 2).is_err(), "{bad}");
        }
    }

    #[test]
    fn pruned_cascade_with_full_probe_equals_cascade() {
        use crate::config::IndexParams;
        use crate::index::{dataset_fingerprint, IvfIndex};
        let eng = engine();
        let ix = IvfIndex::train(
            eng.wcd_centroids(),
            eng.dataset().embeddings.dim(),
            &IndexParams { nlist: 5, nprobe: 2, train_iters: 6, seed: 9, min_points_per_list: 1 },
            2,
            dataset_fingerprint(eng.dataset()),
        )
        .unwrap();
        let q = eng.dataset().histogram(7);
        let full = cascade_search(&eng, &q, Method::Act { k: 4 }, 3, 4).unwrap();
        let pruned =
            cascade_search_pruned(&eng, &ix, &q, Method::Act { k: 4 }, 3, 4, ix.nlist())
                .unwrap();
        assert_eq!(pruned.hits, full.hits);
        assert_eq!(pruned.certified, full.certified);

        // narrow probe: results respect the stage-1 bound and never claim a
        // global certificate
        let narrow =
            cascade_search_pruned(&eng, &ix, &q, Method::Act { k: 4 }, 3, 4, 2).unwrap();
        assert!(!narrow.certified);
        let stage1 = eng.distances(&q, Method::Rwmd);
        for &(d, u) in &narrow.hits {
            assert!(d + 1e-5 >= stage1[u], "rerank below the lower bound");
        }
        // a database query still finds itself through its own list
        assert_eq!(narrow.hits[0].1, 7);
    }

    #[test]
    fn overfetch_one_still_returns_l() {
        let eng = engine();
        let q = eng.dataset().histogram(1);
        let res = cascade_search(&eng, &q, Method::Act { k: 2 }, 5, 1).unwrap();
        assert_eq!(res.hits.len(), 5);
        assert!(res.reranked >= 5);
    }

    #[test]
    fn full_overfetch_is_always_certified() {
        let eng = engine();
        let q = eng.dataset().histogram(2);
        let res = cascade_search(&eng, &q, Method::Act { k: 4 }, 3, usize::MAX / 4).unwrap();
        assert!(res.certified);
    }
}
