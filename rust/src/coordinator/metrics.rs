//! Lock-free coordinator metrics: counters + latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Exponential latency histogram: bucket i covers [2^i, 2^(i+1)) microseconds.
const BUCKETS: usize = 24;

/// A log-bucketed latency histogram with running per-bucket sums, usable
/// lock-free from any number of threads.  Percentiles interpolate within
/// the hit bucket using its recorded mean, so a bucket filled by identical
/// samples reports their exact value (a single 10 µs sample yields
/// p50 = 10, not the old 16 µs upper bound).
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; BUCKETS],
    /// Sum of the samples that landed in each bucket — the interpolation
    /// anchor for percentiles and the exposition layer's `_sum` series.
    bucket_sums: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHist {
    pub fn record(&self, latency: Duration) {
        self.record_us(latency.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    pub fn record_us(&self, us: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.bucket_sums[bucket].fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    /// Approximate percentile, microseconds.  Walks the buckets to the one
    /// holding the requested rank, then interpolates within it using the
    /// bucket's recorded mean (clamped to the bucket bounds) — exact when
    /// the hit bucket holds one distinct value, within the bucket span
    /// otherwise.
    pub fn percentile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let sums: Vec<u64> =
            self.bucket_sums.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        percentile_from(&counts, &sums, total, q)
    }

    /// Per-bucket counts (non-cumulative), index i covering
    /// `[2^i, 2^(i+1))` µs — the exposition layer's raw series.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold every sample recorded in `other` into `self` (atomic adds, so
    /// both histograms may keep recording concurrently).  The merged
    /// histogram reports exactly what a single histogram fed both sample
    /// streams serially would.
    pub fn merge(&self, other: &LatencyHist) {
        for i in 0..BUCKETS {
            let c = other.buckets[i].load(Ordering::Relaxed);
            if c > 0 {
                self.buckets[i].fetch_add(c, Ordering::Relaxed);
                self.bucket_sums[i]
                    .fetch_add(other.bucket_sums[i].load(Ordering::Relaxed), Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Plain-value copy of the current state — the telemetry windows' unit
    /// of storage, diffable via [`HistSnapshot::delta`].
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::default();
        for i in 0..BUCKETS {
            s.buckets[i] = self.buckets[i].load(Ordering::Relaxed);
            s.bucket_sums[i] = self.bucket_sums[i].load(Ordering::Relaxed);
        }
        s.count = self.count.load(Ordering::Relaxed);
        s.sum_us = self.sum_us.load(Ordering::Relaxed);
        s
    }

    /// Upper bound of bucket `i` in µs; `None` marks the last,
    /// unbounded bucket (`+Inf` in Prometheus terms).
    pub fn bucket_bound(i: usize) -> Option<u64> {
        if i + 1 >= BUCKETS {
            None
        } else {
            Some(1u64 << (i + 1))
        }
    }

    /// Zero every counter.  Not atomic as a whole: samples recorded while
    /// the reset sweeps may survive in some arrays and not others, but
    /// every individual counter stays monotonic between resets — good
    /// enough for zeroing between bench phases.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        for b in &self.bucket_sums {
            b.store(0, Ordering::Relaxed);
        }
        self.sum_us.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }

    /// Snapshot: `{count, mean_us, p50_us, p95_us, p99_us}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", (self.count() as usize).into()),
            ("mean_us", self.mean_us().into()),
            ("p50_us", (self.percentile_us(0.5) as usize).into()),
            ("p95_us", (self.percentile_us(0.95) as usize).into()),
            ("p99_us", (self.percentile_us(0.99) as usize).into()),
        ])
    }
}

/// Shared percentile walk over plain bucket arrays: find the bucket holding
/// the requested rank and interpolate within it using the bucket's recorded
/// mean.  `0` for an empty histogram — including the (racy-snapshot) case
/// where `total > 0` but every per-bucket count read back as zero, which
/// used to fall through to a fictitious bucket edge.
fn percentile_from(counts: &[u64], sums: &[u64], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let want = ((total as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= want && c > 0 {
            let lo = if i == 0 { 0 } else { 1u64 << i };
            let hi = if i + 1 >= BUCKETS { u64::MAX } else { (1u64 << (i + 1)) - 1 };
            let mean = sums[i] / c;
            return mean.clamp(lo, hi);
        }
    }
    // every count read as zero while `total` claimed samples: a torn
    // concurrent snapshot — report empty rather than inventing an edge
    0
}

/// A plain-value copy of a [`LatencyHist`] at one instant.  Two snapshots
/// of the same histogram diff into the samples recorded between them —
/// the telemetry store's per-window latency delta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub bucket_sums: [u64; BUCKETS],
    pub sum_us: u64,
    pub count: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot {
            buckets: [0; BUCKETS],
            bucket_sums: [0; BUCKETS],
            sum_us: 0,
            count: 0,
        }
    }
}

impl HistSnapshot {
    /// The samples recorded between `earlier` and `self` (both snapshots of
    /// one monotonic histogram; saturating, so a reset racing the pair
    /// yields zeros rather than wrapping).
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut d = HistSnapshot::default();
        for i in 0..BUCKETS {
            d.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
            d.bucket_sums[i] = self.bucket_sums[i].saturating_sub(earlier.bucket_sums[i]);
        }
        d.count = self.count.saturating_sub(earlier.count);
        d.sum_us = self.sum_us.saturating_sub(earlier.sum_us);
        d
    }

    /// Record one sample directly (plain-value histograms owned by a lock,
    /// e.g. a telemetry window under its ring mutex).  Same bucketing as
    /// [`LatencyHist::record_us`], so merged snapshots and atomic
    /// histograms stay comparable.
    pub fn record_us(&mut self, us: u64) {
        self.count += 1;
        self.sum_us += us;
        let bucket = (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[bucket] += 1;
        self.bucket_sums[bucket] += us;
    }

    /// Accumulate another snapshot (merging window deltas).
    pub fn add(&mut self, other: &HistSnapshot) {
        for i in 0..BUCKETS {
            self.buckets[i] += other.buckets[i];
            self.bucket_sums[i] += other.bucket_sums[i];
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Bucket-mean-interpolated percentile; `0` when empty.
    pub fn percentile_us(&self, q: f64) -> u64 {
        percentile_from(&self.buckets, &self.bucket_sums, self.count, q)
    }

    /// Same shape as [`LatencyHist::to_json`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", (self.count as usize).into()),
            ("mean_us", self.mean_us().into()),
            ("p50_us", (self.percentile_us(0.5) as usize).into()),
            ("p95_us", (self.percentile_us(0.95) as usize).into()),
            ("p99_us", (self.percentile_us(0.99) as usize).into()),
        ])
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    /// Plan executions (one per `SearchEngine::execute`, i.e. one per
    /// dispatch group — a single-query request counts as a batch of one,
    /// and a failed group's per-query retries count individually).
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    pub distance_evals: AtomicU64,
    /// Queries routed through the IVF pruning index.
    pub index_queries: AtomicU64,
    /// Inverted lists visited by index-routed queries.
    pub lists_probed: AtomicU64,
    /// Candidates actually scored by index-routed queries.
    pub candidates_scored: AtomicU64,
    /// What exhaustive search would have scored for the same queries
    /// (denominator of the pruned fraction).
    index_possible: AtomicU64,
    /// Queries answered through a cascade plan (RWMD prefilter → rerank).
    pub cascade_queries: AtomicU64,
    /// Candidates rescored by cascade rerank stages.
    pub reranked_total: AtomicU64,
    /// Query batches answered by the sharded fan-out route.
    pub shard_batches: AtomicU64,
    /// Microseconds spent k-way-merging per-shard top-ℓ accumulators (the
    /// fan-out overhead a monolithic corpus does not pay).
    merge_sum_us: AtomicU64,
    latency: LatencyHist,
    /// Searches admitted into the compute bridge.
    pub admitted: AtomicU64,
    /// Searches shed at admission (`overloaded`).
    pub shed: AtomicU64,
    /// Searches shed because their deadline expired before/during compute.
    pub deadline_expired: AtomicU64,
    /// Hedged remote shard requests (a replica was raced after the hedge
    /// delay elapsed without a primary response).
    pub remote_hedges: AtomicU64,
    /// Remote shard attempts retried after a connection/overload error.
    pub remote_retries: AtomicU64,
    /// Remote shards dropped from a merge on their per-shard timeout
    /// (the response is marked `partial`).
    pub remote_timeouts: AtomicU64,
    /// Enqueue → batch-drain wait per search.
    pub queue_wait: LatencyHist,
    /// Engine execute time per dispatch group.
    pub execute: LatencyHist,
    /// Enqueue → response-serialized end-to-end time per search.
    pub e2e: LatencyHist,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_query(&self, latency: Duration, evals: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.distance_evals.fetch_add(evals as u64, Ordering::Relaxed);
        self.latency.record(latency);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one index-routed query: `lists` probed, `candidates` scored,
    /// out of `possible` (the full database size).
    pub fn record_probe(&self, lists: usize, candidates: usize, possible: usize) {
        self.index_queries.fetch_add(1, Ordering::Relaxed);
        self.lists_probed.fetch_add(lists as u64, Ordering::Relaxed);
        self.candidates_scored.fetch_add(candidates as u64, Ordering::Relaxed);
        self.index_possible.fetch_add(possible as u64, Ordering::Relaxed);
    }

    /// Record one cascade dispatch: `queries` answered, `reranked`
    /// candidates rescored by stage 2.
    pub fn record_cascade(&self, queries: usize, reranked: usize) {
        self.cascade_queries.fetch_add(queries as u64, Ordering::Relaxed);
        self.reranked_total.fetch_add(reranked as u64, Ordering::Relaxed);
    }

    /// Record one sharded fan-out dispatch and its cross-shard merge time.
    pub fn record_merge(&self, merge: Duration) {
        self.shard_batches.fetch_add(1, Ordering::Relaxed);
        let us = merge.as_micros().min(u128::from(u64::MAX)) as u64;
        self.merge_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_remote_hedge(&self) {
        self.remote_hedges.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_remote_retry(&self) {
        self.remote_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_remote_timeout(&self) {
        self.remote_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Total microseconds spent in cross-shard top-ℓ merges.
    pub fn merge_us(&self) -> u64 {
        self.merge_sum_us.load(Ordering::Relaxed)
    }

    /// Fraction of the database index-routed queries did *not* score
    /// (0.0 when no query went through the index).
    pub fn pruned_fraction(&self) -> f64 {
        let possible = self.index_possible.load(Ordering::Relaxed);
        if possible == 0 {
            return 0.0;
        }
        let scored = self.candidates_scored.load(Ordering::Relaxed);
        1.0 - scored as f64 / possible as f64
    }

    /// Approximate latency percentile (bucket-mean interpolated),
    /// microseconds.
    pub fn latency_percentile_us(&self, q: f64) -> u64 {
        self.latency.percentile_us(q)
    }

    /// Zero every counter and histogram (the `stats` op's
    /// `{"reset": true}`).  Racy-but-monotonic: a query racing the reset
    /// may land some of its increments before the sweep and some after, so
    /// cross-counter invariants (e.g. `queries >= cascade_queries`) can be
    /// off by in-flight work — each counter individually restarts from a
    /// value ≤ its true post-reset count and only grows.
    pub fn reset(&self) {
        for c in [
            &self.queries,
            &self.batches,
            &self.errors,
            &self.distance_evals,
            &self.index_queries,
            &self.lists_probed,
            &self.candidates_scored,
            &self.index_possible,
            &self.cascade_queries,
            &self.reranked_total,
            &self.shard_batches,
            &self.merge_sum_us,
            &self.admitted,
            &self.shed,
            &self.deadline_expired,
            &self.remote_hedges,
            &self.remote_retries,
            &self.remote_timeouts,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        self.latency.reset();
        self.queue_wait.reset();
        self.execute.reset();
        self.e2e.reset();
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.queries.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency.sum_us() as f64 / n as f64
        }
    }

    /// Snapshot as JSON (served by the coordinator's `stats` command).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queries", (self.queries.load(Ordering::Relaxed) as usize).into()),
            ("batches", (self.batches.load(Ordering::Relaxed) as usize).into()),
            ("errors", (self.errors.load(Ordering::Relaxed) as usize).into()),
            (
                "distance_evals",
                (self.distance_evals.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "index_queries",
                (self.index_queries.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "lists_probed",
                (self.lists_probed.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "candidates_scored",
                (self.candidates_scored.load(Ordering::Relaxed) as usize).into(),
            ),
            ("pruned_fraction", self.pruned_fraction().into()),
            (
                "cascade_queries",
                (self.cascade_queries.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "reranked_total",
                (self.reranked_total.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "shard_batches",
                (self.shard_batches.load(Ordering::Relaxed) as usize).into(),
            ),
            ("merge_us_total", (self.merge_us() as usize).into()),
            ("mean_latency_us", self.mean_latency_us().into()),
            ("p50_latency_us", (self.latency_percentile_us(0.5) as usize).into()),
            ("p95_latency_us", (self.latency_percentile_us(0.95) as usize).into()),
            ("p99_latency_us", (self.latency_percentile_us(0.99) as usize).into()),
            ("admitted", (self.admitted.load(Ordering::Relaxed) as usize).into()),
            ("shed", (self.shed.load(Ordering::Relaxed) as usize).into()),
            (
                "deadline_expired",
                (self.deadline_expired.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "remote_hedges",
                (self.remote_hedges.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "remote_retries",
                (self.remote_retries.load(Ordering::Relaxed) as usize).into(),
            ),
            (
                "remote_timeouts",
                (self.remote_timeouts.load(Ordering::Relaxed) as usize).into(),
            ),
            ("queue_wait", self.queue_wait.to_json()),
            ("execute", self.execute.to_json()),
            ("e2e", self.e2e.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(100), 50);
        m.record_query(Duration::from_micros(200), 50);
        m.record_batch();
        assert_eq!(m.queries.load(Ordering::Relaxed), 2);
        assert_eq!(m.distance_evals.load(Ordering::Relaxed), 100);
        assert!((m.mean_latency_us() - 150.0).abs() < 1e-9);
        // 100 µs and 200 µs land in different buckets; the median bucket
        // holds only the 100 µs sample, so interpolation reports it exactly
        assert_eq!(m.latency_percentile_us(0.5), 100);
    }

    #[test]
    fn empty_percentile_zero() {
        assert_eq!(Metrics::new().latency_percentile_us(0.9), 0);
    }

    #[test]
    fn json_snapshot_has_fields() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(10), 1);
        let j = m.to_json();
        assert_eq!(j.get("queries").and_then(Json::as_usize), Some(1));
        assert!(j.get("p95_latency_us").is_some());
        assert!(j.get("pruned_fraction").is_some());
    }

    #[test]
    fn merge_counters_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.merge_us(), 0);
        m.record_merge(Duration::from_micros(40));
        m.record_merge(Duration::from_micros(60));
        assert_eq!(m.shard_batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.merge_us(), 100);
        let j = m.to_json();
        assert_eq!(j.get("shard_batches").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("merge_us_total").and_then(Json::as_usize), Some(100));
    }

    #[test]
    fn cascade_counters_accumulate() {
        let m = Metrics::new();
        m.record_cascade(3, 24);
        m.record_cascade(1, 8);
        assert_eq!(m.cascade_queries.load(Ordering::Relaxed), 4);
        assert_eq!(m.reranked_total.load(Ordering::Relaxed), 32);
        let j = m.to_json();
        assert_eq!(j.get("cascade_queries").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("reranked_total").and_then(Json::as_usize), Some(32));
    }

    #[test]
    fn probe_counters_and_pruned_fraction() {
        let m = Metrics::new();
        assert_eq!(m.pruned_fraction(), 0.0);
        m.record_probe(4, 25, 100);
        m.record_probe(4, 25, 100);
        assert_eq!(m.index_queries.load(Ordering::Relaxed), 2);
        assert_eq!(m.lists_probed.load(Ordering::Relaxed), 8);
        assert_eq!(m.candidates_scored.load(Ordering::Relaxed), 50);
        assert!((m.pruned_fraction() - 0.75).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("candidates_scored").and_then(Json::as_usize), Some(50));
    }

    #[test]
    fn latency_hist_percentiles_and_json() {
        let h = LatencyHist::default();
        assert_eq!(h.percentile_us(0.99), 0);
        for us in [10u64, 100, 100, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 1302.5).abs() < 1e-9);
        // rank 2 of 4 falls in the [64,128) bucket holding both 100 µs
        // samples; rank 4 (p99) is the 5 ms outlier alone in its bucket —
        // bucket-mean interpolation recovers both exactly
        assert_eq!(h.percentile_us(0.5), 100);
        assert_eq!(h.percentile_us(0.99), 5000);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(4));
        assert!(j.get("p99_us").is_some());
    }

    #[test]
    fn percentile_interpolates_within_the_hit_bucket() {
        // the motivating defect: a single 10 µs sample used to report its
        // bucket's upper bound (16 µs) for every percentile
        let h = LatencyHist::default();
        h.record_us(10);
        assert_eq!(h.percentile_us(0.5), 10);
        assert_eq!(h.percentile_us(0.99), 10);
        // a mixed bucket reports its (clamped) mean: 9 and 15 share [8,16)
        let h2 = LatencyHist::default();
        h2.record_us(9);
        h2.record_us(15);
        assert_eq!(h2.percentile_us(0.5), 12);
        // the mean never escapes the bucket bounds
        assert!(h2.percentile_us(0.99) < 16);
    }

    #[test]
    fn hist_reset_zeroes_counts_and_sums() {
        let h = LatencyHist::default();
        h.record_us(10);
        h.record_us(300);
        assert_eq!(h.count(), 2);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_us(), 0);
        assert_eq!(h.percentile_us(0.5), 0);
        assert!(h.bucket_counts().iter().all(|&c| c == 0));
        // and keeps recording after the reset
        h.record_us(20);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile_us(0.5), 20);
    }

    #[test]
    fn metrics_reset_zeroes_every_counter() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(100), 50);
        m.record_batch();
        m.record_probe(4, 25, 100);
        m.record_cascade(3, 24);
        m.record_merge(Duration::from_micros(40));
        m.record_admitted();
        m.record_shed();
        m.record_deadline_expired();
        m.record_remote_hedge();
        m.record_remote_retry();
        m.record_remote_timeout();
        m.queue_wait.record(Duration::from_micros(40));
        m.e2e.record(Duration::from_micros(450));
        m.reset();
        let j = m.to_json();
        for key in [
            "queries",
            "batches",
            "errors",
            "distance_evals",
            "index_queries",
            "lists_probed",
            "candidates_scored",
            "cascade_queries",
            "reranked_total",
            "shard_batches",
            "merge_us_total",
            "admitted",
            "shed",
            "deadline_expired",
            "remote_hedges",
            "remote_retries",
            "remote_timeouts",
        ] {
            assert_eq!(j.get(key).and_then(Json::as_usize), Some(0), "{key} not reset");
        }
        assert_eq!(m.pruned_fraction(), 0.0);
        assert_eq!(m.latency_percentile_us(0.5), 0);
        assert_eq!(
            j.get("e2e").and_then(|e| e.get("count")).and_then(Json::as_usize),
            Some(0)
        );
    }

    #[test]
    fn merged_hist_equals_serial_recording() {
        // the satellite contract: recording two sample streams into two
        // histograms and merging must be indistinguishable from recording
        // both streams into one histogram serially
        let stream_a = [10u64, 100, 100, 5000, 9, 15];
        let stream_b = [3u64, 10, 260, 70_000, 1];
        let serial = LatencyHist::default();
        for &us in stream_a.iter().chain(&stream_b) {
            serial.record_us(us);
        }
        let (a, b) = (LatencyHist::default(), LatencyHist::default());
        for &us in &stream_a {
            a.record_us(us);
        }
        for &us in &stream_b {
            b.record_us(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), serial.count());
        assert_eq!(a.sum_us(), serial.sum_us());
        assert_eq!(a.bucket_counts(), serial.bucket_counts());
        for q in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(a.percentile_us(q), serial.percentile_us(q), "q={q}");
        }
        assert_eq!(a.snapshot(), serial.snapshot());
    }

    #[test]
    fn snapshot_delta_isolates_a_window() {
        let h = LatencyHist::default();
        h.record_us(10);
        h.record_us(100);
        let t0 = h.snapshot();
        h.record_us(100);
        h.record_us(5000);
        let t1 = h.snapshot();
        let win = t1.delta(&t0);
        assert_eq!(win.count, 2);
        assert_eq!(win.sum_us, 5100);
        // the window holds exactly the two new samples: one 100 µs, one 5 ms
        assert_eq!(win.percentile_us(0.5), 100);
        assert_eq!(win.percentile_us(0.99), 5000);
        // deltas accumulate back into the full window sum
        let mut acc = t0.delta(&HistSnapshot::default());
        acc.add(&win);
        assert_eq!(acc, t1);
        // a reset racing the pair saturates to empty instead of wrapping
        h.reset();
        let after = h.snapshot().delta(&t1);
        assert_eq!(after.count, 0);
        assert_eq!(after.percentile_us(0.5), 0);
    }

    #[test]
    fn empty_snapshot_percentile_is_zero_not_a_bucket_edge() {
        let s = HistSnapshot::default();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.percentile_us(q), 0);
        }
        assert_eq!(s.mean_us(), 0.0);
        // a torn snapshot (count claimed, buckets empty) also reports 0
        let torn = HistSnapshot { count: 3, ..HistSnapshot::default() };
        assert_eq!(torn.percentile_us(0.99), 0);
        let j = s.to_json();
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(0));
        assert_eq!(j.get("p99_us").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn bucket_bounds_are_powers_of_two_with_inf_tail() {
        assert_eq!(LatencyHist::bucket_bound(0), Some(2));
        assert_eq!(LatencyHist::bucket_bound(6), Some(128));
        assert_eq!(LatencyHist::bucket_bound(BUCKETS - 1), None);
    }

    #[test]
    fn admission_counters_surface_in_stats() {
        let m = Metrics::new();
        m.record_admitted();
        m.record_admitted();
        m.record_shed();
        m.record_deadline_expired();
        m.queue_wait.record(Duration::from_micros(40));
        m.execute.record(Duration::from_micros(400));
        m.e2e.record(Duration::from_micros(450));
        let j = m.to_json();
        assert_eq!(j.get("admitted").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("shed").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("deadline_expired").and_then(Json::as_usize), Some(1));
        let qw = j.get("queue_wait").expect("queue_wait sub-object");
        assert_eq!(qw.get("count").and_then(Json::as_usize), Some(1));
        assert_eq!(
            j.get("e2e").and_then(|e| e.get("count")).and_then(Json::as_usize),
            Some(1)
        );
    }
}
