//! Lock-free coordinator metrics: counters + latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Exponential latency histogram: bucket i covers [2^i, 2^(i+1)) microseconds.
const BUCKETS: usize = 24;

#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    pub distance_evals: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_query(&self, latency: Duration, evals: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.distance_evals.fetch_add(evals as u64, Ordering::Relaxed);
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency percentile (upper bucket bound), microseconds.
    pub fn latency_percentile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> =
            self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let want = ((total as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= want {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.queries.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Snapshot as JSON (served by the coordinator's `stats` command).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queries", (self.queries.load(Ordering::Relaxed) as usize).into()),
            ("batches", (self.batches.load(Ordering::Relaxed) as usize).into()),
            ("errors", (self.errors.load(Ordering::Relaxed) as usize).into()),
            (
                "distance_evals",
                (self.distance_evals.load(Ordering::Relaxed) as usize).into(),
            ),
            ("mean_latency_us", self.mean_latency_us().into()),
            ("p50_latency_us", (self.latency_percentile_us(0.5) as usize).into()),
            ("p95_latency_us", (self.latency_percentile_us(0.95) as usize).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(100), 50);
        m.record_query(Duration::from_micros(200), 50);
        m.record_batch();
        assert_eq!(m.queries.load(Ordering::Relaxed), 2);
        assert_eq!(m.distance_evals.load(Ordering::Relaxed), 100);
        assert!((m.mean_latency_us() - 150.0).abs() < 1e-9);
        let p50 = m.latency_percentile_us(0.5);
        assert!(p50 >= 128 && p50 <= 256, "p50 {p50}");
    }

    #[test]
    fn empty_percentile_zero() {
        assert_eq!(Metrics::new().latency_percentile_us(0.9), 0);
    }

    #[test]
    fn json_snapshot_has_fields() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(10), 1);
        let j = m.to_json();
        assert_eq!(j.get("queries").and_then(Json::as_usize), Some(1));
        assert!(j.get("p95_latency_us").is_some());
    }
}
