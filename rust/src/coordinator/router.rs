//! Database shard router: partitions the database row space into
//! contiguous shards and fans Phase-2 work out over them.
//!
//! Sharding exists for two reasons: (1) it is the unit of parallel fan-out
//! for batched queries; (2) artifact tiles have a fixed row count, so the
//! shard boundaries align with tile boundaries when the artifact backend is
//! active.

use std::ops::Range;

/// Contiguous row-range sharding.
#[derive(Debug, Clone)]
pub struct Router {
    n: usize,
    boundaries: Vec<usize>,
}

impl Router {
    pub fn new(n: usize, shards: usize) -> Router {
        if n == 0 {
            // an empty database has zero shards, not one empty phantom
            // shard — downstream fan-out loops iterate `shards()` and must
            // see nothing to do
            return Router { n: 0, boundaries: vec![0] };
        }
        let shards = shards.clamp(1, n);
        let base = n / shards;
        let extra = n % shards;
        let mut boundaries = Vec::with_capacity(shards + 1);
        boundaries.push(0);
        let mut pos = 0;
        for s in 0..shards {
            pos += base + usize::from(s < extra);
            boundaries.push(pos);
        }
        Router { n, boundaries }
    }

    /// Align shard boundaries to a tile size (artifact backend).
    pub fn with_tile_alignment(n: usize, tile: usize) -> Router {
        assert!(tile >= 1);
        let mut boundaries = vec![0];
        let mut pos = 0;
        while pos < n {
            pos = (pos + tile).min(n);
            boundaries.push(pos);
        }
        // n == 0 keeps boundaries == [0]: zero shards, matching `new`
        Router { n, boundaries }
    }

    pub fn num_shards(&self) -> usize {
        self.boundaries.len() - 1
    }

    pub fn shard(&self, s: usize) -> Range<usize> {
        self.boundaries[s]..self.boundaries[s + 1]
    }

    pub fn shards(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.num_shards()).map(|s| self.shard(s))
    }

    /// Which shard owns database row `id`.
    pub fn shard_of(&self, id: usize) -> usize {
        debug_assert!(id < self.n);
        self.boundaries.partition_point(|&b| b <= id) - 1
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_disjointly() {
        let r = Router::new(10, 3);
        let all: Vec<usize> = r.shards().flatten().collect();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        assert_eq!(r.num_shards(), 3);
        // balanced: 4, 3, 3
        assert_eq!(r.shard(0), 0..4);
        assert_eq!(r.shard(1), 4..7);
    }

    #[test]
    fn more_shards_than_rows_clamps() {
        let r = Router::new(2, 8);
        assert_eq!(r.num_shards(), 2);
    }

    #[test]
    fn shard_of_matches_ranges() {
        let r = Router::new(11, 4);
        for id in 0..11 {
            let s = r.shard_of(id);
            assert!(r.shard(s).contains(&id), "id {id} shard {s}");
        }
    }

    #[test]
    fn tile_alignment() {
        let r = Router::with_tile_alignment(10, 4);
        let ranges: Vec<_> = r.shards().collect();
        assert_eq!(ranges, vec![0..4, 4..8, 8..10]);
    }

    #[test]
    fn empty_database_yields_zero_shards() {
        // regression: boundaries [0, 0] used to report one phantom empty
        // shard for an empty database
        for r in [Router::new(0, 3), Router::with_tile_alignment(0, 4)] {
            assert_eq!(r.num_shards(), 0, "{r:?}");
            assert_eq!(r.shards().count(), 0);
            assert_eq!(r.len(), 0);
            assert!(r.is_empty());
        }
    }
}
