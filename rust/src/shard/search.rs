//! Fan-out top-ℓ search over a [`ShardedCorpus`]: probe each shard locally,
//! score candidates through the shard engine's bit-identical
//! Phase-1/Phase-2 pipeline, and k-way-merge the per-shard top-ℓ
//! accumulators into global results.
//!
//! ## Bit-identity contract
//!
//! Every shard scores its rows through the same machinery a monolithic
//! sweep uses — the shard dataset's rows are bit-exact copies, the query
//! plan depends only on the (shared) vocabulary, and every Phase-2 row cost
//! is independent of its neighbors — so a shard-local distance equals the
//! monolithic distance for the same (query, document) pair **bit for bit**.
//! Per-shard accumulators keep the ℓ best by `(distance, global id)`; each
//! shard's global ids are strictly ascending in local order, so shard-local
//! tie-breaks agree with global ones, and the k-way merge
//! ([`crate::coordinator::topl::merge_query_rows`]) of per-shard top-ℓ sets
//! contains the global top-ℓ.  With `nprobe >= nlist` on every shard (or no
//! indexes at all) the fan-out therefore reproduces monolithic exhaustive
//! `search_batch` exactly: same ids, bit-equal distances, any shard count.
//!
//! Smaller `nprobe` probes each shard's IVF lists locally and trades recall
//! for a sublinear number of scored candidates, exactly like the
//! single-index pruned route — but trained and probed per shard.

use std::time::{Duration, Instant};

use crate::core::{EmdResult, Histogram, Method};
use crate::coordinator::topl::merge_query_rows;
use crate::coordinator::TopL;
use crate::index::pruned_search_batch;
use crate::util::threadpool::{parallel_for, SyncSlice};

use super::corpus::{Shard, ShardedCorpus};

/// One query's sharded outcome with fan-out work accounting.
#[derive(Debug, Clone)]
pub struct ShardedSearch {
    /// (distance, **global** document id), best first — distances are
    /// bit-identical to the monolithic values for the same pairs.
    pub hits: Vec<(f32, usize)>,
    /// Label of each hit.
    pub labels: Vec<u16>,
    /// Database rows scored for this query, summed over shards.
    pub candidates: usize,
    /// Inverted lists visited for this query, summed over pruned shards.
    pub lists_probed: usize,
    /// Whether any shard served this query through its IVF index.
    pub pruned: bool,
}

/// A whole batch's sharded outcome.
#[derive(Debug, Clone)]
pub struct ShardedBatch {
    pub results: Vec<ShardedSearch>,
    /// Wall time of the final cross-shard k-way merge (the fan-out
    /// overhead a monolithic corpus does not pay).
    pub merge_time: Duration,
    /// Wall time of the parallel fan-out (every shard probed + scored),
    /// up to the start of the merge.
    pub fanout_time: Duration,
    /// Per-shard lanes in shard order: (start offset from fan-out entry,
    /// duration) — the span timeline's `ShardFanout` children.
    pub shard_times: Vec<(Duration, Duration)>,
}

/// One shard's contribution to a fan-out batch: per-query top-ℓ
/// accumulators (global ids) plus probe accounting.
struct ShardContribution {
    accs: Vec<TopL>,
    candidates: Vec<usize>,
    lists_probed: Vec<usize>,
    pruned: bool,
}

/// Search one shard for the whole batch (the per-shard stage of the plan).
/// Pure with respect to its shard — contributions are independent, which is
/// what makes the parallel fan-out bit-identical to the serial one.
fn search_shard(
    shard: &Shard,
    queries: &[Histogram],
    method: Method,
    l: usize,
    np: Option<usize>,
) -> EmdResult<ShardContribution> {
    let nq = queries.len();
    let mut candidates = vec![0usize; nq];
    let mut lists_probed = vec![0usize; nq];
    let route = match (shard.index(), np) {
        (Some(ix), Some(np)) if np < ix.nlist() => Some((ix, np)),
        _ => None,
    };
    let (accs, pruned) = match route {
        Some((ix, np)) => {
            // shard-local IVF probe; the whole batch shares one
            // candidate-union scoring dispatch per shard
            let pruned = pruned_search_batch(shard.engine(), ix, queries, method, l, np)?;
            let mut accs = Vec::with_capacity(nq);
            for (q, pr) in pruned.into_iter().enumerate() {
                let mut top = TopL::new(l);
                // local → global is strictly monotone, so pushing the
                // already-sorted hits preserves their order exactly
                for (d, local) in pr.hits {
                    top.push(d, shard.global(local));
                }
                candidates[q] += pr.candidates;
                lists_probed[q] += pr.lists_probed;
                accs.push(top);
            }
            (accs, true)
        }
        None => {
            // exhaustive shard sweep through the multi-query kernel
            let n = shard.len();
            let flat = shard.engine().distances_batch(queries, method);
            let mut accs = Vec::with_capacity(nq);
            for q in 0..nq {
                let row = &flat[q * n..(q + 1) * n];
                let mut top = TopL::new(l);
                for (local, &d) in row.iter().enumerate() {
                    top.push(d, shard.global(local));
                }
                candidates[q] += n;
                accs.push(top);
            }
            (accs, false)
        }
    };
    Ok(ShardContribution { accs, candidates, lists_probed, pruned })
}

/// Fan a query batch out across shards and k-way-merge per-shard top-ℓ.
///
/// `nprobe = None` uses the corpus' configured per-shard index default;
/// each shard clamps the effective width to its own list count, so any
/// width at or above every shard's `nlist` is the exhaustive
/// (bit-identical) route.  Shards are searched concurrently with the
/// corpus' full thread budget as the fan-out width (each shard engine runs
/// on its per-shard budget); see [`search_batch_budgeted`] for an explicit
/// width.
pub fn search_batch(
    corpus: &ShardedCorpus,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: Option<usize>,
) -> EmdResult<ShardedBatch> {
    search_batch_budgeted(corpus, queries, method, l, nprobe, None)
}

/// [`search_batch`] with an explicit fan-out width: up to `fanout` shards
/// are searched concurrently (`None` = the corpus' total thread budget;
/// `Some(1)` = the serial reference).  Every shard's contribution is
/// computed independently and merged in shard order, so the result is
/// **bit-identical for every width** — the serial-vs-parallel equality test
/// pins this down.
pub fn search_batch_budgeted(
    corpus: &ShardedCorpus,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: Option<usize>,
    fanout: Option<usize>,
) -> EmdResult<ShardedBatch> {
    let nq = queries.len();
    if nq == 0 {
        return Ok(ShardedBatch {
            results: Vec::new(),
            merge_time: Duration::ZERO,
            fanout_time: Duration::ZERO,
            shard_times: Vec::new(),
        });
    }
    let l = l.max(1);
    let np = corpus.effective_nprobe(nprobe, corpus.index_params().map(|p| p.nprobe));

    // parallel fan-out: each shard's contribution lands in its own slot, so
    // the post-join assembly below reads them back in shard order
    let nshards = corpus.num_shards();
    let width = fanout
        .unwrap_or(corpus.engine_params().threads)
        .clamp(1, nshards.max(1));
    let t_fan = Instant::now();
    let mut slots: Vec<Option<(EmdResult<ShardContribution>, Duration, Duration)>> =
        (0..nshards).map(|_| None).collect();
    {
        let sync = SyncSlice::new(&mut slots);
        parallel_for(nshards, width, |start, end| {
            for s in start..end {
                let begin = t_fan.elapsed();
                let contribution = search_shard(&corpus.shards()[s], queries, method, l, np);
                let dur = t_fan.elapsed().saturating_sub(begin);
                // SAFETY: slot s is owned by exactly this chunk.
                unsafe { sync.write(s, Some((contribution, begin, dur))) };
            }
        });
    }
    let fanout_time = t_fan.elapsed();

    let mut shard_accs: Vec<Vec<TopL>> = Vec::with_capacity(nshards);
    let mut shard_times = Vec::with_capacity(nshards);
    let mut candidates = vec![0usize; nq];
    let mut lists_probed = vec![0usize; nq];
    let mut pruned_any = false;
    for slot in slots {
        let (contribution, begin, dur) = slot.expect("every shard searched");
        let contribution = contribution?;
        shard_times.push((begin, dur));
        for q in 0..nq {
            candidates[q] += contribution.candidates[q];
            lists_probed[q] += contribution.lists_probed[q];
        }
        pruned_any |= contribution.pruned;
        shard_accs.push(contribution.accs);
    }

    // cross-shard k-way merge, parallel over the batch's query rows
    let t0 = Instant::now();
    let merged = merge_query_rows(&shard_accs, nq, l, corpus.engine_params().threads);
    let merge_time = t0.elapsed();

    let results = merged
        .into_iter()
        .enumerate()
        .map(|(q, acc)| {
            let hits = acc.into_sorted();
            let labels = hits.iter().map(|&(_, id)| corpus.label(id)).collect();
            ShardedSearch {
                hits,
                labels,
                candidates: candidates[q],
                lists_probed: lists_probed[q],
                pruned: pruned_any,
            }
        })
        .collect();
    Ok(ShardedBatch { results, merge_time, fanout_time, shard_times })
}

/// Single-query convenience wrapper around [`search_batch`].
pub fn search(
    corpus: &ShardedCorpus,
    query: &Histogram,
    method: Method,
    l: usize,
    nprobe: Option<usize>,
) -> EmdResult<ShardedSearch> {
    let mut out = search_batch(corpus, std::slice::from_ref(query), method, l, nprobe)?;
    Ok(out.results.pop().expect("one query in, one result out"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexParams, ShardParams};
    use crate::data::{generate_text, TextConfig};
    use crate::lc::{EngineParams, LcEngine};
    use std::sync::Arc;

    fn setup(shards: usize, index: bool) -> (Arc<crate::core::Dataset>, ShardedCorpus) {
        let ds = Arc::new(generate_text(&TextConfig {
            n: 60,
            classes: 4,
            vocab: 250,
            dim: 10,
            doc_len: 25,
            seed: 23,
            ..Default::default()
        }));
        let ixp =
            IndexParams { nlist: 5, nprobe: 2, train_iters: 6, seed: 3, min_points_per_list: 1 };
        let corpus = ShardedCorpus::build(
            &ds,
            ShardParams { shards, max_docs_per_shard: 1 << 20 },
            EngineParams { threads: 2, ..Default::default() },
            index.then_some(&ixp),
        )
        .unwrap();
        (ds, corpus)
    }

    #[test]
    fn exhaustive_fanout_matches_monolithic_topl() {
        let (ds, corpus) = setup(3, false);
        let eng =
            LcEngine::new(Arc::clone(&ds), EngineParams { threads: 2, ..Default::default() });
        let queries: Vec<Histogram> = (0..4).map(|u| ds.histogram(u * 7)).collect();
        for method in [Method::Rwmd, Method::Act { k: 2 }, Method::Wcd] {
            let batch = search_batch(&corpus, &queries, method, 6, None).unwrap();
            assert!(!batch.results[0].pruned);
            for (q, res) in queries.iter().zip(&batch.results) {
                let row = eng.distances(q, method);
                let mut want = TopL::new(6);
                want.push_slice(&row, 0);
                assert_eq!(res.hits, want.into_sorted(), "{method}");
                assert_eq!(res.candidates, ds.len());
            }
        }
    }

    #[test]
    fn full_probe_equals_exhaustive_per_shard() {
        let (_, corpus) = setup(3, true);
        let queries: Vec<Histogram> = (0..3).map(|u| corpus.histogram(u * 11)).collect();
        let exhaustive =
            search_batch(&corpus, &queries, Method::Rwmd, 5, Some(usize::MAX >> 1)).unwrap();
        let (_, plain) = setup(3, false);
        let want = search_batch(&plain, &queries, Method::Rwmd, 5, None).unwrap();
        for (a, b) in exhaustive.results.iter().zip(&want.results) {
            assert_eq!(a.hits, b.hits);
        }
    }

    #[test]
    fn pruned_fanout_scores_fewer_candidates_and_finds_self() {
        let (ds, corpus) = setup(3, true);
        let q = ds.histogram(12);
        let res = search(&corpus, &q, Method::Rwmd, 5, Some(1)).unwrap();
        assert!(res.pruned);
        assert!(res.candidates < ds.len(), "nprobe 1 must prune somewhere");
        assert!(res.lists_probed >= corpus.num_shards());
        assert_eq!(res.hits[0].1, 12, "a database query finds itself");
        assert!(res.hits[0].0.abs() < 1e-5);
        assert_eq!(res.labels[0], ds.labels[12]);
    }

    #[test]
    fn parallel_fanout_is_bit_identical_to_serial() {
        for index in [false, true] {
            let (_, corpus) = setup(4, index);
            let queries: Vec<Histogram> = (0..5).map(|u| corpus.histogram(u * 9)).collect();
            for nprobe in [None, Some(1), Some(3)] {
                let serial = search_batch_budgeted(
                    &corpus, &queries, Method::Act { k: 2 }, 6, nprobe, Some(1),
                )
                .unwrap();
                for width in [Some(2), Some(4), Some(64), None] {
                    let par = search_batch_budgeted(
                        &corpus, &queries, Method::Act { k: 2 }, 6, nprobe, width,
                    )
                    .unwrap();
                    for (a, b) in serial.results.iter().zip(&par.results) {
                        assert_eq!(a.hits, b.hits, "index={index} nprobe={nprobe:?}");
                        assert_eq!(a.labels, b.labels);
                        assert_eq!(a.candidates, b.candidates);
                        assert_eq!(a.lists_probed, b.lists_probed);
                    }
                }
            }
        }
    }

    #[test]
    fn shard_datasets_share_one_embedding_table() {
        // Arc<Embeddings> sharing: building S shards must not clone the
        // (v, m) coordinate matrix per shard
        let (ds, corpus) = setup(4, true);
        assert!(corpus.embeddings().shares_storage(&ds.embeddings));
        for shard in corpus.shards() {
            assert!(
                shard.dataset().embeddings.shares_storage(&ds.embeddings),
                "shard dataset must reference the corpus embedding table"
            );
        }
    }

    #[test]
    fn empty_corpus_returns_empty_hits() {
        let (ds, _) = setup(1, false);
        let empty = ShardedCorpus::build(
            &crate::core::Dataset::new("none", ds.embeddings.clone(), &[], Vec::new()),
            ShardParams { shards: 2, max_docs_per_shard: 10 },
            EngineParams { threads: 1, ..Default::default() },
            None,
        )
        .unwrap();
        let res = search(&empty, &ds.histogram(0), Method::Rwmd, 4, None).unwrap();
        assert!(res.hits.is_empty());
        assert_eq!(res.candidates, 0);
    }
}
