//! Sharded live-corpus subsystem: per-shard engines + IVF indexes behind a
//! fan-out / top-ℓ-merge route, with incremental ingestion.
//!
//! The monolithic serving stack owns one engine and (optionally) one IVF
//! index over one immutable corpus; any change means a full retrain.  This
//! subsystem turns that corpus into `S` independently-owned shards — the
//! partition-local-search-plus-cheap-merge shape the sublinear-EMD
//! literature argues for (Do Ba et al., *Sublinear Time Algorithms for
//! Earth Mover's Distance*; Ding et al., *Querying EMD with Low Doubling
//! Dimensions*) — and makes the corpus **appendable at runtime**:
//!
//! * [`corpus`] — [`ShardedCorpus`] / [`Shard`]: per-shard CSR slices,
//!   [`crate::lc::LcEngine`]s and shard-locally-trained
//!   [`crate::index::IvfIndex`]es, with the
//!   [`crate::coordinator::Router`]-derived global-id ↔ (shard, local-id)
//!   mapping and the smallest-shard / fresh-shard append policy
//!   ([`ShardedCorpus::append`] assigns new documents to already-trained
//!   centroids — no retraining).
//! * [`search`] — [`search_batch`]: fan the batch out, probe each shard's
//!   IVF lists locally, score through the bit-identical
//!   [`crate::lc::LcEngine::distances_batch_subset`] pipeline, and
//!   k-way-merge per-shard top-ℓ accumulators
//!   ([`crate::coordinator::topl::merge_query_rows`], parallel over query
//!   rows).  `nprobe >= nlist` on every shard reproduces monolithic
//!   exhaustive `search_batch` bit-identically.
//! * [`manifest`] — the `EMDX` **version 2** sidecar: per-shard layout +
//!   index + doc counts, so a restarted server reloads the same live
//!   corpus (stale fingerprints and wrong versions rejected before
//!   allocation).
//! * [`segments`] — the `EMDX` **version 3** append segment: `add_docs`
//!   persistence appends one `O(batch)` segment file instead of rewriting
//!   the whole `EMD1` dataset; a restarted node replays the segment chain
//!   through the deterministic append placement.
//!
//! The coordinator ([`crate::coordinator::SearchEngine`]) routes through a
//! [`ShardedCorpus`] when [`crate::config::Config::sharded`] is set, exposes
//! appends as `add_docs` (API + TCP protocol), and persists the layout next
//! to file-backed datasets.

pub mod corpus;
pub mod manifest;
pub mod search;
pub mod segments;

pub use corpus::{AppendOutcome, DocView, Shard, ShardStat, ShardedCorpus};
pub use manifest::{
    load_manifest, load_manifest_for, reconstruct, save_manifest, Manifest, ManifestShard,
    MANIFEST_VERSION,
};
pub use search::{search, search_batch, search_batch_budgeted, ShardedBatch, ShardedSearch};
pub use segments::{
    append_segment, clear_segments, list_segments, load_segment, replay_segments, segments_dir,
    Segment, SEGMENT_VERSION,
};
