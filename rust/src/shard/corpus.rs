//! The sharded live corpus: per-shard CSR slices, engines and IVF indexes,
//! plus the incremental-append path.
//!
//! A [`ShardedCorpus`] owns `S` [`Shard`]s.  At build time the document
//! space is partitioned contiguously by [`crate::coordinator::Router`]
//! (shard `s` owns global ids `boundaries[s]..boundaries[s+1]`, local id =
//! global − base), so the router *is* the initial global-id ↔ (shard,
//! local-id) mapping.  Appends extend that mapping explicitly: every new
//! document gets the next global id and joins the smallest shard (or a
//! fresh shard once every shard has reached
//! [`crate::config::ShardParams::max_docs_per_shard`]), so each shard's
//! global-id list stays strictly ascending — the invariant that keeps
//! shard-local top-ℓ tie-breaks identical to global ones.
//!
//! Each shard wraps its own [`LcEngine`] (per-shard BoW norms, WCD
//! centroids, vocabulary norms) and, when index parameters are configured,
//! its own shard-locally-trained [`IvfIndex`].  Appended documents are
//! assigned to the shard's **already-trained** centroids via
//! [`IvfIndex::append_assigned`] — no retraining on the append path; only
//! the receiving shard rebuilds its `O(shard)` engine precomputations.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{IndexParams, ShardParams};
use crate::core::{CsrMatrix, Dataset, Embeddings, EmdResult, Histogram};
use crate::coordinator::Router;
use crate::emd_ensure;
use crate::index::{dataset_fingerprint, IvfIndex};
use crate::lc::{EngineParams, LcEngine};

/// Incremental CSR + label assembly shared by the gather / extend /
/// reassemble paths: every row is copied bit-exactly, so datasets built
/// here sweep identically to the rows' original home.
struct RowBuilder {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f32>,
    labels: Vec<u16>,
}

impl RowBuilder {
    fn with_capacity(rows: usize) -> RowBuilder {
        RowBuilder {
            indptr: {
                let mut p = Vec::with_capacity(rows + 1);
                p.push(0);
                p
            },
            indices: Vec::new(),
            data: Vec::new(),
            labels: Vec::with_capacity(rows),
        }
    }

    fn push_row(&mut self, indices: &[u32], weights: &[f32], label: u16) {
        self.indices.extend_from_slice(indices);
        self.data.extend_from_slice(weights);
        self.indptr.push(self.indices.len());
        self.labels.push(label);
    }

    fn into_dataset(self, name: impl Into<String>, embeddings: &Embeddings) -> Dataset {
        let matrix =
            CsrMatrix::from_raw(self.indptr, self.indices, self.data, embeddings.num_vectors());
        Dataset::from_csr(name, embeddings.clone(), matrix, self.labels)
    }
}

/// One shard: a contiguous-at-build (append-extended) slice of the corpus
/// with its own engine and optional IVF index.
#[derive(Clone)]
pub struct Shard {
    /// Global ids owned by this shard, strictly ascending; the local id of
    /// a document is its position in this list.
    globals: Vec<u32>,
    /// Shard-local dataset (rows copied bit-exactly from the corpus).
    dataset: Arc<Dataset>,
    /// Shard-local engine over `dataset`.
    engine: Arc<LcEngine>,
    /// Shard-local IVF index (trained on this shard's WCD centroids).
    index: Option<IvfIndex>,
    /// Documents appended after the shard was built (skew reporting).
    appended: usize,
}

/// Per-shard shape snapshot (server `stats`, CLI `shard info`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStat {
    pub docs: usize,
    pub appended: usize,
    /// Trained list count (`None` = exhaustive shard).
    pub nlist: Option<usize>,
    pub min_list: usize,
    pub max_list: usize,
}

/// Outcome of one append batch.
#[derive(Debug, Clone)]
pub struct AppendOutcome {
    /// Global ids assigned to the appended documents, in input order.
    pub ids: Vec<usize>,
    /// Shards that received documents (ascending shard ids).
    pub touched: Vec<usize>,
    /// Fresh shards opened by this batch.
    pub opened: usize,
}

impl Shard {
    /// Build a shard over `globals`' rows of `corpus`, training a local IVF
    /// index when `index_params` is set.  `engine_params` is the shard's
    /// *serving* budget; `build_threads` is the (full) pool available to
    /// the serial construction path — precompute and index training run on
    /// it so building S shards never idles (S-1)/S of the machine.
    fn build(
        corpus: &Dataset,
        globals: Vec<u32>,
        ordinal: usize,
        engine_params: EngineParams,
        build_threads: usize,
        index_params: Option<&IndexParams>,
    ) -> EmdResult<Shard> {
        let name = format!("{}/shard{}", corpus.name, ordinal);
        let dataset = Arc::new(gather_rows(corpus, &globals, name));
        Shard::from_dataset(dataset, globals, 0, engine_params, build_threads, index_params)
    }

    /// Assemble a shard around an already-gathered dataset, training the
    /// index from scratch (on `build_threads`; see [`Shard::build`]).
    fn from_dataset(
        dataset: Arc<Dataset>,
        globals: Vec<u32>,
        appended: usize,
        engine_params: EngineParams,
        build_threads: usize,
        index_params: Option<&IndexParams>,
    ) -> EmdResult<Shard> {
        debug_assert_eq!(dataset.len(), globals.len());
        let engine = Arc::new(LcEngine::with_precompute_threads(
            Arc::clone(&dataset),
            engine_params,
            build_threads,
        ));
        let index = match index_params {
            Some(p) if !dataset.is_empty() => Some(IvfIndex::train(
                engine.wcd_centroids(),
                dataset.embeddings.dim(),
                p,
                build_threads,
                dataset_fingerprint(&dataset),
            )?),
            _ => None,
        };
        Ok(Shard { globals, dataset, engine, index, appended })
    }

    /// Reassemble a shard from persisted parts (the manifest loader): the
    /// index, when present, must already be validated against `dataset`.
    pub(crate) fn from_parts(
        dataset: Arc<Dataset>,
        globals: Vec<u32>,
        appended: usize,
        index: Option<IvfIndex>,
        engine_params: EngineParams,
        build_threads: usize,
    ) -> Shard {
        debug_assert_eq!(dataset.len(), globals.len());
        let engine = Arc::new(LcEngine::with_precompute_threads(
            Arc::clone(&dataset),
            engine_params,
            build_threads,
        ));
        Shard { globals, dataset, engine, index, appended }
    }

    /// Append a batch of (global id, L1-normalized histogram, label) rows:
    /// the shard dataset and engine are rebuilt with the new rows (old rows
    /// bit-exact), and each new document joins the already-trained index
    /// via [`IvfIndex::append_assigned`] — no retraining.  The rebuild runs
    /// on `build_threads` (the append path is serial, behind the write
    /// lock); the stored engine serves on `engine_params`.
    fn extend(
        &mut self,
        batch: &[(u32, Histogram, u16)],
        engine_params: EngineParams,
        build_threads: usize,
    ) {
        let old = Arc::clone(&self.dataset);
        let mut rows = RowBuilder::with_capacity(old.len() + batch.len());
        for u in 0..old.len() {
            let (idx, w) = old.matrix.row(u);
            rows.push_row(idx, w, old.labels[u]);
        }
        for (_, h, label) in batch {
            rows.push_row(h.indices(), h.weights(), *label);
        }
        let dataset = Arc::new(rows.into_dataset(old.name.clone(), &old.embeddings));
        let engine = Arc::new(LcEngine::with_precompute_threads(
            Arc::clone(&dataset),
            engine_params,
            build_threads,
        ));
        if let Some(ix) = &mut self.index {
            // assign to the trained centroids using the same per-row WCD
            // centroid representation the original members were indexed by
            let m = dataset.embeddings.dim();
            let cents = engine.wcd_centroids();
            for local in old.len()..dataset.len() {
                ix.append_assigned(&cents[local * m..(local + 1) * m]);
            }
            ix.set_fingerprint(dataset_fingerprint(&dataset));
        }
        self.globals.extend(batch.iter().map(|&(g, _, _)| g));
        self.appended += batch.len();
        self.dataset = dataset;
        self.engine = engine;
    }

    pub fn len(&self) -> usize {
        self.globals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.globals.is_empty()
    }

    /// Global ids owned by this shard, strictly ascending.
    pub fn globals(&self) -> &[u32] {
        &self.globals
    }

    /// The global id of shard-local row `local`.
    #[inline]
    pub fn global(&self, local: usize) -> usize {
        self.globals[local] as usize
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.dataset
    }

    pub fn engine(&self) -> &LcEngine {
        &self.engine
    }

    pub fn index(&self) -> Option<&IvfIndex> {
        self.index.as_ref()
    }

    /// Documents appended since the shard was built.
    pub fn appended(&self) -> usize {
        self.appended
    }

    pub fn stat(&self) -> ShardStat {
        let (nlist, min_list, max_list) = match &self.index {
            Some(ix) => {
                let sizes = ix.list_sizes();
                (
                    Some(ix.nlist()),
                    sizes.iter().copied().min().unwrap_or(0),
                    sizes.iter().copied().max().unwrap_or(0),
                )
            }
            None => (None, 0, 0),
        };
        ShardStat { docs: self.len(), appended: self.appended, nlist, min_list, max_list }
    }
}

/// The per-shard engine thread budget for a corpus of `shards` shards under
/// a `total` budget: the parallel fan-out runs up to `min(shards, threads)`
/// shards concurrently, so each shard's engine gets an even share of the
/// pool instead of the full budget (which would oversubscribe the machine
/// `S`-fold).  Thread count never changes results — every kernel is
/// bit-identical across thread counts — so this is purely a scheduling
/// decision.
pub(crate) fn shard_engine_params(total: EngineParams, shards: usize) -> EngineParams {
    let fanout = shards.max(1).min(total.threads.max(1));
    EngineParams { threads: (total.threads / fanout).max(1), ..total }
}

/// The sharded, appendable corpus (see module docs).
#[derive(Clone)]
pub struct ShardedCorpus {
    /// Shared vocabulary coordinates (every shard dataset shares one
    /// reference-counted embedding table; this handle serves append
    /// validation and reassembly).
    embeddings: Embeddings,
    shards: Vec<Shard>,
    /// Global id → (shard, local id); the inverse of the shards' `globals`
    /// lists.
    assign: Vec<(u32, u32)>,
    params: ShardParams,
    /// Total thread budget (fan-out width + cross-shard merge).
    engine_params: EngineParams,
    /// Per-shard engine budget ([`shard_engine_params`]); appended/fresh
    /// shards build their engines with this too.
    shard_engine: EngineParams,
    index_params: Option<IndexParams>,
}

impl ShardedCorpus {
    /// Partition `dataset` into `params.shards` contiguous shards (via
    /// [`Router`]) and build each shard's engine + optional IVF index.
    pub fn build(
        dataset: &Dataset,
        params: ShardParams,
        engine_params: EngineParams,
        index_params: Option<&IndexParams>,
    ) -> EmdResult<ShardedCorpus> {
        emd_ensure!(params.shards >= 1, config, "shard count must be >= 1");
        emd_ensure!(params.max_docs_per_shard >= 1, config, "max_docs_per_shard must be >= 1");
        let router = Router::new(dataset.len(), params.shards);
        // serving budget per shard from the actual shard count (matches
        // what from_parts / manifest reconstruct compute for a reload)
        let shard_engine = shard_engine_params(engine_params, router.num_shards().max(1));
        let mut shards = Vec::with_capacity(router.num_shards());
        let mut assign = Vec::with_capacity(dataset.len());
        for (s, range) in router.shards().enumerate() {
            let globals: Vec<u32> = (range.start as u32..range.end as u32).collect();
            for local in 0..globals.len() {
                assign.push((s as u32, local as u32));
            }
            shards.push(Shard::build(
                dataset,
                globals,
                s,
                shard_engine,
                engine_params.threads,
                index_params,
            )?);
        }
        Ok(ShardedCorpus {
            embeddings: dataset.embeddings.clone(),
            shards,
            assign,
            params,
            engine_params,
            shard_engine,
            index_params: index_params.copied(),
        })
    }

    /// Reassemble a corpus from persisted parts (the manifest loader).
    pub(crate) fn from_parts(
        embeddings: Embeddings,
        shards: Vec<Shard>,
        params: ShardParams,
        engine_params: EngineParams,
        index_params: Option<IndexParams>,
    ) -> EmdResult<ShardedCorpus> {
        let total: usize = shards.iter().map(Shard::len).sum();
        let mut assign = vec![(u32::MAX, u32::MAX); total];
        for (s, shard) in shards.iter().enumerate() {
            emd_ensure!(
                shard.globals.windows(2).all(|w| w[0] < w[1]),
                config,
                "shard {s} global ids are not strictly ascending"
            );
            for (local, &g) in shard.globals.iter().enumerate() {
                emd_ensure!(
                    (g as usize) < total,
                    config,
                    "shard {s} owns global id {g} but the corpus has {total} docs"
                );
                emd_ensure!(
                    assign[g as usize] == (u32::MAX, u32::MAX),
                    config,
                    "global id {g} appears in more than one shard"
                );
                assign[g as usize] = (s as u32, local as u32);
            }
        }
        let shard_engine = shard_engine_params(engine_params, shards.len().max(1));
        Ok(ShardedCorpus {
            embeddings,
            shards,
            assign,
            params,
            engine_params,
            shard_engine,
            index_params,
        })
    }

    /// Documents currently searchable.
    pub fn len(&self) -> usize {
        self.assign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assign.is_empty()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn params(&self) -> &ShardParams {
        &self.params
    }

    pub fn engine_params(&self) -> &EngineParams {
        &self.engine_params
    }

    pub fn index_params(&self) -> Option<&IndexParams> {
        self.index_params.as_ref()
    }

    pub fn embeddings(&self) -> &Embeddings {
        &self.embeddings
    }

    /// Where global id `g` lives: `(shard, local id)`.
    pub fn locate(&self, g: usize) -> (usize, usize) {
        let (s, local) = self.assign[g];
        (s as usize, local as usize)
    }

    /// The label of global document `g`.
    pub fn label(&self, g: usize) -> u16 {
        let (s, local) = self.locate(g);
        self.shards[s].dataset.labels[local]
    }

    /// The histogram of global document `g` (owned copy).
    pub fn histogram(&self, g: usize) -> Histogram {
        let (s, local) = self.locate(g);
        self.shards[s].dataset.histogram(local)
    }

    /// A lock-free document resolver snapshotted from the corpus: the
    /// shard datasets are `Arc`-shared, so this copies O(n) id mappings and
    /// S dataset handles — not the data.  Long-running readers (e.g. the
    /// cascade rerank stage) resolve documents through the snapshot instead
    /// of holding the corpus lock, so concurrent appends are never stalled;
    /// ids resolved through it stay valid because appends only add ids.
    pub fn doc_view(&self) -> DocView {
        DocView {
            assign: self.assign.clone(),
            datasets: self.shards.iter().map(|s| Arc::clone(&s.dataset)).collect(),
        }
    }

    /// The widest trained list count across shards (`None` when no shard
    /// carries an index) — the clamp for effective probe widths.
    pub fn max_nlist(&self) -> Option<usize> {
        self.shards.iter().filter_map(|s| s.index.as_ref().map(IvfIndex::nlist)).max()
    }

    /// Resolve a request's probe width: `None` when no shard carries an
    /// index (always exhaustive); otherwise `requested`, falling back to
    /// `default`, clamped to `[1, max shard nlist]`.  Shards with fewer
    /// lists clamp further at probe time, so `nprobe >= nlist` on every
    /// shard is the exhaustive (bit-identical) route.
    pub fn effective_nprobe(
        &self,
        requested: Option<usize>,
        default: Option<usize>,
    ) -> Option<usize> {
        let cap = self.max_nlist()?;
        Some(requested.or(default).unwrap_or(1).clamp(1, cap))
    }

    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.shards.iter().map(Shard::stat).collect()
    }

    /// Append documents to the live corpus.  Each document is L1-normalized
    /// (matching how built corpora normalize rows), lands in the smallest
    /// shard — or a fresh shard once every shard holds
    /// [`ShardParams::max_docs_per_shard`] documents — and joins that
    /// shard's already-trained IVF centroids without retraining.  `labels`
    /// may be empty (label 0) or one per document.
    pub fn append(&mut self, docs: &[Histogram], labels: &[u16]) -> EmdResult<AppendOutcome> {
        emd_ensure!(!docs.is_empty(), config, "append needs at least one document");
        emd_ensure!(
            labels.is_empty() || labels.len() == docs.len(),
            config,
            "append got {} labels for {} documents",
            labels.len(),
            docs.len()
        );
        let v = self.embeddings.num_vectors();
        for (i, d) in docs.iter().enumerate() {
            emd_ensure!(!d.is_empty(), config, "appended document {i} is empty");
            emd_ensure!(
                d.min_vocab_size() <= v,
                config,
                "appended document {i} indexes vocabulary entry {} but the corpus \
                 vocabulary has {v}",
                d.min_vocab_size() - 1
            );
        }

        // place every document against simulated sizes so a batch that
        // crosses the fresh-shard threshold splits deterministically
        let max_docs = self.params.max_docs_per_shard.max(1);
        let mut sizes: Vec<usize> = self.shards.iter().map(Shard::len).collect();
        let mut per_target: BTreeMap<usize, Vec<(u32, Histogram, u16)>> = BTreeMap::new();
        let mut ids = Vec::with_capacity(docs.len());
        let mut opened = 0usize;
        let mut next_global = self.assign.len();
        for (i, doc) in docs.iter().enumerate() {
            let label = labels.get(i).copied().unwrap_or(0);
            let smallest = sizes.iter().enumerate().min_by_key(|&(s, &n)| (n, s)).map(|(s, _)| s);
            let target = match smallest {
                Some(s) if sizes[s] < max_docs => s,
                _ => {
                    sizes.push(0);
                    opened += 1;
                    sizes.len() - 1
                }
            };
            sizes[target] += 1;
            per_target
                .entry(target)
                .or_default()
                .push((next_global as u32, doc.normalized(), label));
            ids.push(next_global);
            next_global += 1;
        }

        self.assign.resize(next_global, (u32::MAX, u32::MAX));
        let mut touched = Vec::with_capacity(per_target.len());
        for (target, batch) in per_target {
            let base_local;
            if target < self.shards.len() {
                base_local = self.shards[target].len();
                self.shards[target].extend(
                    &batch,
                    self.shard_engine,
                    self.engine_params.threads,
                );
            } else {
                debug_assert_eq!(target, self.shards.len(), "fresh shards open densely");
                base_local = 0;
                let globals: Vec<u32> = batch.iter().map(|&(g, _, _)| g).collect();
                let mut rows = RowBuilder::with_capacity(batch.len());
                for (_, h, label) in &batch {
                    rows.push_row(h.indices(), h.weights(), *label);
                }
                let name = format!("live/shard{target}");
                let dataset = Arc::new(rows.into_dataset(name, &self.embeddings));
                self.shards.push(Shard::from_dataset(
                    dataset,
                    globals,
                    batch.len(),
                    self.shard_engine,
                    self.engine_params.threads,
                    self.index_params.as_ref(),
                )?);
            }
            for (j, &(g, _, _)) in batch.iter().enumerate() {
                self.assign[g as usize] = (target as u32, (base_local + j) as u32);
            }
            touched.push(target);
        }
        Ok(AppendOutcome { ids, touched, opened })
    }

    /// Reassemble the whole corpus as one dataset in global-id order
    /// (persistence: the `EMD1` file a restarted server reloads).  Rows are
    /// copied bit-exactly from the shard slices.
    pub fn to_dataset(&self, name: impl Into<String>) -> Dataset {
        let mut rows = RowBuilder::with_capacity(self.len());
        for &(s, local) in &self.assign {
            let ds = &self.shards[s as usize].dataset;
            let (idx, w) = ds.matrix.row(local as usize);
            rows.push_row(idx, w, ds.labels[local as usize]);
        }
        rows.into_dataset(name, &self.embeddings)
    }
}

/// A lock-free snapshot of the corpus' global-id → document mapping
/// ([`ShardedCorpus::doc_view`]).
#[derive(Clone)]
pub struct DocView {
    assign: Vec<(u32, u32)>,
    datasets: Vec<Arc<Dataset>>,
}

impl DocView {
    pub fn len(&self) -> usize {
        self.assign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assign.is_empty()
    }

    /// The histogram of global document `g` (owned copy, bit-exact).
    pub fn histogram(&self, g: usize) -> Histogram {
        let (s, local) = self.assign[g];
        self.datasets[s as usize].histogram(local as usize)
    }

    /// The label of global document `g`.
    pub fn label(&self, g: usize) -> u16 {
        let (s, local) = self.assign[g];
        self.datasets[s as usize].labels[local as usize]
    }
}

/// Gather `globals`' rows of `corpus` into a standalone dataset (weights
/// copied verbatim, so shard-local sweeps are bit-identical to the
/// corresponding rows of a monolithic sweep).  Shared with the manifest
/// loader, which re-gathers shard datasets from the persisted layout.
pub(crate) fn gather_rows(corpus: &Dataset, globals: &[u32], name: String) -> Dataset {
    let mut rows = RowBuilder::with_capacity(globals.len());
    for &g in globals {
        let (idx, w) = corpus.matrix.row(g as usize);
        rows.push_row(idx, w, corpus.labels[g as usize]);
    }
    rows.into_dataset(name, &corpus.embeddings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_text, TextConfig};

    fn corpus_dataset(n: usize) -> Dataset {
        generate_text(&TextConfig {
            n,
            classes: 3,
            vocab: 200,
            dim: 8,
            doc_len: 20,
            seed: 17,
            ..Default::default()
        })
    }

    fn params(shards: usize, max_docs: usize) -> ShardParams {
        ShardParams { shards, max_docs_per_shard: max_docs }
    }

    fn engine_params() -> EngineParams {
        EngineParams { threads: 2, ..Default::default() }
    }

    fn index_params() -> IndexParams {
        IndexParams { nlist: 4, nprobe: 2, train_iters: 6, seed: 3, min_points_per_list: 1 }
    }

    #[test]
    fn build_partitions_contiguously() {
        let ds = corpus_dataset(25);
        let c = ShardedCorpus::build(&ds, params(4, 1000), engine_params(), None).unwrap();
        assert_eq!(c.len(), 25);
        assert_eq!(c.num_shards(), 4);
        let mut seen = Vec::new();
        for shard in c.shards() {
            assert!(shard.globals().windows(2).all(|w| w[0] < w[1]));
            seen.extend_from_slice(shard.globals());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..25u32).collect::<Vec<_>>());
        // locate is the exact inverse of the shard globals lists
        for g in 0..25 {
            let (s, local) = c.locate(g);
            assert_eq!(c.shards()[s].global(local), g);
            assert_eq!(c.label(g), ds.labels[g]);
        }
        // shard rows are bit-exact copies of the corpus rows
        for g in 0..25 {
            let (s, local) = c.locate(g);
            let (gi, gw) = ds.matrix.row(g);
            let (si, sw) = c.shards()[s].dataset().matrix.row(local);
            assert_eq!(gi, si);
            assert_eq!(gw, sw);
        }
        // reassembly round-trips bit-exactly
        let back = c.to_dataset("roundtrip");
        assert_eq!(back.matrix, ds.matrix);
        assert_eq!(back.labels, ds.labels);
    }

    #[test]
    fn append_lands_in_smallest_then_opens_fresh_shard() {
        let ds = corpus_dataset(20);
        let mut c =
            ShardedCorpus::build(&ds, params(2, 11), engine_params(), Some(&index_params()))
                .unwrap();
        assert_eq!(c.shards()[0].len(), 10);
        assert_eq!(c.shards()[1].len(), 10);
        let extra: Vec<Histogram> = (0..5).map(|u| ds.histogram(u)).collect();
        let out = c.append(&extra[..2], &[7, 8]).unwrap();
        assert_eq!(out.ids, vec![20, 21]);
        assert_eq!(out.opened, 0);
        // smallest-first with low-id tie-break: one doc per shard
        assert_eq!(c.shards()[0].len(), 11);
        assert_eq!(c.shards()[1].len(), 11);
        assert_eq!(c.label(20), 7);
        assert_eq!(c.label(21), 8);
        // both shards are now at max_docs_per_shard = 11: the next append
        // opens a fresh shard and fills it
        let out = c.append(&extra[2..], &[1, 2, 3]).unwrap();
        assert_eq!(out.ids, vec![22, 23, 24]);
        assert_eq!(out.opened, 1);
        assert_eq!(c.num_shards(), 3);
        assert_eq!(c.shards()[2].len(), 3);
        assert_eq!(c.shards()[2].appended(), 3);
        // the fresh shard trains its own index; old shards assigned
        // incrementally (num_points grew without retraining)
        assert!(c.shards()[2].index().is_some());
        assert_eq!(c.shards()[0].index().unwrap().num_points(), 11);
        // the mapping stays a bijection
        let mut seen: Vec<usize> = (0..c.len())
            .map(|g| {
                let (s, local) = c.locate(g);
                c.shards()[s].global(local)
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn append_rejects_bad_input() {
        let ds = corpus_dataset(10);
        let mut c = ShardedCorpus::build(&ds, params(2, 100), engine_params(), None).unwrap();
        assert!(c.append(&[], &[]).is_err());
        let h = ds.histogram(0);
        assert!(c.append(std::slice::from_ref(&h), &[1, 2]).is_err());
        let oob = Histogram::from_pairs(vec![(10_000, 1.0)]);
        assert!(c.append(&[oob], &[]).is_err());
        let empty = Histogram::from_pairs(vec![]);
        assert!(c.append(&[empty], &[]).is_err());
    }

    #[test]
    fn empty_corpus_grows_from_zero_shards() {
        let ds = corpus_dataset(8);
        // an empty slice of the dataset: zero shards (Router regression)
        let empty = gather_rows(&ds, &[], "empty".into());
        let mut c =
            ShardedCorpus::build(&empty, params(3, 4), engine_params(), Some(&index_params()))
                .unwrap();
        assert_eq!(c.num_shards(), 0);
        assert_eq!(c.len(), 0);
        let docs: Vec<Histogram> = (0..6).map(|u| ds.histogram(u)).collect();
        let out = c.append(&docs, &[]).unwrap();
        assert_eq!(out.opened, 2, "6 docs at 4 per shard need two fresh shards");
        assert_eq!(c.len(), 6);
        assert_eq!(c.num_shards(), 2);
    }
}
