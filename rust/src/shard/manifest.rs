//! Persistence for the sharded live corpus: the `EMDX` **version 2**
//! sidecar (the shard manifest), extending the version-1 single-index
//! format of [`crate::index::persist`] with the shard layout.
//!
//! Format (little-endian):
//! ```text
//! magic "EMDX" | version u32 = 2
//! corpus_fingerprint u64    (dataset_fingerprint of the full corpus file)
//! max_docs_per_shard u64    (append policy the corpus was running)
//! num_shards u64
//! per shard:
//!   doc_count u64
//!   globals u32[doc_count]  (strictly ascending global ids)
//!   appended u64
//!   has_index u8
//!   [index body]            (the shared v1 body: fingerprint, dims, tables)
//! ```
//! The manifest lives at the dataset's conventional sidecar path
//! ([`crate::index::sidecar_path`]); version 1 and version 2 sidecars
//! reject each other cleanly at load, so a config switch between the
//! monolithic index and the sharded corpus falls back to a rebuild instead
//! of misreading the file.  Like the v1 loader, every header-implied size
//! is validated against the remaining file length **before any allocation
//! is sized from it**, and the embedded corpus fingerprint ties the
//! manifest to the exact dataset bytes it describes.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::config::{IndexParams, ShardParams};
use crate::core::{Dataset, EmdError, EmdResult};
use crate::emd_ensure;
use crate::index::persist::{read_body, write_body};
use crate::index::{dataset_fingerprint, IvfIndex};
use crate::lc::EngineParams;

use super::corpus::{gather_rows, Shard, ShardedCorpus};

const MAGIC: &[u8; 4] = b"EMDX";
/// The shard-manifest version of the `EMDX` sidecar family (version 1 is
/// the single-index sidecar).
pub const MANIFEST_VERSION: u32 = 2;

/// A loaded (not yet reconstructed) shard manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Fingerprint of the full corpus dataset this layout describes.
    pub corpus_fingerprint: u64,
    /// Append policy the corpus was running when persisted.
    pub max_docs_per_shard: usize,
    pub shards: Vec<ManifestShard>,
}

/// One shard's persisted layout.
#[derive(Debug, Clone)]
pub struct ManifestShard {
    /// Global ids owned by the shard, strictly ascending.
    pub globals: Vec<u32>,
    /// Documents appended to the shard since it was built.
    pub appended: usize,
    /// The shard's trained IVF index, when it had one.
    pub index: Option<IvfIndex>,
}

impl Manifest {
    /// Total documents across shards.
    pub fn num_docs(&self) -> usize {
        self.shards.iter().map(|s| s.globals.len()).sum()
    }
}

/// Save a corpus' layout.  `corpus_fingerprint` must be the
/// [`dataset_fingerprint`] of the corpus dataset **as persisted** (the
/// `EMD1` file a restarted server reloads next to this manifest).
pub fn save_manifest(
    corpus: &ShardedCorpus,
    corpus_fingerprint: u64,
    path: &Path,
) -> EmdResult<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&MANIFEST_VERSION.to_le_bytes())?;
    w.write_all(&corpus_fingerprint.to_le_bytes())?;
    w.write_all(&(corpus.params().max_docs_per_shard as u64).to_le_bytes())?;
    w.write_all(&(corpus.num_shards() as u64).to_le_bytes())?;
    for shard in corpus.shards() {
        w.write_all(&(shard.len() as u64).to_le_bytes())?;
        for &g in shard.globals() {
            w.write_all(&g.to_le_bytes())?;
        }
        w.write_all(&(shard.appended() as u64).to_le_bytes())?;
        match shard.index() {
            Some(ix) => {
                w.write_all(&[1u8])?;
                write_body(&mut w, ix)?;
            }
            None => w.write_all(&[0u8])?,
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a manifest without checking what dataset it belongs to (inspection
/// use; serving paths should use [`load_manifest_for`]).
pub fn load_manifest(path: &Path) -> EmdResult<Manifest> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "bad magic (not an EMDX file)",
        )
        .into());
    }
    let version = read_u32(&mut r)?;
    if version != MANIFEST_VERSION {
        return Err(EmdError::config(format!(
            "unsupported EMDX version {version} (expected {MANIFEST_VERSION}; version 1 is \
             the single-index sidecar, see `emdpar index`)"
        )));
    }
    let mut remaining = file_len.saturating_sub(8); // magic + version consumed
    take(&mut remaining, 24, "manifest header", path)?;
    let corpus_fingerprint = read_u64(&mut r)?;
    let max_docs_per_shard = read_u64(&mut r)? as usize;
    let num_shards = read_u64(&mut r)? as usize;
    // every shard costs at least 17 bytes (doc_count + appended + flag):
    // bound the shard-vector allocation by the bytes actually present
    emd_ensure!(
        (num_shards as u128) * 17 <= remaining as u128,
        config,
        "corrupt EMDX manifest in {path:?}: {num_shards} shards cannot fit in {remaining} \
         remaining bytes"
    );
    let mut shards = Vec::with_capacity(num_shards);
    for s in 0..num_shards {
        take(&mut remaining, 8, "shard doc count", path)?;
        let docs = read_u64(&mut r)? as usize;
        take(&mut remaining, (docs as u128) * 4, "shard global-id list", path)?;
        let mut globals = Vec::with_capacity(docs);
        for _ in 0..docs {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            globals.push(u32::from_le_bytes(b));
        }
        take(&mut remaining, 9, "shard trailer", path)?;
        let appended = read_u64(&mut r)? as usize;
        let mut flag = [0u8; 1];
        r.read_exact(&mut flag)?;
        let index = match flag[0] {
            0 => None,
            1 => {
                let (ix, consumed) = read_body(&mut r, remaining).map_err(|e| match e {
                    EmdError::Config(m) => {
                        EmdError::config(format!("{m} (shard {s} of {path:?})"))
                    }
                    other => other,
                })?;
                remaining -= consumed;
                Some(ix)
            }
            other => {
                return Err(EmdError::config(format!(
                    "corrupt EMDX manifest in {path:?}: shard {s} index flag is {other}"
                )))
            }
        };
        shards.push(ManifestShard { globals, appended, index });
    }
    emd_ensure!(
        remaining == 0,
        config,
        "corrupt EMDX manifest in {path:?}: {remaining} trailing bytes"
    );
    Ok(Manifest { corpus_fingerprint, max_docs_per_shard, shards })
}

/// Load a manifest for a specific corpus dataset, rejecting a stale sidecar
/// whose embedded fingerprint does not match `expected_fingerprint`.
pub fn load_manifest_for(path: &Path, expected_fingerprint: u64) -> EmdResult<Manifest> {
    let man = load_manifest(path)?;
    if man.corpus_fingerprint != expected_fingerprint {
        return Err(EmdError::config(format!(
            "stale shard manifest {path:?}: fingerprint {:#018x} does not match dataset \
             {:#018x} — rebuild with `emdpar shard --op build`",
            man.corpus_fingerprint, expected_fingerprint
        )));
    }
    Ok(man)
}

/// Reconstruct the live corpus a manifest describes over its (already
/// loaded) corpus dataset: shard datasets are gathered bit-exactly from the
/// corpus rows, per-shard engines are rebuilt, and each persisted index is
/// validated against the shard data it claims to cover (shape, dim and
/// fingerprint) before it is trusted.
///
/// `index_params` follows the caller's configuration: `None` drops any
/// persisted indexes (exhaustive shards); `Some` trains a fresh index for a
/// shard the manifest left exhaustive.  `max_docs_override` replaces the
/// persisted append policy when the caller's config carries its own.
pub fn reconstruct(
    dataset: &Dataset,
    manifest: &Manifest,
    max_docs_override: Option<usize>,
    engine_params: EngineParams,
    index_params: Option<&IndexParams>,
) -> EmdResult<ShardedCorpus> {
    emd_ensure!(
        manifest.num_docs() == dataset.len(),
        config,
        "manifest covers {} docs but the dataset has {}",
        manifest.num_docs(),
        dataset.len()
    );
    // reject out-of-range global ids *before* any row is gathered — a
    // corrupt manifest must surface as a clean error the engine's
    // log-and-rebuild fallback can catch, never an index panic
    for (s, ms) in manifest.shards.iter().enumerate() {
        for &g in &ms.globals {
            emd_ensure!(
                (g as usize) < dataset.len(),
                config,
                "manifest shard {s} owns global id {g} but the dataset has {} docs",
                dataset.len()
            );
        }
    }
    let mut shards = Vec::with_capacity(manifest.shards.len());
    // per-shard engine thread budget, matching what ShardedCorpus::from_parts
    // computes for the same shard count (the append path reuses it)
    let shard_engine =
        super::corpus::shard_engine_params(engine_params, manifest.shards.len().max(1));
    for (s, ms) in manifest.shards.iter().enumerate() {
        let name = format!("{}/shard{}", dataset.name, s);
        let shard_ds = Arc::new(gather_rows(dataset, &ms.globals, name));
        let index = match (&ms.index, index_params) {
            (Some(ix), Some(_)) => {
                emd_ensure!(
                    ix.num_points() == shard_ds.len(),
                    config,
                    "shard {s} index covers {} rows but the shard has {}",
                    ix.num_points(),
                    shard_ds.len()
                );
                emd_ensure!(
                    ix.dim() == shard_ds.embeddings.dim(),
                    config,
                    "shard {s} index dim {} does not match embedding dim {}",
                    ix.dim(),
                    shard_ds.embeddings.dim()
                );
                let fp = dataset_fingerprint(&shard_ds);
                emd_ensure!(
                    ix.fingerprint() == fp,
                    config,
                    "stale shard {s} index: fingerprint {:#018x} does not match shard data \
                     {:#018x}",
                    ix.fingerprint(),
                    fp
                );
                Some(ix.clone())
            }
            // config has no index: run the shard exhaustive
            (_, None) => None,
            // config wants an index the manifest does not carry: train one
            (None, Some(p)) => {
                if shard_ds.is_empty() {
                    None
                } else {
                    // reconstruct is serial: precompute + training run on
                    // the full pool, like the fresh-build path
                    let engine = crate::lc::LcEngine::with_precompute_threads(
                        Arc::clone(&shard_ds),
                        shard_engine,
                        engine_params.threads,
                    );
                    Some(IvfIndex::train(
                        engine.wcd_centroids(),
                        shard_ds.embeddings.dim(),
                        p,
                        engine_params.threads,
                        dataset_fingerprint(&shard_ds),
                    )?)
                }
            }
        };
        shards.push(Shard::from_parts(
            shard_ds,
            ms.globals.clone(),
            ms.appended,
            index,
            shard_engine,
            engine_params.threads,
        ));
    }
    let params = ShardParams {
        shards: shards.len().max(1),
        max_docs_per_shard: max_docs_override.unwrap_or(manifest.max_docs_per_shard).max(1),
    };
    ShardedCorpus::from_parts(
        dataset.embeddings.clone(),
        shards,
        params,
        engine_params,
        index_params.copied(),
    )
}

fn take(remaining: &mut u64, bytes: u128, what: &str, path: &Path) -> EmdResult<()> {
    emd_ensure!(
        bytes <= *remaining as u128,
        config,
        "corrupt EMDX manifest in {path:?}: {what} needs {bytes} bytes but only \
         {remaining} remain"
    );
    *remaining -= bytes as u64;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_text, TextConfig};
    use std::path::PathBuf;

    fn dataset() -> Dataset {
        generate_text(&TextConfig {
            n: 36,
            classes: 3,
            vocab: 180,
            dim: 8,
            doc_len: 18,
            seed: 41,
            ..Default::default()
        })
    }

    fn index_params() -> IndexParams {
        IndexParams { nlist: 3, nprobe: 1, train_iters: 5, seed: 9, min_points_per_list: 1 }
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("emdpar_shard_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build(ds: &Dataset, with_index: bool) -> ShardedCorpus {
        let ixp = index_params();
        ShardedCorpus::build(
            ds,
            ShardParams { shards: 3, max_docs_per_shard: 100 },
            EngineParams { threads: 2, ..Default::default() },
            with_index.then_some(&ixp),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_restores_layout_and_indexes() {
        let ds = dataset();
        let corpus = build(&ds, true);
        let fp = dataset_fingerprint(&ds);
        let path = tmp("roundtrip.emdx");
        save_manifest(&corpus, fp, &path).unwrap();

        let man = load_manifest_for(&path, fp).unwrap();
        assert_eq!(man.num_docs(), 36);
        assert_eq!(man.max_docs_per_shard, 100);
        assert_eq!(man.shards.len(), 3);
        for (ms, shard) in man.shards.iter().zip(corpus.shards()) {
            assert_eq!(ms.globals, shard.globals());
            assert_eq!(ms.appended, shard.appended());
            assert_eq!(ms.index.as_ref(), shard.index());
        }
        let ixp = index_params();
        let back = reconstruct(
            &ds,
            &man,
            None,
            EngineParams { threads: 2, ..Default::default() },
            Some(&ixp),
        )
        .unwrap();
        assert_eq!(back.len(), corpus.len());
        assert_eq!(back.num_shards(), corpus.num_shards());
        for (a, b) in back.shards().iter().zip(corpus.shards()) {
            assert_eq!(a.globals(), b.globals());
            assert_eq!(a.index(), b.index());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stale_and_wrong_version_rejected() {
        let ds = dataset();
        let corpus = build(&ds, false);
        let fp = dataset_fingerprint(&ds);
        let path = tmp("stale.emdx");
        save_manifest(&corpus, fp, &path).unwrap();
        assert!(load_manifest_for(&path, fp).is_ok());
        let err = load_manifest_for(&path, fp.wrapping_add(1)).unwrap_err();
        assert!(err.to_string().contains("stale shard manifest"), "{err}");

        // a v1 single-index sidecar is cleanly rejected by the manifest
        // loader (and vice versa, see rust/tests/index_pruning.rs)
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"EMDX");
        v1.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1).unwrap();
        let err = load_manifest(&path).unwrap_err();
        assert!(err.to_string().contains("unsupported EMDX version 1"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_and_absurd_counts_rejected_before_allocation() {
        let ds = dataset();
        let corpus = build(&ds, true);
        let fp = dataset_fingerprint(&ds);
        let path = tmp("corrupt.emdx");
        save_manifest(&corpus, fp, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // truncated tail: clean error, no panic
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        assert!(load_manifest(&path).is_err());
        // absurd shard count: bounded against the file length before the
        // shard vector is allocated
        let mut bogus = Vec::new();
        bogus.extend_from_slice(b"EMDX");
        bogus.extend_from_slice(&2u32.to_le_bytes());
        bogus.extend_from_slice(&0u64.to_le_bytes()); // fingerprint
        bogus.extend_from_slice(&10u64.to_le_bytes()); // max docs
        bogus.extend_from_slice(&(1u64 << 50).to_le_bytes()); // num_shards
        std::fs::write(&path, &bogus).unwrap();
        let err = load_manifest(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt EMDX manifest"), "{err}");
        // absurd per-shard doc count: bounded the same way
        let mut bogus = Vec::new();
        bogus.extend_from_slice(b"EMDX");
        bogus.extend_from_slice(&2u32.to_le_bytes());
        bogus.extend_from_slice(&0u64.to_le_bytes());
        bogus.extend_from_slice(&10u64.to_le_bytes());
        bogus.extend_from_slice(&1u64.to_le_bytes()); // one shard
        bogus.extend_from_slice(&(1u64 << 50).to_le_bytes()); // doc_count
        std::fs::write(&path, &bogus).unwrap();
        let err = load_manifest(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt EMDX manifest"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reconstruct_rejects_out_of_range_global_ids_cleanly() {
        let ds = dataset();
        let corpus = build(&ds, false);
        let mut shards: Vec<ManifestShard> = corpus
            .shards()
            .iter()
            .map(|s| ManifestShard {
                globals: s.globals().to_vec(),
                appended: s.appended(),
                index: None,
            })
            .collect();
        // a corrupted global-id entry must be a clean config error, not an
        // index-out-of-bounds panic in the gather path
        shards[0].globals[0] = 10_000;
        let man = Manifest {
            corpus_fingerprint: dataset_fingerprint(&ds),
            max_docs_per_shard: 100,
            shards,
        };
        let err = reconstruct(
            &ds,
            &man,
            None,
            EngineParams { threads: 1, ..Default::default() },
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("global id 10000"), "{err}");
    }

    #[test]
    fn reconstruct_rejects_mismatched_dataset() {
        let ds = dataset();
        let corpus = build(&ds, true);
        let man = Manifest {
            corpus_fingerprint: dataset_fingerprint(&ds),
            max_docs_per_shard: 100,
            shards: corpus
                .shards()
                .iter()
                .map(|s| ManifestShard {
                    globals: s.globals().to_vec(),
                    appended: s.appended(),
                    index: s.index().cloned(),
                })
                .collect(),
        };
        // a different dataset of the same size: per-shard index
        // fingerprints no longer match the gathered shard data
        let other = generate_text(&TextConfig {
            n: 36,
            classes: 3,
            vocab: 180,
            dim: 8,
            doc_len: 18,
            seed: 42,
            ..Default::default()
        });
        let ixp = index_params();
        let err = reconstruct(
            &other,
            &man,
            None,
            EngineParams { threads: 2, ..Default::default() },
            Some(&ixp),
        )
        .unwrap_err();
        assert!(err.to_string().contains("stale shard"), "{err}");
        // dropping the indexes (no index config) reconstructs fine
        assert!(reconstruct(
            &other,
            &man,
            None,
            EngineParams { threads: 2, ..Default::default() },
            None,
        )
        .is_ok());
    }
}
