//! Segmented append persistence: the `EMDX` **version 3** segment file.
//!
//! [`crate::coordinator::SearchEngine::add_docs`] used to persist every
//! append by rewriting the whole `EMD1` dataset plus the version-2 shard
//! manifest — `O(corpus)` disk work per append batch.  Segments make the
//! append path `O(batch)`: each accepted batch is written as one numbered
//! segment file next to the dataset, and a restarted node replays the
//! segments (in sequence order) through the deterministic
//! [`crate::shard::ShardedCorpus::append`] placement, reconstructing the
//! exact live corpus without the base file ever changing.
//!
//! Format (little-endian):
//! ```text
//! magic "EMDX" | version u32 = 3
//! base_fingerprint u64   (dataset_fingerprint of the base EMD1 file)
//! base_global u64        (corpus size the batch was appended at)
//! doc_count u64
//! per doc:
//!   label u16
//!   nnz u32
//!   indices u32[nnz]
//!   weights f32[nnz]
//! ```
//! Documents are stored exactly as the client submitted them —
//! **un-normalized** — because [`crate::shard::ShardedCorpus::append`]
//! normalizes deterministically; replaying the raw input through the same
//! code path reproduces the live rows bit-exactly.  Like the manifest
//! loader, every header-implied size is validated against the remaining
//! file length before any allocation is sized from it, and the embedded
//! base fingerprint plus the `base_global` chain reject segments that
//! belong to a different (or since-rewritten) dataset instead of silently
//! corrupting the corpus.
//!
//! Segments live in a `<dataset>.segments/` directory as
//! `seg-NNNNNN.emdx`; a successful full rewrite
//! ([`crate::coordinator::SearchEngine::persist_shards`]) folds them into
//! the base file and clears the directory.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::core::{EmdError, EmdResult, Histogram};
use crate::emd_ensure;

use super::corpus::ShardedCorpus;

const MAGIC: &[u8; 4] = b"EMDX";
/// The append-segment version of the `EMDX` family (1 = single-index
/// sidecar, 2 = shard manifest).
pub const SEGMENT_VERSION: u32 = 3;

/// One loaded append batch.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Fingerprint of the base `EMD1` dataset the batch extends.
    pub base_fingerprint: u64,
    /// Corpus size (next global id) at the moment the batch was appended.
    pub base_global: usize,
    /// The batch's documents, exactly as submitted (un-normalized).
    pub docs: Vec<Histogram>,
    /// One label per document (0 when the client sent none).
    pub labels: Vec<u16>,
}

/// The segment directory conventionally paired with a dataset file:
/// `<file>.segments/` next to it.
pub fn segments_dir(dataset_path: &Path) -> PathBuf {
    let mut name = dataset_path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".to_string());
    name.push_str(".segments");
    dataset_path.with_file_name(name)
}

/// Segment files currently on disk, in replay (sequence) order.
pub fn list_segments(dir: &Path) -> EmdResult<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
        if let Some(name) = name {
            if name.starts_with("seg-") && name.ends_with(".emdx") {
                out.push(path);
            }
        }
    }
    // zero-padded fixed-width sequence numbers: lexicographic = numeric
    out.sort();
    Ok(out)
}

/// Append one batch as the next numbered segment in `dir` (created on
/// first use).  The file is written to a temporary name and renamed into
/// place, so a crash mid-write never leaves a half-segment to replay.
pub fn append_segment(
    dir: &Path,
    base_fingerprint: u64,
    base_global: usize,
    docs: &[Histogram],
    labels: &[u16],
) -> EmdResult<PathBuf> {
    emd_ensure!(!docs.is_empty(), config, "a segment needs at least one document");
    emd_ensure!(
        labels.is_empty() || labels.len() == docs.len(),
        config,
        "segment got {} labels for {} documents",
        labels.len(),
        docs.len()
    );
    std::fs::create_dir_all(dir)?;
    let seq = match list_segments(dir)?.last() {
        Some(last) => segment_seq(last)? + 1,
        None => 0,
    };
    let path = dir.join(format!("seg-{seq:06}.emdx"));
    let tmp = dir.join(format!("seg-{seq:06}.emdx.tmp"));
    {
        let mut w = BufWriter::new(File::create(&tmp)?);
        w.write_all(MAGIC)?;
        w.write_all(&SEGMENT_VERSION.to_le_bytes())?;
        w.write_all(&base_fingerprint.to_le_bytes())?;
        w.write_all(&(base_global as u64).to_le_bytes())?;
        w.write_all(&(docs.len() as u64).to_le_bytes())?;
        for (i, doc) in docs.iter().enumerate() {
            let label = labels.get(i).copied().unwrap_or(0);
            w.write_all(&label.to_le_bytes())?;
            w.write_all(&(doc.indices().len() as u32).to_le_bytes())?;
            for &idx in doc.indices() {
                w.write_all(&idx.to_le_bytes())?;
            }
            for &wgt in doc.weights() {
                w.write_all(&wgt.to_le_bytes())?;
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Load one segment, validating every size against the file length before
/// it is allocated.
pub fn load_segment(path: &Path) -> EmdResult<Segment> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "bad magic (not an EMDX file)",
        )
        .into());
    }
    let version = read_u32(&mut r)?;
    if version != SEGMENT_VERSION {
        return Err(EmdError::config(format!(
            "unsupported EMDX version {version} (expected segment version {SEGMENT_VERSION})"
        )));
    }
    let mut remaining = file_len.saturating_sub(8); // magic + version consumed
    take(&mut remaining, 24, "segment header", path)?;
    let base_fingerprint = read_u64(&mut r)?;
    let base_global = read_u64(&mut r)? as usize;
    let doc_count = read_u64(&mut r)? as usize;
    // every document costs at least 6 bytes (label + nnz): bound the doc
    // vector allocation by the bytes actually present
    emd_ensure!(
        (doc_count as u128) * 6 <= remaining as u128,
        config,
        "corrupt EMDX segment {path:?}: {doc_count} documents cannot fit in {remaining} \
         remaining bytes"
    );
    let mut docs = Vec::with_capacity(doc_count);
    let mut labels = Vec::with_capacity(doc_count);
    for d in 0..doc_count {
        take(&mut remaining, 6, "document header", path)?;
        let mut lb = [0u8; 2];
        r.read_exact(&mut lb)?;
        labels.push(u16::from_le_bytes(lb));
        let nnz = read_u32(&mut r)? as usize;
        take(&mut remaining, (nnz as u128) * 8, "document entries", path)?;
        emd_ensure!(nnz >= 1, config, "corrupt EMDX segment {path:?}: document {d} is empty");
        let mut indices = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            indices.push(read_u32(&mut r)?);
        }
        let mut pairs = Vec::with_capacity(nnz);
        for &idx in &indices {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            pairs.push((idx, f32::from_le_bytes(b)));
        }
        docs.push(Histogram::from_pairs(pairs));
    }
    emd_ensure!(
        remaining == 0,
        config,
        "corrupt EMDX segment {path:?}: {remaining} trailing bytes"
    );
    Ok(Segment { base_fingerprint, base_global, docs, labels })
}

/// Replay every segment in `dir` (sequence order) into `corpus`, which
/// must be the corpus reconstructed from the base dataset whose
/// fingerprint is `base_fingerprint`.  Returns the number of documents
/// replayed.  A segment written against a different dataset, or one whose
/// `base_global` does not chain onto the corpus (a deleted / reordered
/// segment file), is a hard error — replaying it would silently shift
/// every subsequent global id.
pub fn replay_segments(
    corpus: &mut ShardedCorpus,
    dir: &Path,
    base_fingerprint: u64,
) -> EmdResult<usize> {
    let mut replayed = 0usize;
    for path in list_segments(dir)? {
        let seg = load_segment(&path)?;
        emd_ensure!(
            seg.base_fingerprint == base_fingerprint,
            config,
            "stale segment {path:?}: fingerprint {:#018x} does not match the base dataset \
             {:#018x} — remove the segment directory or restore the matching dataset",
            seg.base_fingerprint,
            base_fingerprint
        );
        emd_ensure!(
            seg.base_global == corpus.len(),
            config,
            "segment {path:?} was appended at corpus size {} but replay reached {} — the \
             segment chain is broken (missing or reordered segment files)",
            seg.base_global,
            corpus.len()
        );
        let out = corpus.append(&seg.docs, &seg.labels)?;
        replayed += out.ids.len();
    }
    Ok(replayed)
}

/// Remove every segment file in `dir` (after a successful full rewrite
/// folded them into the base dataset).  The directory itself is removed
/// when it ends up empty.
pub fn clear_segments(dir: &Path) -> EmdResult<()> {
    for path in list_segments(dir)? {
        std::fs::remove_file(&path)?;
    }
    // non-empty (foreign files) or already-gone directories are fine
    std::fs::remove_dir(dir).ok();
    Ok(())
}

fn segment_seq(path: &Path) -> EmdResult<u64> {
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    name.strip_prefix("seg-")
        .and_then(|s| s.strip_suffix(".emdx"))
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| EmdError::config(format!("malformed segment file name {path:?}")))
}

fn take(remaining: &mut u64, bytes: u128, what: &str, path: &Path) -> EmdResult<()> {
    emd_ensure!(
        bytes <= *remaining as u128,
        config,
        "corrupt EMDX segment {path:?}: {what} needs {bytes} bytes but only {remaining} \
         remain"
    );
    *remaining -= bytes as u64;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ShardParams;
    use crate::data::{generate_text, TextConfig};
    use crate::index::dataset_fingerprint;
    use crate::lc::EngineParams;
    use std::path::PathBuf;

    fn dataset() -> crate::core::Dataset {
        generate_text(&TextConfig {
            n: 24,
            classes: 3,
            vocab: 150,
            dim: 8,
            doc_len: 16,
            seed: 31,
            ..Default::default()
        })
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("emdpar_segments_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_replay_reproduce_the_live_corpus() {
        let ds = dataset();
        let fp = dataset_fingerprint(&ds);
        let params = ShardParams { shards: 2, max_docs_per_shard: 1 << 20 };
        let ep = EngineParams { threads: 2, ..Default::default() };
        let mut live = ShardedCorpus::build(&ds, params, ep, None).unwrap();

        let dir = tmp("replay.bin.segments");
        clear_segments(&dir).unwrap();
        let batches: Vec<Vec<Histogram>> = vec![
            (0..3).map(|u| ds.histogram(u)).collect(),
            (3..5).map(|u| ds.histogram(u)).collect(),
        ];
        let labels = [vec![7u16, 8, 9], vec![]];
        for (docs, lb) in batches.iter().zip(&labels) {
            let base = live.len();
            live.append(docs, lb).unwrap();
            append_segment(&dir, fp, base, docs, lb).unwrap();
        }
        assert_eq!(list_segments(&dir).unwrap().len(), 2);

        let mut restored = ShardedCorpus::build(&ds, params, ep, None).unwrap();
        let replayed = replay_segments(&mut restored, &dir, fp).unwrap();
        assert_eq!(replayed, 5);
        assert_eq!(restored.len(), live.len());
        for g in 0..live.len() {
            assert_eq!(restored.label(g), live.label(g), "doc {g}");
            let a = restored.histogram(g);
            let b = live.histogram(g);
            assert_eq!(a.indices(), b.indices(), "doc {g}");
            assert_eq!(a.weights(), b.weights(), "doc {g}");
        }
        clear_segments(&dir).unwrap();
        assert!(list_segments(&dir).unwrap().is_empty());
    }

    #[test]
    fn stale_fingerprint_and_broken_chain_rejected() {
        let ds = dataset();
        let fp = dataset_fingerprint(&ds);
        let params = ShardParams { shards: 2, max_docs_per_shard: 1 << 20 };
        let ep = EngineParams { threads: 1, ..Default::default() };
        let dir = tmp("chain.bin.segments");
        clear_segments(&dir).unwrap();
        let docs: Vec<Histogram> = (0..2).map(|u| ds.histogram(u)).collect();
        append_segment(&dir, fp, ds.len(), &docs, &[]).unwrap();

        let mut c = ShardedCorpus::build(&ds, params, ep, None).unwrap();
        let err = replay_segments(&mut c, &dir, fp.wrapping_add(1)).unwrap_err();
        assert!(err.to_string().contains("stale segment"), "{err}");

        // replay against a corpus that is not at the recorded base size
        let mut c = ShardedCorpus::build(&ds, params, ep, None).unwrap();
        c.append(&docs, &[]).unwrap();
        let err = replay_segments(&mut c, &dir, fp).unwrap_err();
        assert!(err.to_string().contains("segment chain is broken"), "{err}");
        clear_segments(&dir).unwrap();
    }

    #[test]
    fn truncation_and_absurd_counts_rejected_before_allocation() {
        let ds = dataset();
        let dir = tmp("corrupt.bin.segments");
        clear_segments(&dir).unwrap();
        let docs: Vec<Histogram> = vec![ds.histogram(0)];
        let path = append_segment(&dir, 1, 24, &docs, &[3]).unwrap();
        let full = std::fs::read(&path).unwrap();

        let seg = load_segment(&path).unwrap();
        assert_eq!(seg.base_fingerprint, 1);
        assert_eq!(seg.base_global, 24);
        assert_eq!(seg.labels, vec![3]);
        assert_eq!(seg.docs[0].indices(), docs[0].indices());
        assert_eq!(seg.docs[0].weights(), docs[0].weights());

        // truncated tail: clean error, no panic
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        assert!(load_segment(&path).is_err());
        // absurd doc count: bounded against the file length before the
        // vector is allocated
        let mut bogus = Vec::new();
        bogus.extend_from_slice(b"EMDX");
        bogus.extend_from_slice(&SEGMENT_VERSION.to_le_bytes());
        bogus.extend_from_slice(&0u64.to_le_bytes()); // fingerprint
        bogus.extend_from_slice(&0u64.to_le_bytes()); // base_global
        bogus.extend_from_slice(&(1u64 << 50).to_le_bytes()); // doc_count
        std::fs::write(&path, &bogus).unwrap();
        let err = load_segment(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt EMDX segment"), "{err}");
        // a v2 manifest is cleanly rejected by the segment loader
        let mut v2 = Vec::new();
        v2.extend_from_slice(b"EMDX");
        v2.extend_from_slice(&2u32.to_le_bytes());
        std::fs::write(&path, &v2).unwrap();
        let err = load_segment(&path).unwrap_err();
        assert!(err.to_string().contains("unsupported EMDX version 2"), "{err}");
        clear_segments(&dir).unwrap();
    }
}
