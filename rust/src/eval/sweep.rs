//! Runtime-vs-accuracy sweeps (paper Fig. 8) and table generation
//! (paper Tables 5-6): run a set of methods over a dataset, timing the
//! all-pairs (or query-subset) distance computation and scoring
//! precision@top-ℓ.
//!
//! Method dispatch goes through [`MethodRegistry`] / [`BatchDistance`]
//! trait objects, so the quadratic comparators (ICT, Sinkhorn, exact EMD)
//! sweep exactly like the LC bounds — pass `Method::Sinkhorn` or
//! `Method::Exact` in the method list and they time and score identically.

use std::time::Duration;

use std::sync::Arc;

use crate::core::{BatchDistance, Dataset, EmdResult, Method, MethodRegistry};
use crate::lc::{EngineParams, LcEngine};
use crate::util::stats::fmt_duration;

use super::precision::precision_curve;

/// One method's sweep outcome.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub method: String,
    /// Wall-clock for the full distance computation.
    pub runtime: Duration,
    /// Number of query-database distance evaluations performed.
    pub pairs: usize,
    /// (ℓ, precision@ℓ).
    pub precision: Vec<(usize, f64)>,
}

impl SweepRow {
    /// Distance computations per second.
    pub fn throughput(&self) -> f64 {
        self.pairs as f64 / self.runtime.as_secs_f64().max(1e-12)
    }
}

/// Registry-bound batch objects for each requested method.
fn batches(
    dataset: &Arc<Dataset>,
    methods: &[Method],
    params: EngineParams,
) -> Vec<Box<dyn BatchDistance>> {
    let engine = Arc::new(LcEngine::new(Arc::clone(dataset), params));
    let registry = MethodRegistry::new(params.metric);
    methods.iter().map(|&m| registry.batch(&engine, m)).collect()
}

/// All-pairs evaluation of `methods` on `dataset` (the Fig. 8 protocol:
/// every document queried against every other).
pub fn sweep_all_pairs(
    dataset: &Arc<Dataset>,
    methods: &[Method],
    ls: &[usize],
    params: EngineParams,
) -> EmdResult<Vec<SweepRow>> {
    let n = dataset.len();
    batches(dataset, methods, params)
        .into_iter()
        .map(|batch| -> EmdResult<SweepRow> {
            let t0 = std::time::Instant::now();
            let matrix = batch.all_pairs_symmetric()?;
            let runtime = t0.elapsed();
            let precision =
                precision_curve(&matrix, &dataset.labels, &dataset.labels, ls, true);
            Ok(SweepRow { method: batch.method().name(), runtime, pairs: n * n, precision })
        })
        .collect()
}

/// Query-subset evaluation: the first `nq` documents query the full
/// database (the paper's MNIST-subset protocol for Fig. 8(b)).
pub fn sweep_subset(
    dataset: &Arc<Dataset>,
    nq: usize,
    methods: &[Method],
    ls: &[usize],
    params: EngineParams,
) -> EmdResult<Vec<SweepRow>> {
    let n = dataset.len();
    let nq = nq.min(n);
    batches(dataset, methods, params)
        .into_iter()
        .map(|batch| -> EmdResult<SweepRow> {
            let t0 = std::time::Instant::now();
            let matrix = subset_matrix(dataset, batch.as_ref(), nq)?;
            let runtime = t0.elapsed();
            let qlabels = &dataset.labels[..nq];
            let precision = precision_curve(&matrix, qlabels, &dataset.labels, ls, true);
            Ok(SweepRow { method: batch.method().name(), runtime, pairs: nq * n, precision })
        })
        .collect()
}

/// Row-major `(nq, n)` distance matrix through a [`BatchDistance`] object —
/// one multi-query dispatch, so LC methods run the batched Phase-1 kernel.
fn subset_matrix(
    dataset: &Arc<Dataset>,
    batch: &dyn BatchDistance,
    nq: usize,
) -> EmdResult<Vec<f32>> {
    let queries: Vec<_> = (0..nq).map(|i| dataset.histogram(i)).collect();
    batch.distances_batch(&queries)
}

/// Render sweep rows as a markdown table (EXPERIMENTS.md format).
pub fn render_markdown(title: &str, rows: &[SweepRow]) -> String {
    let mut out = format!("### {title}\n\n");
    if rows.is_empty() {
        return out;
    }
    let ls: Vec<usize> = rows[0].precision.iter().map(|&(l, _)| l).collect();
    out.push_str("| method | runtime | pairs/s |");
    for l in &ls {
        out.push_str(&format!(" p@{l} |"));
    }
    out.push_str("\n|---|---|---|");
    for _ in &ls {
        out.push_str("---|");
    }
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.3e} |",
            r.method,
            fmt_duration(r.runtime),
            r.throughput()
        ));
        for &(_, p) in &r.precision {
            out.push_str(&format!(" {p:.4} |"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_text, TextConfig};

    fn tiny() -> Arc<Dataset> {
        Arc::new(generate_text(&TextConfig {
            n: 60,
            classes: 3,
            vocab: 300,
            dim: 8,
            doc_len: 30,
            ..Default::default()
        }))
    }

    #[test]
    fn sweep_produces_sane_rows() {
        let ds = tiny();
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Bow, Method::Rwmd, Method::Act { k: 2 }],
            &[1, 4],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.pairs, 60 * 60);
            for &(_, p) in &r.precision {
                assert!((0.0..=1.0).contains(&p), "{}: p={p}", r.method);
                // better than random guessing over 3 classes
                assert!(p > 1.0 / 3.0, "{}: p={p} not better than chance", r.method);
            }
        }
    }

    #[test]
    fn subset_sweep_shapes() {
        let ds = tiny();
        let rows = sweep_subset(
            &ds,
            10,
            &[Method::Rwmd],
            &[1],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows[0].pairs, 10 * 60);
    }

    #[test]
    fn sinkhorn_and_exact_sweep_through_registry() {
        // the comparators are selectable exactly like the LC bounds
        let ds = Arc::new(generate_text(&TextConfig {
            n: 24,
            classes: 3,
            vocab: 120,
            dim: 6,
            doc_len: 12,
            ..Default::default()
        }));
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Rwmd, Method::Ict, Method::Sinkhorn, Method::Exact],
            &[2],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[1].method, "ICT");
        assert_eq!(rows[2].method, "Sinkhorn");
        assert_eq!(rows[3].method, "EMD");
        for r in &rows {
            assert_eq!(r.pairs, 24 * 24);
            assert!((0.0..=1.0).contains(&r.precision[0].1), "{}", r.method);
        }
    }

    #[test]
    fn markdown_render_contains_methods() {
        let ds = tiny();
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Bow],
            &[1],
            EngineParams { threads: 1, ..Default::default() },
        )
        .unwrap();
        let md = render_markdown("test", &rows);
        assert!(md.contains("| BoW |"));
        assert!(md.contains("p@1"));
    }
}
