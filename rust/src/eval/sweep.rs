//! Runtime-vs-accuracy sweeps (paper Fig. 8) and table generation
//! (paper Tables 5-6): run a set of methods over a dataset, timing the
//! all-pairs (or query-subset) distance computation and scoring
//! precision@top-ℓ.
//!
//! Method dispatch goes through [`MethodRegistry`] / [`BatchDistance`]
//! trait objects, so the quadratic comparators (ICT, Sinkhorn, exact EMD)
//! sweep exactly like the LC bounds — pass `Method::Sinkhorn` or
//! `Method::Exact` in the method list and they time and score identically.

use std::time::Duration;

use std::sync::Arc;

use crate::coordinator::{SearchEngine, SearchRequest};
use crate::core::{BatchDistance, Dataset, EmdResult, Histogram, Method, MethodRegistry};
use crate::lc::{EngineParams, LcEngine};
use crate::util::stats::fmt_duration;

use super::precision::precision_curve;

/// One method's sweep outcome.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub method: String,
    /// Wall-clock for the full distance computation.
    pub runtime: Duration,
    /// Number of query-database distance evaluations performed.
    pub pairs: usize,
    /// (ℓ, precision@ℓ).
    pub precision: Vec<(usize, f64)>,
}

impl SweepRow {
    /// Distance computations per second.
    pub fn throughput(&self) -> f64 {
        self.pairs as f64 / self.runtime.as_secs_f64().max(1e-12)
    }
}

/// Registry-bound batch objects for each requested method.
fn batches(
    dataset: &Arc<Dataset>,
    methods: &[Method],
    params: EngineParams,
) -> Vec<Box<dyn BatchDistance>> {
    let engine = Arc::new(LcEngine::new(Arc::clone(dataset), params));
    let registry = MethodRegistry::new(params.metric);
    methods.iter().map(|&m| registry.batch(&engine, m)).collect()
}

/// All-pairs evaluation of `methods` on `dataset` (the Fig. 8 protocol:
/// every document queried against every other).
pub fn sweep_all_pairs(
    dataset: &Arc<Dataset>,
    methods: &[Method],
    ls: &[usize],
    params: EngineParams,
) -> EmdResult<Vec<SweepRow>> {
    let n = dataset.len();
    batches(dataset, methods, params)
        .into_iter()
        .map(|batch| -> EmdResult<SweepRow> {
            let t0 = std::time::Instant::now();
            let matrix = batch.all_pairs_symmetric()?;
            let runtime = t0.elapsed();
            let precision =
                precision_curve(&matrix, &dataset.labels, &dataset.labels, ls, true);
            Ok(SweepRow { method: batch.method().name(), runtime, pairs: n * n, precision })
        })
        .collect()
}

/// Query-subset evaluation: the first `nq` documents query the full
/// database (the paper's MNIST-subset protocol for Fig. 8(b)).
pub fn sweep_subset(
    dataset: &Arc<Dataset>,
    nq: usize,
    methods: &[Method],
    ls: &[usize],
    params: EngineParams,
) -> EmdResult<Vec<SweepRow>> {
    let n = dataset.len();
    let nq = nq.min(n);
    batches(dataset, methods, params)
        .into_iter()
        .map(|batch| -> EmdResult<SweepRow> {
            let t0 = std::time::Instant::now();
            let matrix = subset_matrix(dataset, batch.as_ref(), nq)?;
            let runtime = t0.elapsed();
            let qlabels = &dataset.labels[..nq];
            let precision = precision_curve(&matrix, qlabels, &dataset.labels, ls, true);
            Ok(SweepRow { method: batch.method().name(), runtime, pairs: nq * n, precision })
        })
        .collect()
}

/// Precision@top-ℓ through the **serving path**: the first `nq` documents
/// are dispatched as one multi-query [`SearchRequest`] per method through
/// the query planner, so the sweep measures exactly what a deployment
/// executes — index pruning and shard fan-out included when the engine is
/// configured with them.  Self-hits are excluded, matching the matrix
/// sweeps; `pairs` reports the candidates the plan actually scored (the
/// pruning win shows up directly in throughput).
pub fn sweep_serving(
    engine: &SearchEngine,
    methods: &[Method],
    ls: &[usize],
    nq: usize,
) -> EmdResult<Vec<SweepRow>> {
    let n = engine.num_docs();
    let nq = nq.min(n).max(1);
    let lmax = ls.iter().copied().max().unwrap_or(1);
    let queries: Vec<Histogram> =
        (0..nq).map(|i| engine.doc_histogram(i)).collect::<EmdResult<_>>()?;
    // labels come from the same live-corpus source as the histograms, so
    // appended documents score against their real class
    let qlabels: Vec<u16> = (0..nq).map(|i| engine.doc_label(i)).collect::<EmdResult<_>>()?;
    let mut rows = Vec::with_capacity(methods.len());
    for &method in methods {
        // one extra hit so the self-hit can be dropped without starving ℓ
        let req = SearchRequest::batch(queries.clone()).method(method).topl(lmax + 1);
        let t0 = std::time::Instant::now();
        let resp = engine.execute(&req)?;
        let runtime = t0.elapsed();
        let precision = ls
            .iter()
            .map(|&l| {
                let mut acc = 0.0f64;
                for (qi, res) in resp.results.iter().enumerate() {
                    let mut good = 0usize;
                    let mut seen = 0usize;
                    for (&(_, id), &lab) in res.hits.iter().zip(&res.labels) {
                        if id == qi {
                            continue; // self-hit excluded, like the matrix sweeps
                        }
                        if seen == l {
                            break;
                        }
                        seen += 1;
                        if lab == qlabels[qi] {
                            good += 1;
                        }
                    }
                    acc += good as f64 / seen.max(1) as f64;
                }
                (l, acc / resp.results.len().max(1) as f64)
            })
            .collect();
        rows.push(SweepRow {
            method: method.name(),
            runtime,
            pairs: resp.stats.candidates_scored,
            precision,
        });
    }
    Ok(rows)
}

/// Row-major `(nq, n)` distance matrix through a [`BatchDistance`] object —
/// one multi-query dispatch, so LC methods run the batched Phase-1 kernel.
fn subset_matrix(
    dataset: &Arc<Dataset>,
    batch: &dyn BatchDistance,
    nq: usize,
) -> EmdResult<Vec<f32>> {
    let queries: Vec<_> = (0..nq).map(|i| dataset.histogram(i)).collect();
    batch.distances_batch(&queries)
}

/// Render sweep rows as a markdown table (EXPERIMENTS.md format).
pub fn render_markdown(title: &str, rows: &[SweepRow]) -> String {
    let mut out = format!("### {title}\n\n");
    if rows.is_empty() {
        return out;
    }
    let ls: Vec<usize> = rows[0].precision.iter().map(|&(l, _)| l).collect();
    out.push_str("| method | runtime | pairs/s |");
    for l in &ls {
        out.push_str(&format!(" p@{l} |"));
    }
    out.push_str("\n|---|---|---|");
    for _ in &ls {
        out.push_str("---|");
    }
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.3e} |",
            r.method,
            fmt_duration(r.runtime),
            r.throughput()
        ));
        for &(_, p) in &r.precision {
            out.push_str(&format!(" {p:.4} |"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_text, TextConfig};

    fn tiny() -> Arc<Dataset> {
        Arc::new(generate_text(&TextConfig {
            n: 60,
            classes: 3,
            vocab: 300,
            dim: 8,
            doc_len: 30,
            ..Default::default()
        }))
    }

    #[test]
    fn sweep_produces_sane_rows() {
        let ds = tiny();
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Bow, Method::Rwmd, Method::Act { k: 2 }],
            &[1, 4],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.pairs, 60 * 60);
            for &(_, p) in &r.precision {
                assert!((0.0..=1.0).contains(&p), "{}: p={p}", r.method);
                // better than random guessing over 3 classes
                assert!(p > 1.0 / 3.0, "{}: p={p} not better than chance", r.method);
            }
        }
    }

    #[test]
    fn subset_sweep_shapes() {
        let ds = tiny();
        let rows = sweep_subset(
            &ds,
            10,
            &[Method::Rwmd],
            &[1],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows[0].pairs, 10 * 60);
    }

    #[test]
    fn sinkhorn_and_exact_sweep_through_registry() {
        // the comparators are selectable exactly like the LC bounds
        let ds = Arc::new(generate_text(&TextConfig {
            n: 24,
            classes: 3,
            vocab: 120,
            dim: 6,
            doc_len: 12,
            ..Default::default()
        }));
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Rwmd, Method::Ict, Method::Sinkhorn, Method::Exact],
            &[2],
            EngineParams { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[1].method, "ICT");
        assert_eq!(rows[2].method, "Sinkhorn");
        assert_eq!(rows[3].method, "EMD");
        for r in &rows {
            assert_eq!(r.pairs, 24 * 24);
            assert!((0.0..=1.0).contains(&r.precision[0].1), "{}", r.method);
        }
    }

    #[test]
    fn serving_sweep_dispatches_through_the_planner() {
        use crate::config::{Config, DatasetSpec, IndexParams};
        let engine = SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 40, vocab: 200, dim: 8, seed: 6 },
            threads: 2,
            index: Some(IndexParams {
                nlist: 4,
                nprobe: 2,
                train_iters: 5,
                seed: 2,
                min_points_per_list: 1,
            }),
            ..Config::default()
        })
        .unwrap();
        let rows =
            sweep_serving(&engine, &[Method::Rwmd, Method::Act { k: 2 }], &[1, 4], 10).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.pairs > 0, "{}: candidates scored must be reported", r.method);
            // the pruned route scores fewer pairs than exhaustive nq x n
            assert!(r.pairs < 10 * 40, "{}: nprobe 2 of 4 lists must prune", r.method);
            for &(_, p) in &r.precision {
                assert!((0.0..=1.0).contains(&p), "{}: p={p}", r.method);
            }
        }
    }

    #[test]
    fn markdown_render_contains_methods() {
        let ds = tiny();
        let rows = sweep_all_pairs(
            &ds,
            &[Method::Bow],
            &[1],
            EngineParams { threads: 1, ..Default::default() },
        )
        .unwrap();
        let md = render_markdown("test", &rows);
        assert!(md.contains("| BoW |"));
        assert!(md.contains("p@1"));
    }
}
