//! Precision@top-ℓ — the paper's accuracy metric (Section 6): for each
//! query, the fraction of its ℓ nearest neighbors (self excluded) sharing
//! the query's label, averaged over all queries.

/// Indices of the ℓ smallest entries of `row`, excluding `exclude`
/// (usually the query itself).  Ties break to the lowest index, matching
/// the rest of the stack.
pub fn topl_indices(row: &[f32], l: usize, exclude: Option<usize>) -> Vec<usize> {
    let mut vals: Vec<f32> = Vec::with_capacity(l);
    let mut idxs: Vec<usize> = Vec::with_capacity(l);
    for (j, &d) in row.iter().enumerate() {
        if Some(j) == exclude {
            continue;
        }
        if vals.len() < l {
            let pos = vals.partition_point(|&v| v <= d);
            vals.insert(pos, d);
            idxs.insert(pos, j);
        } else if l > 0 && d < vals[l - 1] {
            let pos = vals.partition_point(|&v| v <= d);
            vals.pop();
            idxs.pop();
            vals.insert(pos, d);
            idxs.insert(pos, j);
        }
    }
    idxs
}

/// Recall@ℓ of an approximate result list against the exhaustive truth:
/// the fraction of the true top-ℓ ids the approximate search retrieved
/// (order ignored).  The denominator is `truth.len()`, so a shorter
/// approximate list caps recall accordingly.  Used by the IVF pruning
/// index's evaluation (`rust/tests/index_pruning.rs`, `benches/ivf_recall`).
pub fn recall_at(truth: &[usize], approx: &[usize]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let hits = truth.iter().filter(|t| approx.contains(t)).count();
    hits as f64 / truth.len() as f64
}

/// Average precision@ℓ from a row-major `(nq, n)` distance matrix.
///
/// `query_labels[i]` labels row i; `db_labels[j]` labels column j.  When the
/// query set is a prefix of the database (all-pairs evaluation), pass
/// `exclude_diagonal = true` to skip the self match.
pub fn precision_at(
    distances: &[f32],
    query_labels: &[u16],
    db_labels: &[u16],
    l: usize,
    exclude_diagonal: bool,
) -> f64 {
    let nq = query_labels.len();
    let n = db_labels.len();
    assert_eq!(distances.len(), nq * n);
    assert!(l >= 1);
    let mut total = 0.0f64;
    for i in 0..nq {
        let row = &distances[i * n..(i + 1) * n];
        let exclude = if exclude_diagonal { Some(i) } else { None };
        let top = topl_indices(row, l, exclude);
        let hits = top.iter().filter(|&&j| db_labels[j] == query_labels[i]).count();
        total += hits as f64 / top.len().max(1) as f64;
    }
    total / nq as f64
}

/// precision@ℓ for several ℓ values at once (shares the top-ℓ_max scan).
pub fn precision_curve(
    distances: &[f32],
    query_labels: &[u16],
    db_labels: &[u16],
    ls: &[usize],
    exclude_diagonal: bool,
) -> Vec<(usize, f64)> {
    let nq = query_labels.len();
    let n = db_labels.len();
    assert_eq!(distances.len(), nq * n);
    let lmax = ls.iter().copied().max().unwrap_or(1);
    let mut acc = vec![0.0f64; ls.len()];
    for i in 0..nq {
        let row = &distances[i * n..(i + 1) * n];
        let exclude = if exclude_diagonal { Some(i) } else { None };
        let top = topl_indices(row, lmax, exclude);
        for (slot, &l) in acc.iter_mut().zip(ls) {
            let take = l.min(top.len());
            let hits =
                top[..take].iter().filter(|&&j| db_labels[j] == query_labels[i]).count();
            *slot += hits as f64 / take.max(1) as f64;
        }
    }
    ls.iter().zip(acc).map(|(&l, a)| (l, a / nq as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recall_counts_overlap() {
        assert_eq!(recall_at(&[1, 2, 3, 4], &[4, 2, 9, 1]), 0.75);
        assert_eq!(recall_at(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(recall_at(&[1, 2], &[]), 0.0);
        assert_eq!(recall_at(&[], &[5]), 1.0);
    }

    #[test]
    fn topl_basic_and_ties() {
        let row = [0.5f32, 0.1, 0.1, 0.9];
        assert_eq!(topl_indices(&row, 2, None), vec![1, 2]);
        assert_eq!(topl_indices(&row, 2, Some(1)), vec![2, 0]);
        assert_eq!(topl_indices(&row, 10, None).len(), 4);
    }

    #[test]
    fn perfect_clustering_gives_one() {
        // 2 classes x 3 docs; distances: same-class 0.1, cross 0.9
        let labels = [0u16, 0, 0, 1, 1, 1];
        let n = 6;
        let mut d = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                d[i * n + j] = if labels[i] == labels[j] { 0.1 } else { 0.9 };
            }
        }
        let p = precision_at(&d, &labels, &labels, 2, true);
        assert!((p - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_two_class_is_about_half() {
        // distance = index parity mismatch free; craft adversarial: all
        // distances equal -> ties resolved by index, labels alternate
        let labels: Vec<u16> = (0..40).map(|i| (i % 2) as u16).collect();
        let d = vec![1.0f32; 40 * 40];
        let p = precision_at(&d, &labels, &labels, 10, true);
        assert!((p - 0.5).abs() < 0.08, "p = {p}");
    }

    #[test]
    fn curve_matches_single_calls() {
        let labels = [0u16, 1, 0, 1, 0];
        let n = 5;
        let mut d = vec![0.0f32; n * n];
        let mut seed = 7u32;
        for x in d.iter_mut() {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            *x = (seed >> 8) as f32 / (1u32 << 24) as f32;
        }
        let curve = precision_curve(&d, &labels, &labels, &[1, 3], true);
        for &(l, p) in &curve {
            let single = precision_at(&d, &labels, &labels, l, true);
            assert!((p - single).abs() < 1e-12, "l={l}");
        }
    }

    #[test]
    fn query_subset_vs_full_db() {
        // 2 queries against 4 docs, no diagonal exclusion
        let qlabels = [0u16, 1];
        let dblabels = [0u16, 0, 1, 1];
        let d = vec![
            0.1, 0.2, 0.8, 0.9, // query 0: nearest two are class 0
            0.9, 0.8, 0.2, 0.1, // query 1: nearest two are class 1
        ];
        let p = precision_at(&d, &qlabels, &dblabels, 2, false);
        assert!((p - 1.0).abs() < 1e-12);
    }
}
