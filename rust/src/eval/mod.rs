//! Evaluation harness: precision@top-ℓ (the paper's accuracy metric) and
//! runtime-vs-accuracy sweeps reproducing Fig. 8 / Tables 5-6.

pub mod precision;
pub mod sweep;

pub use precision::{precision_at, precision_curve, recall_at, topl_indices};
pub use sweep::{render_markdown, sweep_all_pairs, sweep_serving, sweep_subset, SweepRow};
