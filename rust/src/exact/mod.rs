//! Exact EMD ground truth: transportation min-cost-flow solver and the
//! pruned "WMD" top-ℓ search baseline.

pub mod emd;
pub mod flow;

pub use emd::{emd, emd_with_cost, wmd_topl_pruned};
pub use flow::{solve_transport, FlowSolution};
