//! Min-cost-flow substrate for the transportation problem.
//!
//! Successive-shortest-paths with node potentials (Johnson reduction) and a
//! dense Dijkstra per augmentation — the right shape for dense bipartite
//! transportation instances (h up to ~1000).  All arithmetic in f64.
//!
//! Graph model: sources = bins of `p`, sinks = bins of `q`; every
//! source-sink edge has capacity +inf and cost `C[i][j]`; residual
//! (backward) edges carry flow that can be rerouted.  One potential value
//! per node keeps reduced costs non-negative; after each Dijkstra the
//! potentials are advanced by `min(dist(v), dist(target))` — the capping is
//! what preserves feasibility for nodes the search did not reach.

/// Result of solving a transportation instance.
#[derive(Debug, Clone)]
pub struct FlowSolution {
    /// Row-major `(hp, hq)` optimal flow matrix.
    pub flow: Vec<f64>,
    /// Objective value Σ F·C.
    pub cost: f64,
    /// Augmentation count (diagnostics).
    pub augmentations: usize,
}

const EPS: f64 = 1e-12;

/// Solve `min Σ F C` s.t. out-flow = p, in-flow = q, F >= 0.
///
/// Requires Σp ≈ Σq (checked to 1e-6 relative).  `cost[i * hq + j]` is the
/// cost of edge (i, j); costs must be non-negative and finite.
pub fn solve_transport(p: &[f64], q: &[f64], cost: &[f32], hq: usize) -> FlowSolution {
    let hp = p.len();
    assert_eq!(q.len(), hq);
    assert_eq!(cost.len(), hp * hq);
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    assert!(
        (sp - sq).abs() <= 1e-6 * sp.max(sq).max(1.0),
        "unbalanced transportation instance: {sp} vs {sq}"
    );

    let n = hp + hq;
    let mut supply = p.to_vec();
    // Rescale demand so Σq matches Σp *exactly*: f32-normalized inputs are
    // only equal to ~1e-7, and any excess supply would otherwise be left
    // with no reachable demand ("disconnected" assert).
    let rescale = sp / sq;
    let mut demand: Vec<f64> = q.iter().map(|&x| x * rescale).collect();
    let mut flow = vec![0.0f64; hp * hq];
    // phi[v]: node potential; forward edge (i, j) reduced cost is
    // c_ij + phi[i] - phi[hp + j] >= 0 (invariant).
    let mut phi = vec![0.0f64; n];
    let mut augmentations = 0usize;

    let mut dist = vec![0.0f64; n];
    let mut parent = vec![usize::MAX; n];
    let mut done = vec![false; n];

    // Absolute mass-termination threshold: leaving 1e-10 of a unit of mass
    // unshipped perturbs the objective by <= 1e-10 * max(C).
    let stop = 1e-10 * sp.max(1.0);
    loop {
        let rem_supply: f64 = supply.iter().sum();
        if rem_supply <= stop {
            break;
        }
        // ---- multi-source Dijkstra over reduced costs -----------------------
        for v in 0..n {
            dist[v] = f64::INFINITY;
            parent[v] = usize::MAX;
            done[v] = false;
        }
        for i in 0..hp {
            if supply[i] > 0.0 {
                dist[i] = 0.0;
            }
        }
        loop {
            let mut best = usize::MAX;
            let mut bd = f64::INFINITY;
            for v in 0..n {
                if !done[v] && dist[v] < bd {
                    bd = dist[v];
                    best = v;
                }
            }
            if best == usize::MAX {
                break;
            }
            done[best] = true;
            if best < hp {
                let i = best;
                let base = i * hq;
                for j in 0..hq {
                    let rc = (cost[base + j] as f64 + phi[i] - phi[hp + j]).max(0.0);
                    let nd = dist[i] + rc;
                    if nd + EPS < dist[hp + j] {
                        dist[hp + j] = nd;
                        parent[hp + j] = i;
                    }
                }
            } else {
                let j = best - hp;
                for i in 0..hp {
                    if flow[i * hq + j] > EPS {
                        let rc =
                            (-(cost[i * hq + j] as f64 + phi[i] - phi[hp + j])).max(0.0);
                        let nd = dist[hp + j] + rc;
                        if nd + EPS < dist[i] {
                            dist[i] = nd;
                            parent[i] = hp + j;
                        }
                    }
                }
            }
        }

        // ---- cheapest reachable sink with remaining demand ------------------
        let mut tgt = usize::MAX;
        let mut td = f64::INFINITY;
        for j in 0..hq {
            if demand[j] > 0.0 && dist[hp + j] < td {
                td = dist[hp + j];
                tgt = hp + j;
            }
        }
        assert!(tgt != usize::MAX, "no augmenting path; instance disconnected?");

        // ---- bottleneck along the path --------------------------------------
        let mut bottleneck = demand[tgt - hp];
        {
            let mut v = tgt;
            loop {
                let u = parent[v];
                if u == usize::MAX {
                    bottleneck = bottleneck.min(supply[v]);
                    break;
                }
                if u >= hp {
                    // backward edge: v is a source, u a sink; bounded by flow
                    bottleneck = bottleneck.min(flow[v * hq + (u - hp)]);
                }
                v = u;
            }
        }

        // ---- apply the augmentation -----------------------------------------
        {
            let mut v = tgt;
            loop {
                let u = parent[v];
                if u == usize::MAX {
                    supply[v] -= bottleneck;
                    break;
                }
                if u < hp {
                    flow[u * hq + (v - hp)] += bottleneck;
                } else {
                    flow[v * hq + (u - hp)] -= bottleneck;
                }
                v = u;
            }
            demand[tgt - hp] -= bottleneck;
            // snap tiny residues so they don't linger as unreachable slivers
            let j = tgt - hp;
            if demand[j] < EPS {
                demand[j] = 0.0;
            }
            for s in supply.iter_mut() {
                if *s != 0.0 && *s < EPS {
                    *s = 0.0;
                }
            }
        }

        // ---- advance potentials (capped at the target distance) -------------
        for v in 0..n {
            phi[v] += dist[v].min(td);
        }
        augmentations += 1;
        assert!(
            augmentations <= 8 * (hp + hq) * (hp + hq),
            "augmentation budget exceeded — numerical cycling?"
        );
    }

    let total: f64 = flow.iter().zip(cost).map(|(&f, &c)| f * c as f64).sum();
    FlowSolution { flow, cost: total, augmentations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Prop};

    #[test]
    fn trivial_identity() {
        let cost = vec![0.0, 1.0, 1.0, 0.0];
        let s = solve_transport(&[0.5, 0.5], &[0.5, 0.5], &cost, 2);
        assert!(s.cost.abs() < 1e-12);
        assert!((s.flow[0] - 0.5).abs() < 1e-12);
        assert!((s.flow[3] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn forced_cross_shipment() {
        let cost = vec![1.0, 3.0];
        let s = solve_transport(&[1.0], &[0.25, 0.75], &cost, 2);
        assert!((s.cost - (0.25 * 1.0 + 0.75 * 3.0)).abs() < 1e-9);
    }

    #[test]
    fn rerouting_beats_greedy() {
        //      snk0  snk1
        // src0   0     1
        // src1  10   100
        let cost = vec![0.0, 1.0, 10.0, 100.0];
        let s = solve_transport(&[0.5, 0.5], &[0.5, 0.5], &cost, 2);
        // options: F00=.5,F11=.5 => 50 ; F01=.5,F10=.5 => 5.5  (optimal)
        assert!((s.cost - 5.5).abs() < 1e-9, "cost {}", s.cost);
    }

    #[test]
    fn mass_conservation() {
        let p = [0.2, 0.3, 0.5];
        let q = [0.6, 0.4];
        let cost = vec![1.0, 2.0, 3.0, 0.5, 2.5, 1.5];
        let s = solve_transport(&p, &q, &cost, 2);
        for i in 0..3 {
            let out: f64 = (0..2).map(|j| s.flow[i * 2 + j]).sum();
            assert!((out - p[i]).abs() < 1e-9);
        }
        for j in 0..2 {
            let inn: f64 = (0..3).map(|i| s.flow[i * 2 + j]).sum();
            assert!((inn - q[j]).abs() < 1e-9);
        }
        assert!(s.flow.iter().all(|&f| f >= -1e-12));
    }

    /// Cross-check against brute-force enumeration on 2x2 instances, where
    /// the optimum is min over the one-parameter family of feasible flows.
    #[test]
    fn optimal_on_random_2x2() {
        check("flow-2x2-optimal", 42, 200, |rng| {
            let p0 = rng.range_f64(0.05, 0.95);
            let q0 = rng.range_f64(0.05, 0.95);
            let p = [p0, 1.0 - p0];
            let q = [q0, 1.0 - q0];
            let c: Vec<f32> = (0..4).map(|_| rng.range_f64(0.0, 5.0) as f32).collect();
            let s = solve_transport(&p, &q, &c, 2);
            // F00 = t parametrizes all feasible flows:
            // t in [max(0, p0 - q1), min(p0, q0)]
            let lo = (p0 - (1.0 - q0)).max(0.0);
            let hi = p0.min(q0);
            let cost_at = |t: f64| {
                t * c[0] as f64
                    + (p0 - t) * c[1] as f64
                    + (q0 - t) * c[2] as f64
                    + ((1.0 - p0) - (q0 - t)) * c[3] as f64
            };
            let best = cost_at(lo).min(cost_at(hi)); // linear in t -> extreme
            ensure(
                (s.cost - best).abs() < 1e-7,
                || format!("solver {} vs brute {best}", s.cost),
            )
        });
    }

    /// Random larger instances: optimality cross-checked by verifying
    /// complementary slackness is achievable — here simply against a
    /// naive O(n!) assignment on tiny equal-mass instances.
    #[test]
    fn matches_assignment_on_permutation_instances() {
        check("flow-assignment", 7, 50, |rng| {
            let h = 4usize;
            let p = vec![1.0 / h as f64; h];
            let q = vec![1.0 / h as f64; h];
            let c: Vec<f32> = (0..h * h).map(|_| rng.range_f64(0.0, 3.0) as f32).collect();
            let s = solve_transport(&p, &q, &c, h);
            // brute force over permutations (Birkhoff: optimum at a vertex)
            let mut best = f64::INFINITY;
            let mut perm = [0usize, 1, 2, 3];
            permute(&mut perm, 0, &mut |pm| {
                let cost: f64 =
                    pm.iter().enumerate().map(|(i, &j)| c[i * h + j] as f64 / h as f64).sum();
                if cost < best {
                    best = cost;
                }
            });
            ensure(
                (s.cost - best).abs() < 1e-7,
                || format!("solver {} vs perm {best}", s.cost),
            )
        });
    }

    fn permute(xs: &mut [usize; 4], k: usize, f: &mut impl FnMut(&[usize; 4])) {
        if k == 4 {
            f(xs);
            return;
        }
        for i in k..4 {
            xs.swap(k, i);
            permute(xs, k + 1, f);
            xs.swap(k, i);
        }
    }

    #[test]
    #[should_panic(expected = "unbalanced")]
    fn unbalanced_panics() {
        solve_transport(&[1.0], &[0.5], &[0.0], 1);
    }
}
