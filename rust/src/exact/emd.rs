//! Exact Earth Mover's Distance (paper eq. (1)-(3)) on histogram pairs.
//!
//! This is the ground truth the approximation chain (Theorem 2) is checked
//! against, and the "WMD" comparator of the evaluation section: the paper's
//! WMD = exact EMD over word histograms (computed there via FastEMD); here
//! it is computed by the [`crate::exact::flow`] min-cost-flow solver, with
//! the same RWMD-based pruning trick Kusner et al. use to skip full EMD
//! computations during top-ℓ search.

use crate::approx::rwmd::rwmd_symmetric;
use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

use super::flow::solve_transport;

/// Exact EMD between two histograms over a shared vocabulary.
///
/// Histograms need not be normalized; they are normalized internally
/// (the paper assumes unit total mass).
pub fn emd(vocab: &Embeddings, p: &Histogram, q: &Histogram, metric: Metric) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    let pw: Vec<f64> = pn.weights().iter().map(|&w| w as f64).collect();
    let qw: Vec<f64> = qn.weights().iter().map(|&w| w as f64).collect();
    solve_transport(&pw, &qw, &cost, qw.len()).cost
}

/// Exact EMD given an explicit cost matrix (row-major `(hp, hq)`).
pub fn emd_with_cost(p: &[f32], q: &[f32], cost: &[f32], hq: usize) -> f64 {
    let sp: f64 = p.iter().map(|&x| x as f64).sum();
    let sq: f64 = q.iter().map(|&x| x as f64).sum();
    assert!(sp > 0.0 && sq > 0.0, "empty histogram");
    let pw: Vec<f64> = p.iter().map(|&x| x as f64 / sp).collect();
    let qw: Vec<f64> = q.iter().map(|&x| x as f64 / sq).collect();
    solve_transport(&pw, &qw, cost, hq).cost
}

/// Prune-accelerated top-ℓ exact-EMD search (the paper's "WMD" baseline).
///
/// For a query against `n` candidates: compute the cheap symmetric RWMD
/// lower bound for every candidate, seed the result heap with `l` exact
/// EMDs, then visit remaining candidates in ascending lower-bound order and
/// skip any whose lower bound already exceeds the current ℓ-th best exact
/// distance.  Returns `(sorted (distance, index) top-ℓ, exact_evals)`.
pub fn wmd_topl_pruned(
    vocab: &Embeddings,
    query: &Histogram,
    database: &[Histogram],
    metric: Metric,
    l: usize,
) -> (Vec<(f64, usize)>, usize) {
    let n = database.len();
    let l = l.min(n);
    if l == 0 {
        return (Vec::new(), 0);
    }
    let mut order: Vec<(f64, usize)> = database
        .iter()
        .enumerate()
        .map(|(u, h)| (rwmd_symmetric(vocab, query, h, metric), u))
        .collect();
    order.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut exact_evals = 0usize;
    // (distance, index) max-heap via sorted vec of size l (l is small)
    let mut best: Vec<(f64, usize)> = Vec::with_capacity(l + 1);
    for &(lb, u) in &order {
        if best.len() == l && lb >= best[l - 1].0 {
            break; // every remaining candidate is pruned by its lower bound
        }
        let d = emd(vocab, query, &database[u], metric);
        exact_evals += 1;
        let pos = best.partition_point(|&(bd, _)| bd <= d);
        best.insert(pos, (d, u));
        if best.len() > l {
            best.pop();
        }
    }
    (best, exact_evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Prop};
    use crate::util::rng::Rng;

    fn random_vocab(rng: &mut Rng, v: usize, m: usize) -> Embeddings {
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        Embeddings::new(data, v, m)
    }

    fn random_hist(rng: &mut Rng, v: usize, support: usize) -> Histogram {
        let idx = rng.sample_indices(v, support);
        Histogram::from_pairs(
            idx.into_iter().map(|i| (i as u32, rng.range_f64(0.05, 1.0) as f32)).collect(),
        )
    }

    #[test]
    fn emd_identical_is_zero() {
        let mut rng = Rng::new(1);
        let vocab = random_vocab(&mut rng, 20, 3);
        let h = random_hist(&mut rng, 20, 6);
        assert!(emd(&vocab, &h, &h, Metric::L2).abs() < 1e-9);
    }

    #[test]
    fn emd_symmetric_for_l2() {
        check("emd-symmetry", 11, 10, |rng| {
            let vocab = random_vocab(rng, 16, 2);
            let p = random_hist(rng, 16, 5);
            let q = random_hist(rng, 16, 5);
            let a = emd(&vocab, &p, &q, Metric::L2);
            let b = emd(&vocab, &q, &p, Metric::L2);
            // f32 costs + near-tie path selection: compare at 1e-6 relative
            ensure((a - b).abs() < 1e-6 * a.max(b).max(1.0), || format!("{a} vs {b}"))
        });
    }

    #[test]
    fn emd_point_masses_is_ground_distance() {
        let vocab = Embeddings::new(vec![0.0, 0.0, 3.0, 4.0], 2, 2);
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(1, 1.0)]);
        assert!((emd(&vocab, &p, &q, Metric::L2) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn emd_triangleish_on_point_masses() {
        // EMD between point masses is the ground metric, so the triangle
        // inequality must hold exactly there.
        let vocab = Embeddings::new(vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0], 3, 2);
        let a = Histogram::from_pairs(vec![(0, 1.0)]);
        let b = Histogram::from_pairs(vec![(1, 1.0)]);
        let c = Histogram::from_pairs(vec![(2, 1.0)]);
        let ab = emd(&vocab, &a, &b, Metric::L2);
        let bc = emd(&vocab, &b, &c, Metric::L2);
        let ac = emd(&vocab, &a, &c, Metric::L2);
        assert!(ac <= ab + bc + 1e-9);
    }

    #[test]
    fn wmd_pruned_matches_bruteforce() {
        let mut rng = Rng::new(5);
        let vocab = random_vocab(&mut rng, 24, 2);
        let query = random_hist(&mut rng, 24, 6);
        let db: Vec<Histogram> = (0..12).map(|_| random_hist(&mut rng, 24, 6)).collect();
        let (top, evals) = wmd_topl_pruned(&vocab, &query, &db, Metric::L2, 3);
        assert!(evals <= db.len());
        let mut brute: Vec<(f64, usize)> = db
            .iter()
            .enumerate()
            .map(|(u, h)| (emd(&vocab, &query, h, Metric::L2), u))
            .collect();
        brute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (got, want) in top.iter().zip(brute.iter().take(3)) {
            assert!((got.0 - want.0).abs() < 1e-7, "{top:?} vs {brute:?}");
        }
    }

    #[test]
    fn pruning_skips_work_on_separated_clusters() {
        // Two well-separated coordinate clusters: candidates living in the
        // far cluster have an RWMD lower bound above the ℓ-th best exact
        // distance, so the pruned search must evaluate far fewer than n.
        let mut rng = Rng::new(6);
        let v = 16;
        let mut emb = Vec::with_capacity(v * 2);
        for i in 0..v {
            let offset = if i < v / 2 { 0.0 } else { 100.0 };
            emb.push(offset + rng.normal() as f32);
            emb.push(offset + rng.normal() as f32);
        }
        let vocab = Embeddings::new(emb, v, 2);
        let near: Vec<Histogram> = (0..8)
            .map(|_| {
                let idx = rng.sample_indices(v / 2, 3);
                Histogram::from_pairs(idx.into_iter().map(|i| (i as u32, 1.0)).collect())
            })
            .collect();
        let far: Vec<Histogram> = (0..8)
            .map(|_| {
                let idx = rng.sample_indices(v / 2, 3);
                Histogram::from_pairs(
                    idx.into_iter().map(|i| ((i + v / 2) as u32, 1.0)).collect(),
                )
            })
            .collect();
        let mut db = near.clone();
        db.extend(far);
        let (top, evals) = wmd_topl_pruned(&vocab, &near[0], &db, Metric::L2, 2);
        assert_eq!(top.len(), 2);
        assert!(evals < db.len(), "pruning evaluated everything ({evals})");
        // the winners must come from the near cluster
        assert!(top.iter().all(|&(_, u)| u < 8), "{top:?}");
    }
}
