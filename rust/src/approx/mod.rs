//! Per-pair EMD approximations and baselines (paper Algorithms 1-3 plus the
//! comparison methods of Section 6).  These quadratic-per-pair forms define
//! the semantics the linear-complexity engines in [`crate::lc`] must match.

pub mod act;
pub mod adjusted;
pub mod bow;
pub mod ict;
pub mod omr;
pub mod rwmd;
pub mod sinkhorn;
pub mod wcd;

pub use act::{act_directed, act_symmetric, act_with_cost};
pub use adjusted::{bow_adjusted_directed, bow_adjusted_symmetric};
pub use bow::{bow_distance, bow_distances_batch, cosine_similarity};
pub use ict::{ict_directed, ict_symmetric, ict_with_cost};
pub use omr::{omr_directed, omr_symmetric, omr_with_cost};
pub use rwmd::{rwmd_directed, rwmd_symmetric, rwmd_with_cost};
pub use sinkhorn::{sinkhorn, sinkhorn_with_cost, SinkhornParams};
pub use wcd::{centroid, centroids_batch, wcd, wcd_from_centroids};
