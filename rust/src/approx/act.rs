//! Approximate Iterative Constrained Transfers (paper Algorithm 3): run
//! `k-1` capacity-constrained transfer iterations over the top-k nearest
//! destinations, then ship any remainder at the k-th smallest distance.
//! ACT-j in the paper's evaluation = `act_*` with `k = j + 1`.

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// Branchless-ish top-k smallest (value, index) selection for one row;
/// ties break to the lowest index — identical to the Pallas kernel and the
/// numpy oracle, so all three implementations agree bit-for-bit.
#[inline]
pub fn row_topk(row: &[f32], k: usize, vals: &mut Vec<f32>, idxs: &mut Vec<u32>) {
    vals.clear();
    idxs.clear();
    for (j, &c) in row.iter().enumerate() {
        // find insertion position among current top-k (vals ascending)
        if vals.len() < k {
            let pos = vals.partition_point(|&v| v <= c);
            vals.insert(pos, c);
            idxs.insert(pos, j as u32);
        } else if c < vals[k - 1] {
            let pos = vals.partition_point(|&v| v <= c);
            vals.pop();
            idxs.pop();
            vals.insert(pos, c);
            idxs.insert(pos, j as u32);
        }
    }
}

/// Directed ACT from normalized weights and a row-major cost matrix.
pub fn act_with_cost(p: &[f32], q: &[f32], cost: &[f32], hq: usize, k: usize) -> f64 {
    assert!(k >= 1);
    assert_eq!(cost.len(), p.len() * hq);
    assert_eq!(q.len(), hq);
    let k = k.min(hq);
    let mut total = 0.0f64;
    let mut vals: Vec<f32> = Vec::with_capacity(k);
    let mut idxs: Vec<u32> = Vec::with_capacity(k);
    for (i, &pi) in p.iter().enumerate() {
        if pi == 0.0 {
            continue;
        }
        let row = &cost[i * hq..(i + 1) * hq];
        row_topk(row, k, &mut vals, &mut idxs);
        let mut pi = pi as f64;
        for l in 0..k - 1 {
            let r = pi.min(q[idxs[l] as usize] as f64);
            pi -= r;
            total += r * vals[l] as f64;
        }
        if pi > 1e-15 {
            total += pi * vals[k - 1] as f64;
        }
    }
    total
}

/// Directed ACT between histograms over a shared vocabulary.
pub fn act_directed(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
    k: usize,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    act_with_cost(pn.weights(), qn.weights(), &cost, qn.len(), k)
}

/// Symmetric ACT = max of the two directions.
pub fn act_symmetric(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
    k: usize,
) -> f64 {
    act_directed(vocab, p, q, metric, k).max(act_directed(vocab, q, p, metric, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::ict::ict_with_cost;
    use crate::approx::rwmd::rwmd_with_cost;

    #[test]
    fn row_topk_orders_and_breaks_ties() {
        let mut vals = Vec::new();
        let mut idxs = Vec::new();
        row_topk(&[3.0, 1.0, 1.0, 0.5, 2.0], 3, &mut vals, &mut idxs);
        assert_eq!(vals, vec![0.5, 1.0, 1.0]);
        assert_eq!(idxs, vec![3, 1, 2]);
    }

    #[test]
    fn k1_equals_rwmd() {
        let p = [0.3f32, 0.7];
        let q = [0.5f32, 0.5];
        let cost = vec![0.2, 0.9, 0.4, 0.1];
        let act = act_with_cost(&p, &q, &cost, 2, 1);
        let rwmd = rwmd_with_cost(&p, &cost, 2);
        assert!((act - rwmd).abs() < 1e-12);
    }

    #[test]
    fn k_full_equals_ict() {
        let p = [0.3f32, 0.7];
        let q = [0.5f32, 0.5];
        let cost = vec![0.2, 0.9, 0.4, 0.1];
        let act = act_with_cost(&p, &q, &cost, 2, 2);
        let ict = ict_with_cost(&p, &q, &cost, 2);
        assert!((act - ict).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_k() {
        let p = [0.25f32, 0.25, 0.5];
        let q = [0.4f32, 0.3, 0.3];
        let cost = vec![0.1, 0.5, 0.9, 0.6, 0.2, 0.8, 0.3, 0.7, 0.4];
        let mut prev = 0.0;
        for k in 1..=3 {
            let v = act_with_cost(&p, &q, &cost, 3, k);
            assert!(v + 1e-12 >= prev, "k={k}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn oversized_k_clamps() {
        let p = [1.0f32];
        let q = [0.5f32, 0.5];
        let cost = vec![1.0, 2.0];
        let a = act_with_cost(&p, &q, &cost, 2, 10);
        let b = act_with_cost(&p, &q, &cost, 2, 2);
        assert_eq!(a, b);
    }
}
