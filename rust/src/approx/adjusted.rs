//! "BoW-adjusted" lower bound: the cheapest member of the bound chain.
//!
//! Directed form: the mass of `p` sitting on coordinates *outside* `q`'s
//! support (a pure bag-of-words quantity) times the minimum ground distance
//! from any such coordinate into `q`'s support.  Since directed RWMD ships
//! each of those bins to its *own* nearest destination at a cost at least
//! that minimum, and overlapping bins ship for free,
//!
//! ```text
//! bow_adjusted_directed(p, q) <= rwmd_directed(p, q)
//! ```
//!
//! holds bin-by-bin, which extends the Theorem-2 chain downwards:
//! BoW-adj <= RWMD <= OMR <= ACT-k <= ICT <= EMD.

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// One-directional BoW-adjusted bound (normalizes internally).
pub fn bow_adjusted_directed(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    let hq = qn.len();
    let qi = qn.indices();
    let mut mass_out = 0.0f64;
    let mut cmin = f64::INFINITY;
    for (i, (&pi, &pw)) in pn.indices().iter().zip(pn.weights()).enumerate() {
        if qi.binary_search(&pi).is_ok() {
            continue; // overlapping bin: ships for free under RWMD too
        }
        mass_out += pw as f64;
        for &c in &cost[i * hq..(i + 1) * hq] {
            if (c as f64) < cmin {
                cmin = c as f64;
            }
        }
    }
    if mass_out == 0.0 || !cmin.is_finite() {
        0.0
    } else {
        mass_out * cmin
    }
}

/// Symmetric BoW-adjusted bound = max of the two directions.
pub fn bow_adjusted_symmetric(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    bow_adjusted_directed(vocab, p, q, metric).max(bow_adjusted_directed(vocab, q, p, metric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::rwmd::{rwmd_directed, rwmd_symmetric};
    use crate::util::rng::Rng;

    fn vocab_line() -> Embeddings {
        Embeddings::new(vec![0.0, 1.0, 2.0, 3.0], 4, 1)
    }

    #[test]
    fn disjoint_singletons_equal_ground_distance() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(3, 1.0)]);
        assert!((bow_adjusted_directed(&vocab, &p, &q, Metric::L2) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn full_overlap_is_zero() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.7), (1, 0.3)]);
        let q = Histogram::from_pairs(vec![(0, 0.3), (1, 0.7)]);
        assert_eq!(bow_adjusted_symmetric(&vocab, &p, &q, Metric::L2), 0.0);
    }

    #[test]
    fn lower_bounds_rwmd_on_random_pairs() {
        let mut rng = Rng::new(0xB0A);
        for case in 0..50 {
            let v = 20;
            let m = 3;
            let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
            let vocab = Embeddings::new(data, v, m);
            let mk = |rng: &mut Rng| {
                let idx = rng.sample_indices(v, 6);
                Histogram::from_pairs(
                    idx.into_iter()
                        .map(|i| (i as u32, rng.range_f64(0.05, 1.0) as f32))
                        .collect(),
                )
            };
            let p = mk(&mut rng);
            let q = mk(&mut rng);
            let adj = bow_adjusted_directed(&vocab, &p, &q, Metric::L2);
            let rwmd = rwmd_directed(&vocab, &p, &q, Metric::L2);
            assert!(adj <= rwmd + 1e-9, "case {case}: adj {adj} > rwmd {rwmd}");
            let adj_s = bow_adjusted_symmetric(&vocab, &p, &q, Metric::L2);
            let rwmd_s = rwmd_symmetric(&vocab, &p, &q, Metric::L2);
            assert!(adj_s <= rwmd_s + 1e-9, "case {case}: sym {adj_s} > {rwmd_s}");
        }
    }
}
