//! Overlapping Mass Reduction (paper Algorithm 1): between overlapping
//! coordinates (`C[i,j] == 0`) at most `min(p_i, q_j)` moves for free; the
//! remainder ships to the second-closest coordinate.

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// Directed OMR from a normalized weight pair and a row-major cost matrix.
pub fn omr_with_cost(p: &[f32], q: &[f32], cost: &[f32], hq: usize) -> f64 {
    assert_eq!(cost.len(), p.len() * hq);
    assert_eq!(q.len(), hq);
    let mut total = 0.0f64;
    for (i, &pi) in p.iter().enumerate() {
        if pi == 0.0 {
            continue;
        }
        let row = &cost[i * hq..(i + 1) * hq];
        // top-2 smallest (value, index), ties -> lowest index
        let (mut v1, mut s1, mut v2) = (f32::INFINITY, usize::MAX, f32::INFINITY);
        for (j, &c) in row.iter().enumerate() {
            if c < v1 {
                v2 = v1;
                v1 = c;
                s1 = j;
            } else if c < v2 {
                v2 = c;
            }
        }
        let mut pi = pi as f64;
        if v1 == 0.0 {
            // free transfer of the overlapping mass, remainder to 2nd-closest
            let r = pi.min(q[s1] as f64);
            pi -= r;
            total += pi * if hq > 1 { v2 as f64 } else { 0.0 };
        } else {
            total += pi * v1 as f64;
        }
    }
    total
}

/// Directed OMR between histograms over a shared vocabulary.
pub fn omr_directed(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    omr_with_cost(pn.weights(), qn.weights(), &cost, qn.len())
}

/// Symmetric OMR = max of the two directions.
pub fn omr_symmetric(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    omr_directed(vocab, p, q, metric).max(omr_directed(vocab, q, p, metric))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab_line() -> Embeddings {
        Embeddings::new(vec![0.0, 1.0, 2.0, 3.0], 4, 1)
    }

    #[test]
    fn no_overlap_equals_rwmd() {
        use crate::approx::rwmd::rwmd_directed;
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.6), (1, 0.4)]);
        let q = Histogram::from_pairs(vec![(2, 0.5), (3, 0.5)]);
        let omr = omr_directed(&vocab, &p, &q, Metric::L2);
        let rwmd = rwmd_directed(&vocab, &p, &q, Metric::L2);
        assert!((omr - rwmd).abs() < 1e-12);
    }

    #[test]
    fn overlap_remainder_pays_second_closest() {
        let vocab = vocab_line();
        // p has 0.7 at coord 0; q has 0.3 at coord 0 and 0.7 at coord 1.
        let p = Histogram::from_pairs(vec![(0, 0.7), (1, 0.3)]);
        let q = Histogram::from_pairs(vec![(0, 0.3), (1, 0.7)]);
        // row i=0: overlap at j=0 (cost 0, cap 0.3): 0.4 remains -> 2nd
        // closest is coord 1 at distance 1 -> 0.4. row i=1: overlap at j=1
        // cap 0.7 >= 0.3 -> free.  total 0.4
        let omr = omr_directed(&vocab, &p, &q, Metric::L2);
        assert!((omr - 0.4).abs() < 1e-7, "omr {omr}");
    }

    #[test]
    fn effectiveness_theorem3() {
        // For an effective cost (distinct coords => positive cost),
        // OMR(p, q) == 0 implies p == q; so different weights => positive.
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.7), (1, 0.3)]);
        let q = Histogram::from_pairs(vec![(0, 0.3), (1, 0.7)]);
        assert!(omr_symmetric(&vocab, &p, &q, Metric::L2) > 0.0);
        assert_eq!(omr_symmetric(&vocab, &p, &p, Metric::L2), 0.0);
    }

    #[test]
    fn single_target_with_overlap_is_free() {
        // hq == 1 and full overlap: everything that fits moves free and the
        // paper's algorithm has no "second closest" — cost 0 by convention.
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(0, 1.0)]);
        assert_eq!(omr_directed(&vocab, &p, &q, Metric::L2), 0.0);
    }
}
