//! Entropy-regularized optimal transport via Sinkhorn iterations
//! (Cuturi 2013) — the GPU baseline of paper Fig. 8(b), λ = 20.
//!
//! K = exp(-λ C / max(C)) (the standard cost normalization Cuturi's
//! reference implementation applies so λ is scale-free), then alternate
//! u ← p ⊘ (K v), v ← q ⊘ (Kᵀ u) until the marginal violation drops below
//! `tol` or `max_iters` is reached.  Returns ⟨diag(u) K diag(v), C⟩.

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// Sinkhorn configuration.
#[derive(Debug, Clone, Copy)]
pub struct SinkhornParams {
    /// Entropic regularization strength (paper uses λ = 20).
    pub lambda: f64,
    pub max_iters: usize,
    /// L1 marginal violation tolerance.
    pub tol: f64,
}

impl Default for SinkhornParams {
    fn default() -> Self {
        SinkhornParams { lambda: 20.0, max_iters: 200, tol: 1e-6 }
    }
}

/// Sinkhorn distance from normalized weights and a row-major cost matrix.
/// Returns `(distance, iterations_used)`.
pub fn sinkhorn_with_cost(
    p: &[f32],
    q: &[f32],
    cost: &[f32],
    hq: usize,
    params: SinkhornParams,
) -> (f64, usize) {
    let hp = p.len();
    assert_eq!(cost.len(), hp * hq);
    assert_eq!(q.len(), hq);
    let cmax = cost.iter().cloned().fold(0.0f32, f32::max).max(1e-30) as f64;

    // Gibbs kernel; guard against full underflow with a floor.
    let mut kmat = vec![0.0f64; hp * hq];
    for (slot, &c) in kmat.iter_mut().zip(cost) {
        *slot = (-(params.lambda) * c as f64 / cmax).exp().max(1e-300);
    }

    let pv: Vec<f64> = p.iter().map(|&x| x as f64).collect();
    let qv: Vec<f64> = q.iter().map(|&x| x as f64).collect();
    let mut u = vec![1.0f64; hp];
    let mut v = vec![1.0f64; hq];
    let mut kv = vec![0.0f64; hp];
    let mut ktu = vec![0.0f64; hq];
    let mut iters = 0;
    for it in 0..params.max_iters {
        iters = it + 1;
        // u = p ./ (K v)
        for i in 0..hp {
            let row = &kmat[i * hq..(i + 1) * hq];
            let mut acc = 0.0;
            for (j, &kij) in row.iter().enumerate() {
                acc += kij * v[j];
            }
            kv[i] = acc.max(1e-300);
            u[i] = pv[i] / kv[i];
        }
        // v = q ./ (K^T u)
        ktu.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..hp {
            let row = &kmat[i * hq..(i + 1) * hq];
            let ui = u[i];
            for (j, &kij) in row.iter().enumerate() {
                ktu[j] += kij * ui;
            }
        }
        let mut violation = 0.0;
        for j in 0..hq {
            let denom = ktu[j].max(1e-300);
            // in-marginal before update: v_j * ktu_j should equal q_j
            violation += (v[j] * ktu[j] - qv[j]).abs();
            v[j] = qv[j] / denom;
        }
        if violation < params.tol {
            break;
        }
    }

    // transport cost <diag(u) K diag(v), C>
    let mut total = 0.0f64;
    for i in 0..hp {
        let row_k = &kmat[i * hq..(i + 1) * hq];
        let row_c = &cost[i * hq..(i + 1) * hq];
        let ui = u[i];
        for j in 0..hq {
            total += ui * row_k[j] * v[j] * row_c[j] as f64;
        }
    }
    (total, iters)
}

/// Sinkhorn distance between histograms over a shared vocabulary.
pub fn sinkhorn(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
    params: SinkhornParams,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    sinkhorn_with_cost(pn.weights(), qn.weights(), &cost, qn.len(), params).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exact::emd_with_cost;

    #[test]
    fn identical_histograms_near_zero() {
        let p = [0.5f32, 0.5];
        let cost = vec![0.0, 1.0, 1.0, 0.0];
        let (d, _) = sinkhorn_with_cost(&p, &p, &cost, 2, SinkhornParams::default());
        assert!(d < 0.05, "d = {d}");
    }

    #[test]
    fn approaches_emd_as_lambda_grows() {
        let p = [0.3f32, 0.7];
        let q = [0.6f32, 0.4];
        let cost = vec![0.1, 0.8, 0.9, 0.2];
        let exact = emd_with_cost(&p, &q, &cost, 2);
        let mut prev_err = f64::INFINITY;
        for lambda in [5.0, 20.0, 80.0] {
            let (d, _) = sinkhorn_with_cost(
                &p,
                &q,
                &cost,
                2,
                SinkhornParams { lambda, max_iters: 2000, tol: 1e-10 },
            );
            let err = (d - exact).abs();
            assert!(err <= prev_err + 1e-9, "λ={lambda}: err {err} > {prev_err}");
            prev_err = err;
        }
        assert!(prev_err < 0.02, "sinkhorn(λ=80) error {prev_err}");
    }

    #[test]
    fn regularized_cost_upper_bounds_loosely() {
        // Sinkhorn's plan is feasible for the original LP, so its transport
        // cost is >= exact EMD (up to numerical tolerance).
        let p = [0.25f32, 0.25, 0.5];
        let q = [0.4f32, 0.3, 0.3];
        let cost = vec![0.1, 0.5, 0.9, 0.6, 0.2, 0.8, 0.3, 0.7, 0.4];
        let exact = emd_with_cost(&p, &q, &cost, 3);
        let (d, _) =
            sinkhorn_with_cost(&p, &q, &cost, 3, SinkhornParams { lambda: 50.0, ..Default::default() });
        assert!(d >= exact - 1e-6, "sinkhorn {d} < emd {exact}");
    }

    #[test]
    fn converges_within_budget() {
        let p = [0.2f32, 0.3, 0.5];
        let q = [0.5f32, 0.25, 0.25];
        let cost = vec![0.5, 0.1, 0.9, 0.3, 0.7, 0.2, 0.8, 0.4, 0.6];
        let (_, iters) = sinkhorn_with_cost(&p, &q, &cost, 3, SinkhornParams::default());
        assert!(iters < 200, "did not converge: {iters}");
    }
}
