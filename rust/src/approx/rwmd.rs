//! Relaxed Word Mover's Distance (paper Section 2.1): drop the in-flow
//! constraints entirely; every bin of `p` ships to its nearest bin of `q`.
//! Quadratic per-pair form; the batched linear-complexity version lives in
//! [`crate::lc`].

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// One-directional RWMD from a normalized weight vector and a row-major
/// `(hp, hq)` cost matrix: `Σ_i p_i · min_j C[i, j]`.
pub fn rwmd_with_cost(p: &[f32], cost: &[f32], hq: usize) -> f64 {
    assert_eq!(cost.len(), p.len() * hq);
    let mut total = 0.0f64;
    for (i, &pi) in p.iter().enumerate() {
        if pi == 0.0 {
            continue;
        }
        let row = &cost[i * hq..(i + 1) * hq];
        let mut min = f32::INFINITY;
        for &c in row {
            if c < min {
                min = c;
            }
        }
        total += pi as f64 * min as f64;
    }
    total
}

/// One-directional RWMD between histograms over a shared vocabulary
/// (normalizes internally).
pub fn rwmd_directed(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    rwmd_with_cost(pn.weights(), &cost, qn.len())
}

/// Symmetric RWMD = max of the two directed bounds (paper Section 2.1).
pub fn rwmd_symmetric(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    rwmd_directed(vocab, p, q, metric).max(rwmd_directed(vocab, q, p, metric))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab_line() -> Embeddings {
        // coords 0,1,2,3 on a line
        Embeddings::new(vec![0.0, 1.0, 2.0, 3.0], 4, 1)
    }

    #[test]
    fn ships_to_nearest() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(1, 0.5), (3, 0.5)]);
        // bin 0 ships to coord 1 at distance 1 regardless of weights
        assert!((rwmd_directed(&vocab, &p, &q, Metric::L2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn asymmetry_and_max() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(1, 1.0), (3, 1.0)]);
        let pq = rwmd_directed(&vocab, &p, &q, Metric::L2); // 1.0
        let qp = rwmd_directed(&vocab, &q, &p, Metric::L2); // 0.5*1 + 0.5*3
        assert!((pq - 1.0).abs() < 1e-9);
        assert!((qp - 2.0).abs() < 1e-9);
        assert!((rwmd_symmetric(&vocab, &p, &q, Metric::L2) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn full_overlap_collapses_to_zero() {
        // Paper Fig. 3: identical coordinates, different weights -> 0.
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.7), (1, 0.3)]);
        let q = Histogram::from_pairs(vec![(0, 0.3), (1, 0.7)]);
        assert_eq!(rwmd_symmetric(&vocab, &p, &q, Metric::L2), 0.0);
    }

    #[test]
    fn identical_histograms_zero() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.5), (2, 0.5)]);
        assert_eq!(rwmd_symmetric(&vocab, &p, &p, Metric::L2), 0.0);
    }
}
