//! Word Centroid Distance baseline (Kusner et al. 2015, used in paper
//! Fig. 8): Euclidean distance between the weighted centroid embeddings of
//! two documents.  O(m) per comparison once centroids are precomputed.

use crate::core::{CsrMatrix, Embeddings, Histogram};
use crate::util::threadpool::{parallel_for, SyncSlice};

/// Weighted centroid of a normalized histogram in embedding space.
pub fn centroid(vocab: &Embeddings, h: &Histogram) -> Vec<f64> {
    let hn = h.normalized();
    vocab.centroid(hn.indices(), hn.weights())
}

/// Centroids for every row of a database matrix, row-major `(n, m)`,
/// data-parallel over database rows.  This `O(nnz·m)` pass sits on the
/// engine-build path and is the training input of the IVF pruning index,
/// so it no longer runs serially.  Each row's accumulation order is
/// unchanged, so any thread count produces bit-identical output.
pub fn centroids_batch(vocab: &Embeddings, db: &CsrMatrix, threads: usize) -> Vec<f64> {
    let m = vocab.dim();
    let n = db.nrows();
    let mut out = vec![0.0f64; n * m];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(n, threads, |start, end| {
            for u in start..end {
                let (idx, w) = db.row(u);
                let total: f64 = w.iter().map(|&x| x as f64).sum();
                if total == 0.0 {
                    continue;
                }
                // SAFETY: row u is owned by exactly this chunk.
                let slot = unsafe { slots.slice_mut(u * m, (u + 1) * m) };
                for (&i, &x) in idx.iter().zip(w) {
                    let row = vocab.row(i as usize);
                    let wgt = x as f64 / total;
                    for (acc, &e) in slot.iter_mut().zip(row) {
                        *acc += wgt * e as f64;
                    }
                }
            }
        });
    }
    out
}

/// WCD between two precomputed centroids.
pub fn wcd_from_centroids(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// WCD between two histograms.
pub fn wcd(vocab: &Embeddings, p: &Histogram, q: &Histogram) -> f64 {
    wcd_from_centroids(&centroid(vocab, p), &centroid(vocab, q))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Embeddings {
        Embeddings::new(vec![0.0, 0.0, 2.0, 0.0, 0.0, 2.0], 3, 2)
    }

    #[test]
    fn identical_zero() {
        let h = Histogram::from_pairs(vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(wcd(&vocab(), &h, &h), 0.0);
    }

    #[test]
    fn centroid_of_point_mass_is_coordinate() {
        let h = Histogram::from_pairs(vec![(1, 2.0)]);
        assert_eq!(centroid(&vocab(), &h), vec![2.0, 0.0]);
    }

    #[test]
    fn wcd_lower_bounds_emd_wmd_relation() {
        // WCD <= WMD (Kusner et al.): check against exact EMD on a tiny case.
        use crate::core::Metric;
        use crate::exact::emd;
        let v = vocab();
        let p = Histogram::from_pairs(vec![(0, 0.5), (1, 0.5)]);
        let q = Histogram::from_pairs(vec![(1, 0.5), (2, 0.5)]);
        let wcd_d = wcd(&v, &p, &q);
        let emd_d = emd(&v, &p, &q, Metric::L2);
        assert!(wcd_d <= emd_d + 1e-9, "wcd {wcd_d} > emd {emd_d}");
    }

    #[test]
    fn batch_matches_single() {
        let rows = vec![
            Histogram::from_pairs(vec![(0, 1.0)]),
            Histogram::from_pairs(vec![(0, 1.0), (2, 3.0)]),
        ];
        let db = CsrMatrix::from_histograms(&rows, 3);
        let cents = centroids_batch(&vocab(), &db, 2);
        for (u, row) in rows.iter().enumerate() {
            let single = centroid(&vocab(), row);
            assert_eq!(&cents[u * 2..(u + 1) * 2], single.as_slice());
        }
    }

    #[test]
    fn parallel_centroids_match_serial_bit_exactly() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let v = 64;
        let m = 7;
        let emb = Embeddings::new((0..v * m).map(|_| rng.normal() as f32).collect(), v, m);
        let rows: Vec<Histogram> = (0..97)
            .map(|_| {
                let idx = rng.sample_indices(v, 9);
                Histogram::from_pairs(
                    idx.into_iter()
                        .map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32))
                        .collect(),
                )
            })
            .collect();
        let db = CsrMatrix::from_histograms(&rows, v);
        let serial = centroids_batch(&emb, &db, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(centroids_batch(&emb, &db, threads), serial, "threads {threads}");
        }
    }
}
