//! Word Centroid Distance baseline (Kusner et al. 2015, used in paper
//! Fig. 8): Euclidean distance between the weighted centroid embeddings of
//! two documents.  O(m) per comparison once centroids are precomputed.

use crate::core::{CsrMatrix, Embeddings, Histogram};

/// Weighted centroid of a normalized histogram in embedding space.
pub fn centroid(vocab: &Embeddings, h: &Histogram) -> Vec<f64> {
    let hn = h.normalized();
    vocab.centroid(hn.indices(), hn.weights())
}

/// Centroids for every row of a database matrix, row-major `(n, m)`.
pub fn centroids_batch(vocab: &Embeddings, db: &CsrMatrix) -> Vec<f64> {
    let m = vocab.dim();
    let mut out = vec![0.0f64; db.nrows() * m];
    for u in 0..db.nrows() {
        let (idx, w) = db.row(u);
        let total: f64 = w.iter().map(|&x| x as f64).sum();
        if total == 0.0 {
            continue;
        }
        let slot = &mut out[u * m..(u + 1) * m];
        for (&i, &x) in idx.iter().zip(w) {
            let row = vocab.row(i as usize);
            let wgt = x as f64 / total;
            for (acc, &e) in slot.iter_mut().zip(row) {
                *acc += wgt * e as f64;
            }
        }
    }
    out
}

/// WCD between two precomputed centroids.
pub fn wcd_from_centroids(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// WCD between two histograms.
pub fn wcd(vocab: &Embeddings, p: &Histogram, q: &Histogram) -> f64 {
    wcd_from_centroids(&centroid(vocab, p), &centroid(vocab, q))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Embeddings {
        Embeddings::new(vec![0.0, 0.0, 2.0, 0.0, 0.0, 2.0], 3, 2)
    }

    #[test]
    fn identical_zero() {
        let h = Histogram::from_pairs(vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(wcd(&vocab(), &h, &h), 0.0);
    }

    #[test]
    fn centroid_of_point_mass_is_coordinate() {
        let h = Histogram::from_pairs(vec![(1, 2.0)]);
        assert_eq!(centroid(&vocab(), &h), vec![2.0, 0.0]);
    }

    #[test]
    fn wcd_lower_bounds_emd_wmd_relation() {
        // WCD <= WMD (Kusner et al.): check against exact EMD on a tiny case.
        use crate::core::Metric;
        use crate::exact::emd;
        let v = vocab();
        let p = Histogram::from_pairs(vec![(0, 0.5), (1, 0.5)]);
        let q = Histogram::from_pairs(vec![(1, 0.5), (2, 0.5)]);
        let wcd_d = wcd(&v, &p, &q);
        let emd_d = emd(&v, &p, &q, Metric::L2);
        assert!(wcd_d <= emd_d + 1e-9, "wcd {wcd_d} > emd {emd_d}");
    }

    #[test]
    fn batch_matches_single() {
        let rows = vec![
            Histogram::from_pairs(vec![(0, 1.0)]),
            Histogram::from_pairs(vec![(0, 1.0), (2, 3.0)]),
        ];
        let db = CsrMatrix::from_histograms(&rows, 3);
        let cents = centroids_batch(&vocab(), &db);
        for (u, row) in rows.iter().enumerate() {
            let single = centroid(&vocab(), row);
            assert_eq!(&cents[u * 2..(u + 1) * 2], single.as_slice());
        }
    }
}
