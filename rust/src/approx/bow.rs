//! Bag-of-Words cosine baseline (paper Section 6): plain sparse dot product
//! after L2 normalization — no embedding proximity information.  Reported
//! as a *distance* (1 - cosine) so smaller is better, matching the other
//! measures' orientation in the evaluation harness.

use crate::core::{CsrMatrix, Histogram};

/// Cosine similarity between two sparse histograms (merge join).
pub fn cosine_similarity(a: &Histogram, b: &Histogram) -> f64 {
    let (ai, aw) = (a.indices(), a.weights());
    let (bi, bw) = (b.indices(), b.weights());
    let mut dot = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < ai.len() && j < bi.len() {
        match ai[i].cmp(&bi[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                dot += aw[i] as f64 * bw[j] as f64;
                i += 1;
                j += 1;
            }
        }
    }
    let na = aw.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let nb = bw.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// BoW cosine distance: `1 - cos`.
pub fn bow_distance(a: &Histogram, b: &Histogram) -> f64 {
    (1.0 - cosine_similarity(a, b)).max(0.0)
}

/// Batched: distances from one query to every row of the database matrix.
/// O(nnz) with precomputed row norms.
pub fn bow_distances_batch(query: &Histogram, db: &CsrMatrix, row_norms: &[f32]) -> Vec<f64> {
    assert_eq!(row_norms.len(), db.nrows());
    let qn = query
        .weights()
        .iter()
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt();
    let mut out = vec![1.0f64; db.nrows()];
    if qn == 0.0 {
        return out;
    }
    // scatter the query into a dense lookup once: O(v) space, O(nnz) time
    let mut dense_q = vec![0.0f32; db.ncols()];
    for (i, w) in query.iter() {
        dense_q[i as usize] = w;
    }
    for u in 0..db.nrows() {
        let (idx, w) = db.row(u);
        let mut dot = 0.0f64;
        for (&i, &x) in idx.iter().zip(w) {
            dot += dense_q[i as usize] as f64 * x as f64;
        }
        let norm = row_norms[u] as f64;
        out[u] = if norm > 0.0 { (1.0 - dot / (qn * norm)).max(0.0) } else { 1.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_zero_distance() {
        let h = Histogram::from_pairs(vec![(0, 0.5), (3, 0.5)]);
        assert!((bow_distance(&h, &h)).abs() < 1e-12);
    }

    #[test]
    fn disjoint_is_max_distance() {
        let a = Histogram::from_pairs(vec![(0, 1.0)]);
        let b = Histogram::from_pairs(vec![(1, 1.0)]);
        assert!((bow_distance(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scale_invariant() {
        let a = Histogram::from_pairs(vec![(0, 1.0), (1, 2.0)]);
        let b = Histogram::from_pairs(vec![(0, 10.0), (1, 20.0)]);
        assert!(bow_distance(&a, &b).abs() < 1e-9);
    }

    #[test]
    fn batch_matches_pairwise() {
        let rows = vec![
            Histogram::from_pairs(vec![(0, 1.0), (2, 1.0)]),
            Histogram::from_pairs(vec![(1, 1.0)]),
            Histogram::from_pairs(vec![(0, 0.3), (1, 0.3), (2, 0.4)]),
        ];
        let db = CsrMatrix::from_histograms(&rows, 3);
        let norms = db.row_l2_norms();
        let q = Histogram::from_pairs(vec![(0, 0.6), (1, 0.4)]);
        let batch = bow_distances_batch(&q, &db, &norms);
        for (u, row) in rows.iter().enumerate() {
            assert!((batch[u] - bow_distance(&q, row)).abs() < 1e-6); // f32 norms
        }
    }
}
