//! Iterative Constrained Transfers (paper Algorithm 2): relax the in-flow
//! constraints to per-edge capacities `F[i,j] <= q_j` (eq. (4)); each source
//! bin greedily fills the cheapest destinations.  Optimal for the relaxed
//! LP (Theorem 1) and the tightest member of the approximation family.

use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};

/// Directed ICT from normalized weights and a row-major cost matrix.
pub fn ict_with_cost(p: &[f32], q: &[f32], cost: &[f32], hq: usize) -> f64 {
    assert_eq!(cost.len(), p.len() * hq);
    assert_eq!(q.len(), hq);
    let mut order: Vec<u32> = (0..hq as u32).collect();
    let mut total = 0.0f64;
    for (i, &pi) in p.iter().enumerate() {
        if pi == 0.0 {
            continue;
        }
        let row = &cost[i * hq..(i + 1) * hq];
        // stable sort by cost, ties -> lowest index (matches the kernels)
        order.sort_by(|&a, &b| {
            row[a as usize].partial_cmp(&row[b as usize]).unwrap().then(a.cmp(&b))
        });
        let mut pi = pi as f64;
        for &j in order.iter() {
            if pi <= 1e-15 {
                break;
            }
            let r = pi.min(q[j as usize] as f64);
            pi -= r;
            total += r * row[j as usize] as f64;
        }
        // reset order for the next row (sort is in-place)
        for (slot, j) in order.iter_mut().zip(0u32..) {
            *slot = j;
        }
    }
    total
}

/// Directed ICT between histograms over a shared vocabulary.
pub fn ict_directed(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    let pn = p.normalized();
    let qn = q.normalized();
    if pn.is_empty() || qn.is_empty() {
        return 0.0;
    }
    let cost = support_cost_matrix(vocab, pn.indices(), qn.indices(), metric);
    ict_with_cost(pn.weights(), qn.weights(), &cost, qn.len())
}

/// Symmetric ICT = max of the two directions.
pub fn ict_symmetric(
    vocab: &Embeddings,
    p: &Histogram,
    q: &Histogram,
    metric: Metric,
) -> f64 {
    ict_directed(vocab, p, q, metric).max(ict_directed(vocab, q, p, metric))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab_line() -> Embeddings {
        Embeddings::new(vec![0.0, 1.0, 2.0, 3.0], 4, 1)
    }

    #[test]
    fn fills_cheapest_first_with_capacity() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 1.0)]);
        let q = Histogram::from_pairs(vec![(1, 0.25), (2, 0.75)]);
        // 0.25 at distance 1, then 0.75 at distance 2 -> 1.75
        let v = ict_directed(&vocab, &p, &q, Metric::L2);
        assert!((v - 1.75).abs() < 1e-7, "{v}");
    }

    #[test]
    fn identical_histograms_zero() {
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.4), (2, 0.6)]);
        assert!(ict_symmetric(&vocab, &p, &p, Metric::L2).abs() < 1e-12);
    }

    #[test]
    fn dense_overlap_detects_difference() {
        // RWMD's Fig.-3 blind spot: ICT must see it.
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.7), (1, 0.3)]);
        let q = Histogram::from_pairs(vec![(0, 0.3), (1, 0.7)]);
        assert!(ict_symmetric(&vocab, &p, &q, Metric::L2) > 0.0);
    }

    #[test]
    fn matches_exact_emd_on_line_instance() {
        // On 1-D with convex cost, the greedy constrained transfer achieves
        // EMD for this particular simple case.
        use crate::exact::emd;
        let vocab = vocab_line();
        let p = Histogram::from_pairs(vec![(0, 0.5), (3, 0.5)]);
        let q = Histogram::from_pairs(vec![(1, 0.5), (2, 0.5)]);
        let ict = ict_symmetric(&vocab, &p, &q, Metric::L2);
        let exact = emd(&vocab, &p, &q, Metric::L2);
        assert!(ict <= exact + 1e-9);
        assert!((ict - exact).abs() < 1e-7, "ict {ict} vs emd {exact}");
    }
}
