//! Admission control: a bounded in-flight budget for the serving runtime.
//!
//! Every search admitted into the compute bridge holds a [`Permit`]; when
//! the budget is exhausted new searches are shed *immediately* with a
//! structured `overloaded` error instead of queueing without bound.  The
//! permit is RAII — it travels with the job through the batcher and the
//! dispatcher and releases its slot wherever the job ends (delivered, shed
//! at a deadline, or dropped with a dead connection), so the budget can
//! never leak.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared in-flight budget.  Clones observe the same budget.
#[derive(Clone)]
pub struct Admission {
    max: usize,
    inflight: Arc<AtomicUsize>,
}

/// RAII token for one admitted request; releases its slot on drop.
pub struct Permit {
    inflight: Arc<AtomicUsize>,
}

impl Admission {
    pub fn new(max_inflight: usize) -> Admission {
        Admission { max: max_inflight.max(1), inflight: Arc::new(AtomicUsize::new(0)) }
    }

    /// Try to admit one request.  `None` means the caller must shed.
    pub fn try_admit(&self) -> Option<Permit> {
        let prev = self.inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= self.max {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            None
        } else {
            Some(Permit { inflight: Arc::clone(&self.inflight) })
        }
    }

    /// Requests currently holding permits.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// The configured budget (`/readyz` reports saturation against this).
    pub fn capacity(&self) -> usize {
        self.max
    }

    /// True when every slot is taken — new requests are being shed right
    /// now, so a readiness probe should steer traffic away.
    pub fn saturated(&self) -> bool {
        self.in_flight() >= self.max
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_budget_then_sheds() {
        let adm = Admission::new(2);
        let a = adm.try_admit().expect("slot 1");
        let b = adm.try_admit().expect("slot 2");
        assert!(adm.try_admit().is_none(), "budget exhausted");
        assert_eq!(adm.in_flight(), 2);
        drop(a);
        let c = adm.try_admit().expect("slot freed by drop");
        assert!(adm.try_admit().is_none());
        drop(b);
        drop(c);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn zero_budget_clamps_to_one() {
        let adm = Admission::new(0);
        let p = adm.try_admit().expect("clamped to at least one slot");
        assert!(adm.try_admit().is_none());
        drop(p);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn contended_admission_never_exceeds_budget() {
        let adm = Admission::new(8);
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let adm = adm.clone();
                let peak = Arc::clone(&peak);
                s.spawn(move || {
                    for _ in 0..500 {
                        if let Some(p) = adm.try_admit() {
                            peak.fetch_max(adm.in_flight(), Ordering::AcqRel);
                            drop(p);
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Acquire) <= 8, "budget must bound in-flight");
        assert_eq!(adm.in_flight(), 0);
    }
}
