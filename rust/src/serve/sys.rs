//! Readiness multiplexing substrate for the reactor runtime.
//!
//! The crate is dependency-free, so on unix the reactor talks to POSIX
//! `poll(2)` through a direct `extern "C"` binding, with a self-pipe for
//! cross-thread wakeups (the classic trick: the poller always watches the
//! read end of a pipe; any thread wakes it by writing one byte).  On
//! non-unix targets a condvar-timed fallback reports every registered fd as
//! ready at a coarse cadence — the nonblocking connection state machines
//! then simply hit `WouldBlock`, so the runtime stays correct (just less
//! efficient) without any platform bindings.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide graceful-shutdown flag; see [`arm_shutdown_signals`].
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// The flag `emdpar serve` polls in its accept loop: flipped by
/// SIGINT/SIGTERM once [`arm_shutdown_signals`] has run, or
/// programmatically by [`request_shutdown`].
pub fn shutdown_flag() -> &'static AtomicBool {
    &SHUTDOWN
}

/// Request a graceful shutdown (the signal handler's body, and the test
/// hook).
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::Release);
}

/// Install SIGINT/SIGTERM handlers that flip [`shutdown_flag`].  The
/// handler body is a single atomic store — async-signal-safe.  On
/// non-unix targets this is a no-op and Ctrl-C terminates the process as
/// before.
#[cfg(unix)]
pub fn arm_shutdown_signals() {
    use std::os::raw::c_int;
    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;
    extern "C" fn on_signal(_sig: c_int) {
        SHUTDOWN.store(true, Ordering::Release);
    }
    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Non-unix targets: no signal bindings; shutdown stays programmatic.
#[cfg(not(unix))]
pub fn arm_shutdown_signals() {}

/// What a registration wants to be told about.
#[derive(Debug, Clone, Copy, Default)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

/// One readiness report.  `readable` is also set on error/hangup so the
/// owner's next `read` surfaces the condition.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// Raw descriptor/socket handle, per platform.
#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
#[cfg(windows)]
pub type Fd = std::os::windows::io::RawSocket;
#[cfg(not(any(unix, windows)))]
pub type Fd = i32;

/// The pollable handle of a stream.
pub fn fd_of(stream: &TcpStream) -> Fd {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        stream.as_raw_fd()
    }
    #[cfg(windows)]
    {
        use std::os::windows::io::AsRawSocket;
        stream.as_raw_socket()
    }
    #[cfg(not(any(unix, windows)))]
    {
        let _ = stream;
        -1
    }
}

#[cfg(unix)]
pub use unix_impl::{Poller, Waker};

#[cfg(unix)]
mod unix_impl {
    use super::{Event, Fd, Interest};
    use std::io;
    use std::os::raw::{c_int, c_short, c_void};
    use std::sync::Arc;
    use std::time::Duration;

    #[cfg(any(target_os = "macos", target_os = "ios"))]
    type Nfds = std::os::raw::c_uint;
    #[cfg(not(any(target_os = "macos", target_os = "ios")))]
    type Nfds = std::os::raw::c_ulong;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    #[cfg(any(target_os = "macos", target_os = "ios"))]
    const O_NONBLOCK: c_int = 0x0004;
    #[cfg(not(any(target_os = "macos", target_os = "ios")))]
    const O_NONBLOCK: c_int = 0o4000;

    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
    }

    /// Both ends of the self-pipe; closed together so a live [`Waker`] can
    /// never write into a recycled descriptor.
    struct PipePair {
        rd: c_int,
        wr: c_int,
    }

    impl Drop for PipePair {
        fn drop(&mut self) {
            unsafe {
                close(self.rd);
                close(self.wr);
            }
        }
    }

    fn set_nonblocking(fd: c_int) -> io::Result<()> {
        unsafe {
            let flags = fcntl(fd, F_GETFL);
            if flags < 0 {
                return Err(io::Error::last_os_error());
            }
            if fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
                return Err(io::Error::last_os_error());
            }
        }
        Ok(())
    }

    /// `poll(2)`-backed readiness source with self-pipe wakeups.
    pub struct Poller {
        pipe: Arc<PipePair>,
        scratch: Vec<PollFd>,
    }

    /// Cloneable cross-thread wakeup handle for one [`Poller`].
    #[derive(Clone)]
    pub struct Waker {
        pipe: Arc<PipePair>,
    }

    impl Waker {
        /// Wake the poller.  A full pipe means a wake is already pending, so
        /// every error is ignorable.
        pub fn wake(&self) {
            let byte = [1u8];
            unsafe {
                let _ = write(self.pipe.wr, byte.as_ptr() as *const c_void, 1);
            }
        }
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let mut fds = [0 as c_int; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
                return Err(io::Error::last_os_error());
            }
            let pair = PipePair { rd: fds[0], wr: fds[1] };
            set_nonblocking(pair.rd)?;
            set_nonblocking(pair.wr)?;
            Ok(Poller { pipe: Arc::new(pair), scratch: Vec::new() })
        }

        pub fn waker(&self) -> Waker {
            Waker { pipe: Arc::clone(&self.pipe) }
        }

        /// Block until a registered fd is ready, the poller is woken, or
        /// `timeout` elapses.  Readiness lands in `events`; returns whether
        /// a wakeup was consumed.
        pub fn wait(
            &mut self,
            regs: &[(Fd, usize, Interest)],
            timeout: Option<Duration>,
            events: &mut Vec<Event>,
        ) -> io::Result<bool> {
            events.clear();
            self.scratch.clear();
            self.scratch.push(PollFd { fd: self.pipe.rd, events: POLLIN, revents: 0 });
            for &(fd, _, interest) in regs {
                let mut ev: c_short = 0;
                if interest.read {
                    ev |= POLLIN;
                }
                if interest.write {
                    ev |= POLLOUT;
                }
                self.scratch.push(PollFd { fd, events: ev, revents: 0 });
            }
            let timeout_ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
            };
            let n = unsafe {
                poll(self.scratch.as_mut_ptr(), self.scratch.len() as Nfds, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(false);
                }
                return Err(err);
            }
            let woken = self.scratch[0].revents & (POLLIN | POLLERR | POLLHUP) != 0;
            if woken {
                // drain every pending wake byte
                let mut buf = [0u8; 64];
                loop {
                    let r = unsafe {
                        read(self.pipe.rd, buf.as_mut_ptr() as *mut c_void, buf.len())
                    };
                    if r <= 0 {
                        break;
                    }
                }
            }
            for (slot, &(_, token, _)) in self.scratch[1..].iter().zip(regs) {
                let re = slot.revents;
                if re == 0 {
                    continue;
                }
                events.push(Event {
                    token,
                    // errors/hangups surface as readability so the owner's
                    // next read reports the condition
                    readable: re & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0,
                    writable: re & (POLLOUT | POLLERR) != 0,
                });
            }
            Ok(woken)
        }
    }
}

#[cfg(not(unix))]
pub use fallback_impl::{Poller, Waker};

#[cfg(not(unix))]
mod fallback_impl {
    use super::{Event, Fd, Interest};
    use std::io;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    /// Coarse fallback: no readiness source, so every registered fd is
    /// reported ready at a bounded cadence and the nonblocking state
    /// machines absorb the spurious readiness as `WouldBlock`.
    pub struct Poller {
        state: Arc<(Mutex<bool>, Condvar)>,
    }

    #[derive(Clone)]
    pub struct Waker {
        state: Arc<(Mutex<bool>, Condvar)>,
    }

    impl Waker {
        pub fn wake(&self) {
            let (lock, cv) = &*self.state;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { state: Arc::new((Mutex::new(false), Condvar::new())) })
        }

        pub fn waker(&self) -> Waker {
            Waker { state: Arc::clone(&self.state) }
        }

        pub fn wait(
            &mut self,
            regs: &[(Fd, usize, Interest)],
            timeout: Option<Duration>,
            events: &mut Vec<Event>,
        ) -> io::Result<bool> {
            events.clear();
            let cadence = Duration::from_millis(5);
            let wait = timeout.map_or(cadence, |t| t.min(cadence));
            let (lock, cv) = &*self.state;
            let mut woken = lock.lock().unwrap();
            if !*woken {
                let (guard, _) = cv.wait_timeout(woken, wait).unwrap();
                woken = guard;
            }
            let was_woken = *woken;
            *woken = false;
            drop(woken);
            for &(_, token, interest) in regs {
                if interest.read || interest.write {
                    events.push(Event {
                        token,
                        readable: interest.read,
                        writable: interest.write,
                    });
                }
            }
            Ok(was_woken)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpListener;
    use std::time::{Duration, Instant};

    #[test]
    fn wake_interrupts_wait() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        let _ = poller.wait(&[], Some(Duration::from_secs(10)), &mut events).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "wake must cut the 10s timeout short");
        t.join().unwrap();
    }

    #[test]
    fn timeout_expires_without_events() {
        let mut poller = Poller::new().unwrap();
        let mut events = Vec::new();
        let t0 = Instant::now();
        poller.wait(&[], Some(Duration::from_millis(20)), &mut events).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert!(events.is_empty());
    }

    #[test]
    fn socket_readability_reported() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        client.write_all(b"x").unwrap();
        client.flush().unwrap();

        let mut poller = Poller::new().unwrap();
        let mut events = Vec::new();
        let regs =
            [(fd_of(&server), 7usize, Interest { read: true, write: false })];
        // a retry loop absorbs scheduling delay between the client write
        // and readability
        let mut readable = false;
        for _ in 0..100 {
            poller.wait(&regs, Some(Duration::from_millis(50)), &mut events).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                readable = true;
                break;
            }
        }
        assert!(readable, "server socket never reported readable");
        let mut buf = [0u8; 8];
        let mut s = &server;
        // the fallback poller reports readiness optimistically, so absorb
        // WouldBlock with a bounded retry
        for _ in 0..1000 {
            match s.read(&mut buf) {
                Ok(n) => {
                    assert_eq!(n, 1);
                    return;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("read failed: {e}"),
            }
        }
        panic!("byte never arrived");
    }
}
