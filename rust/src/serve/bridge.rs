//! Compute bridge: the single dispatch loop both servers share.
//!
//! Jobs (one single-query [`SearchRequest`] each) flow through the dynamic
//! batcher, get grouped by the planner's [`GroupKey`] so batchmates that
//! resolve to the same plan share one grouped `execute`, and are delivered
//! back either over a per-job channel (the legacy thread server) or as a
//! reactor completion ([`WireDone`]) that wakes the owning event loop.
//!
//! Deadlines are enforced here at the two places work can be shed cheaply:
//! at dequeue (before a job's group is formed) and at the group→per-query
//! retry stage boundary.  Shed jobs answer `deadline exceeded` immediately
//! instead of burning engine time.
//!
//! The bridge is also where workload telemetry and recall auditing hook
//! in: each dispatched group feeds one [`crate::obs::agg::Telemetry`]
//! record (one mutex take per *group*), and the
//! [`crate::obs::audit::Auditor`] samples 1-in-N members for off-path
//! full-probe replay.  Both are gated by single branches when off, so the
//! serving path stays byte-identical.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::config::Backend;
use crate::coordinator::batcher::{next_batch, BatchPolicy, Pending};
use crate::coordinator::engine::SearchEngine;
use crate::coordinator::plan::{GroupKey, SearchRequest};
use crate::core::Histogram;
use crate::obs::audit::AuditJob;
use crate::obs::{SpanName, SpanRec, TraceCollector};

use super::admission::Permit;
use super::reactor::WireDone;
use super::wire;

/// A serialized response line (no trailing newline) or an error message.
pub(crate) type JobResult = Result<Vec<u8>, String>;

/// One search travelling through the batcher.
pub(crate) struct Job {
    pub req: SearchRequest,
    pub key: GroupKey,
    /// Absolute shed point, if the request (or the server default) set one.
    pub deadline: Option<Instant>,
    /// Reactor delivery: present for event-loop connections, `None` for the
    /// legacy channel path.
    pub wire: Option<WireDone>,
    /// Admission slot; released wherever the job ends.
    pub permit: Option<Permit>,
}

/// Delivery envelope for one job once its query has been surrendered to a
/// grouped dispatch.
struct Ticket {
    respond: Sender<JobResult>,
    wire: Option<WireDone>,
    permit: Option<Permit>,
    deadline: Option<Instant>,
    enqueued: Instant,
    /// The request asked for its span timeline ([`SearchRequest::trace`]).
    /// Kept on the ticket because grouping rebuilds the request from the
    /// trace-neutral [`GroupKey`], which would otherwise lose the flag.
    trace: bool,
}

struct Member {
    q: Histogram,
    key: GroupKey,
    ticket: Ticket,
}

fn into_member(p: Pending<Job, JobResult>) -> Member {
    let Pending { query, respond, enqueued } = p;
    let Job { req, key, deadline, wire, permit } = query;
    let trace = req.trace;
    let mut qs = req.into_queries();
    Member {
        q: qs.pop().expect("one query per job"),
        key,
        ticket: Ticket { respond, wire, permit, deadline, enqueued, trace },
    }
}

/// Push one ambient serving-layer span (batch gather, dispatch, reactor
/// read/write) straight into the ring.  These are not tied to a request
/// trace (`trace_id` 0) — they give the `trace` export the server-side
/// picture around the per-request timelines.  A disabled collector costs
/// one relaxed load.
pub(crate) fn push_stage(col: &TraceCollector, name: SpanName, dur: std::time::Duration, tid: u16) {
    if !col.enabled() {
        return;
    }
    let dur_us = dur.as_micros().min(u128::from(u64::MAX)) as u64;
    col.push(SpanRec {
        trace_id: 0,
        span_id: 0,
        parent_id: 0,
        name: name as u16,
        tid,
        start_us: col.now_us().saturating_sub(dur_us),
        dur_us,
    });
}

fn expired(deadline: Option<Instant>, now: Instant) -> bool {
    deadline.is_some_and(|d| now >= d)
}

/// Hand one finished job back to its owner and release its permit.
fn deliver(engine: &SearchEngine, ticket: Ticket, result: JobResult) {
    engine.metrics().e2e.record(ticket.enqueued.elapsed());
    match ticket.wire {
        Some(w) => {
            let line = match result {
                Ok(line) => line,
                Err(e) => {
                    // the legacy path counts errors at the connection; the
                    // wire path has no per-connection handler, so count here
                    engine.metrics().record_error();
                    wire::error_line(&e)
                }
            };
            w.complete(line);
        }
        None => {
            let _ = ticket.respond.send(result);
        }
    }
    drop(ticket.permit);
}

/// Spawn the batch-dispatch thread; the returned sender is the enqueue
/// side.  The thread exits when every sender clone is dropped.
pub(crate) fn spawn_dispatcher(engine: Arc<SearchEngine>) -> Sender<Pending<Job, JobResult>> {
    let policy = BatchPolicy {
        max_batch: engine.config().max_batch,
        linger: std::time::Duration::from_millis(engine.config().linger_ms),
    };
    // the audit replay worker rides with the dispatcher: no-op unless
    // sampling is configured (and only the first dispatcher gets the queue)
    crate::obs::audit::spawn_worker(&engine);
    let (batch_tx, batch_rx) = channel::<Pending<Job, JobResult>>();
    std::thread::spawn(move || {
        while let Some(batch) = next_batch(&batch_rx, policy) {
            // dequeue boundary: record queue wait, shed expired work before
            // it reaches the engine
            let now = Instant::now();
            let mut live: Vec<Member> = Vec::with_capacity(batch.len());
            for p in batch {
                engine.metrics().queue_wait.record(now.saturating_duration_since(p.enqueued));
                let m = into_member(p);
                if expired(m.ticket.deadline, now) {
                    engine.metrics().record_deadline_expired();
                    engine.telemetry().record_deadline(&m.key);
                    deliver(&engine, m.ticket, Err(wire::DEADLINE_MSG.to_string()));
                } else {
                    live.push(m);
                }
            }
            // group the drained batch by the planner's GroupKey so each
            // group flows through one grouped plan execution; responses go
            // back per-job, so grouping never reorders anything a client
            // can observe.  Note: Metrics::batches counts plan executions
            // (one per key per drained batch, plus per-query retries when a
            // group fails wholesale), not drained batches
            let mut groups: Vec<(GroupKey, Vec<Member>)> = Vec::new();
            for m in live {
                let key = m.key;
                match groups.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, members)) => members.push(m),
                    None => groups.push((key, vec![m])),
                }
            }
            for (key, members) in groups {
                let (queries, tickets): (Vec<Histogram>, Vec<Ticket>) =
                    members.into_iter().map(|m| (m.q, m.ticket)).unzip();
                // the gather window: first member enqueued → group dispatch
                if let Some(first) = tickets.iter().map(|t| t.enqueued).min() {
                    push_stage(
                        engine.tracer(),
                        SpanName::BatchGather,
                        Instant::now().saturating_duration_since(first),
                        tickets.len().min(u16::MAX as usize) as u16,
                    );
                }
                let per_query = |q: &Histogram, traced: bool| -> JobResult {
                    let single = key.request(vec![q.clone()]).trace(traced);
                    let t0 = Instant::now();
                    let out = engine.execute(&single);
                    engine.metrics().execute.record(t0.elapsed());
                    push_stage(engine.tracer(), SpanName::Dispatch, t0.elapsed(), 0);
                    match out {
                        Ok(mut resp) => {
                            engine.telemetry().record(&key, &resp.stats);
                            let cert = resp.stats.certified.first().copied();
                            let res =
                                resp.results.pop().expect("one query in, one result out");
                            if engine.auditor().should_sample() {
                                engine.auditor().submit(AuditJob {
                                    key,
                                    query: q.clone(),
                                    served: res.hits.iter().map(|&(_, id)| id).collect(),
                                });
                            }
                            Ok(wire::search_result_line(
                                &res,
                                cert,
                                resp.stats.partial,
                                resp.spans.as_deref(),
                            ))
                        }
                        Err(e) => {
                            engine.telemetry().record_error(&key);
                            Err(e.to_string())
                        }
                    }
                };
                // per-query dispatch with a deadline recheck: sequential
                // batchmates can burn past a later job's deadline, so this
                // is a stage boundary too
                let run_one = |q: &Histogram, t: &Ticket| -> JobResult {
                    if expired(t.deadline, Instant::now()) {
                        engine.metrics().record_deadline_expired();
                        engine.telemetry().record_deadline(&key);
                        return Err(wire::DEADLINE_MSG.to_string());
                    }
                    per_query(q, t.trace)
                };
                // the native grouped plan either succeeds for everyone or
                // fails before any query is scored (then each job is
                // evaluated individually once); the artifact backend plans
                // per query anyway, so it dispatches per job from the start
                // — one failing query neither fails its batchmates nor
                // forces re-runs
                let results: Vec<JobResult> = if engine.config().backend == Backend::Artifact {
                    queries.iter().zip(&tickets).map(|(q, t)| run_one(q, t)).collect()
                } else {
                    // the GroupKey is trace-neutral (a traced request batches
                    // with untraced ones), so the rebuilt group request must
                    // re-arm tracing when any member asked for it; members
                    // that did not stay untraced on the wire
                    let any_traced = tickets.iter().any(|t| t.trace);
                    let group_req = key.request(queries).trace(any_traced);
                    let t0 = Instant::now();
                    let out = engine.execute(&group_req);
                    engine.metrics().execute.record(t0.elapsed());
                    push_stage(engine.tracer(), SpanName::Dispatch, t0.elapsed(), 0);
                    match out {
                        Ok(resp) => {
                            engine.telemetry().record(&key, &resp.stats);
                            let partial = resp.stats.partial;
                            let certs = resp.stats.certified;
                            // one grouped execute, one shared timeline: each
                            // traced member gets the whole group's spans
                            let group_spans = resp.spans;
                            resp.results
                                .into_iter()
                                .zip(&tickets)
                                .enumerate()
                                .map(|(i, (res, t))| {
                                    if engine.auditor().should_sample() {
                                        engine.auditor().submit(AuditJob {
                                            key,
                                            query: group_req.queries()[i].clone(),
                                            served: res
                                                .hits
                                                .iter()
                                                .map(|&(_, id)| id)
                                                .collect(),
                                        });
                                    }
                                    let tl =
                                        if t.trace { group_spans.as_deref() } else { None };
                                    Ok(wire::search_result_line(
                                        &res,
                                        certs.get(i).copied(),
                                        partial,
                                        tl,
                                    ))
                                })
                                .collect()
                        }
                        Err(_) => group_req
                            .queries()
                            .iter()
                            .zip(&tickets)
                            .map(|(q, t)| run_one(q, t))
                            .collect(),
                    }
                };
                for (out, ticket) in results.into_iter().zip(tickets) {
                    deliver(&engine, ticket, out);
                }
            }
        }
    });
    batch_tx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec};
    use crate::util::json::Json;
    use std::sync::atomic::Ordering;

    fn test_engine() -> Arc<SearchEngine> {
        Arc::new(
            SearchEngine::from_config(Config {
                dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
                threads: 2,
                linger_ms: 1,
                ..Default::default()
            })
            .unwrap(),
        )
    }

    fn search_job(engine: &SearchEngine, id: usize, deadline: Option<Instant>) -> Job {
        let mut req = SearchRequest::batch(vec![engine.doc_histogram(id).unwrap()]);
        req.l = Some(3);
        let key = req.group_key(engine);
        Job { req, key, deadline, wire: None, permit: None }
    }

    #[test]
    fn dispatches_search_and_serializes_hits() {
        let engine = test_engine();
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        tx.send(Pending {
            query: search_job(&engine, 3, None),
            respond: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        let line = rrx.recv().unwrap().expect("search succeeds");
        let j = Json::parse(std::str::from_utf8(&line).unwrap()).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let hits = j.get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].as_arr().unwrap()[1].as_usize(), Some(3), "finds itself");
        assert!(engine.metrics().e2e.count() >= 1);
        assert!(engine.metrics().queue_wait.count() >= 1);
    }

    #[test]
    fn traced_job_gets_a_span_timeline() {
        let engine = test_engine();
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        let mut job = search_job(&engine, 2, None);
        job.req.trace = true;
        tx.send(Pending { query: job, respond: rtx, enqueued: Instant::now() }).unwrap();
        let line = rrx.recv().unwrap().expect("search succeeds");
        let j = Json::parse(std::str::from_utf8(&line).unwrap()).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let tl = j.get("trace").and_then(Json::as_arr).expect("timeline embedded");
        assert_eq!(tl[0].get("name").and_then(Json::as_str), Some("request"));
        assert!(engine.tracer().total() >= 1, "spans flushed into the shared ring");
    }

    #[test]
    fn untraced_job_stays_byte_identical() {
        let engine = test_engine();
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        tx.send(Pending {
            query: search_job(&engine, 2, None),
            respond: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        let line = rrx.recv().unwrap().expect("search succeeds");
        let j = Json::parse(std::str::from_utf8(&line).unwrap()).unwrap();
        assert!(j.get("trace").is_none(), "no timeline on untraced responses");
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue() {
        let engine = test_engine();
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        tx.send(Pending {
            query: search_job(&engine, 1, Some(Instant::now())),
            respond: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        let out = rrx.recv().unwrap();
        assert_eq!(out, Err(wire::DEADLINE_MSG.to_string()));
        assert_eq!(engine.metrics().deadline_expired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn telemetry_records_each_dispatch_group() {
        let engine = test_engine(); // default telemetry_window_ms=1000 → armed
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        tx.send(Pending {
            query: search_job(&engine, 3, None),
            respond: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        rrx.recv().unwrap().expect("search succeeds");
        let snap = engine.telemetry().snapshot();
        assert_eq!(snap.workloads.len(), 1, "one workload key seen");
        let (key, w, _) = &snap.workloads[0];
        assert_eq!(key.l, 3);
        assert_eq!(w.queries, 1);
        assert_eq!(w.batches, 1);
        assert_eq!(w.latency.count, 1);
    }

    #[test]
    fn sampled_jobs_are_audited_at_full_probe() {
        let mut cfg = Config {
            dataset: DatasetSpec::SynthText { n: 30, vocab: 150, dim: 8, seed: 9 },
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        };
        cfg.serve.audit_sample = 1; // audit every member
        let engine = Arc::new(SearchEngine::from_config(cfg).unwrap());
        let tx = spawn_dispatcher(Arc::clone(&engine));
        let (rtx, rrx) = channel();
        tx.send(Pending {
            query: search_job(&engine, 3, None),
            respond: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        rrx.recv().unwrap().expect("search succeeds");
        // the replay runs on the audit worker; wait for it to land
        let t0 = Instant::now();
        while engine.auditor().audited() == 0
            && t0.elapsed() < std::time::Duration::from_secs(10)
        {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let est = engine.auditor().estimates();
        assert_eq!(est.len(), 1, "audit landed");
        assert_eq!(
            est[0].1.last_recall,
            1.0,
            "an unpruned engine replays its own serving route bit-identically"
        );
        assert!(est[0].1.replay_us > 0);
    }

    #[test]
    fn dispatcher_exits_when_senders_drop() {
        let engine = test_engine();
        let tx = spawn_dispatcher(engine);
        drop(tx); // the loop's next_batch returns None and the thread ends;
                  // nothing to assert beyond not hanging
    }
}
