//! The async serving runtime: a readiness-based event-loop server that
//! replaces thread-per-connection serving for high connection counts.
//!
//! Layers (bottom up):
//! * [`sys`] — `poll(2)` bindings + self-pipe wakeups (dependency-free),
//! * [`wire`] — zero-copy request lexer + streaming response writers,
//!   bit-identical to the tree codec,
//! * [`admission`] — bounded in-flight budget with RAII permits,
//! * [`bridge`] — the shared batcher/dispatch loop (deadline-aware),
//! * [`conn`] — per-connection framing, FIFO pipelining, backpressure,
//! * [`reactor`] — the event loop itself,
//! * [`ReactorServer`] — the front door: accept + round-robin hand-off to
//!   N reactor threads.
//!
//! The legacy [`crate::coordinator::Server`] stays as a compatibility shim
//! on the same bridge, so both servers answer byte-identically; it is also
//! the baseline the serve benchmark compares against.

pub(crate) mod admission;
pub(crate) mod bridge;
pub(crate) mod conn;
pub(crate) mod reactor;
pub mod sys;
pub(crate) mod wire;

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::SearchEngine;
use crate::core::EmdResult;

use admission::Admission;
use reactor::{Injector, Msg, ReactorConfig};
use sys::Poller;

pub use admission::Permit;

struct ReactorHandle {
    injector: Arc<Injector>,
    thread: Option<JoinHandle<()>>,
}

/// The event-loop server: accepts connections and hands each to one of N
/// reactor threads (round-robin).  Speaks exactly the same line protocol
/// as the legacy [`crate::coordinator::Server`].
pub struct ReactorServer {
    engine: Arc<SearchEngine>,
    listener: TcpListener,
    handles: Vec<ReactorHandle>,
    active: Arc<AtomicUsize>,
    next: AtomicUsize,
    admission: Admission,
}

impl ReactorServer {
    /// Bind, spawn the shared dispatcher and the reactor threads.  `addr`
    /// may use port 0 for an ephemeral port (tests).
    pub fn bind(engine: SearchEngine, addr: &str) -> EmdResult<ReactorServer> {
        let engine = Arc::new(engine);
        let listener = TcpListener::bind(addr)?;
        let batch_tx = bridge::spawn_dispatcher(Arc::clone(&engine));
        let serve = engine.config().serve;
        let cfg = ReactorConfig {
            max_line: serve.max_line_bytes,
            retry_after_ms: serve.retry_after_ms,
            default_deadline_ms: serve.deadline_ms,
            idle_timeout: if serve.idle_timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(serve.idle_timeout_ms))
            },
        };
        let admission = Admission::new(serve.max_inflight);
        let active = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(serve.reactors.max(1));
        for _ in 0..serve.reactors.max(1) {
            let poller = Poller::new()?;
            let injector = Arc::new(Injector::new(poller.waker()));
            let thread = {
                let engine = Arc::clone(&engine);
                let batch_tx = batch_tx.clone();
                let admission = admission.clone();
                let injector = Arc::clone(&injector);
                let active = Arc::clone(&active);
                std::thread::spawn(move || {
                    reactor::run(engine, batch_tx, admission, injector, poller, cfg, active)
                })
            };
            handles.push(ReactorHandle { injector, thread: Some(thread) });
        }
        Ok(ReactorServer {
            engine,
            listener,
            handles,
            active,
            next: AtomicUsize::new(0),
            admission,
        })
    }

    /// The engine this server fronts.
    pub fn engine(&self) -> &Arc<SearchEngine> {
        &self.engine
    }

    /// The shared admission budget (readiness probes report saturation
    /// against it).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Build the `/readyz` probe for this server: ready means the corpus
    /// is loaded, every configured index is trained, admission is not
    /// saturated (traffic is not being shed right now), and — for a remote
    /// fan-out coordinator — every remote shard has at least one reachable
    /// replica.
    pub fn ready_probe(&self) -> crate::obs::http::ReadyProbe {
        let engine = Arc::clone(&self.engine);
        let admission = self.admission.clone();
        Arc::new(move || {
            if !engine.ready() {
                return Err("not ready: corpus empty or index untrained".to_string());
            }
            if admission.saturated() {
                return Err(format!(
                    "not ready: admission saturated ({}/{} in flight)",
                    admission.in_flight(),
                    admission.capacity()
                ));
            }
            if let Some(fleet) = engine.remote_fleet() {
                if let Some(why) = fleet.ready_error() {
                    return Err(format!("not ready: {why}"));
                }
            }
            Ok(())
        })
    }

    pub fn local_addr(&self) -> EmdResult<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    fn inject(&self, stream: TcpStream) {
        self.active.fetch_add(1, Ordering::AcqRel);
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.handles.len();
        self.handles[i].injector.push(Msg::Conn(stream));
    }

    /// Accept loop; blocks forever (run in a dedicated thread if needed).
    pub fn serve(&self) -> EmdResult<()> {
        crate::log_info!(
            "serve",
            "reactor server listening on {} ({} reactors, max_inflight {})",
            self.local_addr()?,
            self.handles.len(),
            self.engine.config().serve.max_inflight
        );
        for stream in self.listener.incoming() {
            self.inject(stream?);
        }
        Ok(())
    }

    /// Accept until `stop` flips true, then drain: stop accepting, wait
    /// (bounded) for the reactors' in-flight connections to finish their
    /// pipelined work, and return so the caller can flush final snapshots.
    /// The `Drop` impl then shuts the reactor threads down cleanly.  This
    /// is the graceful SIGINT/SIGTERM path of `emdpar serve`.
    pub fn serve_until(&self, stop: &AtomicBool) -> EmdResult<()> {
        crate::log_info!(
            "serve",
            "reactor server listening on {} ({} reactors, max_inflight {})",
            self.local_addr()?,
            self.handles.len(),
            self.engine.config().serve.max_inflight
        );
        self.listener.set_nonblocking(true)?;
        while !stop.load(Ordering::Acquire) {
            match self.listener.accept() {
                Ok((stream, _)) => self.inject(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        crate::log_info!(
            "serve",
            "shutdown requested: draining {} active connection(s)",
            self.active.load(Ordering::Acquire)
        );
        // bounded drain: clients with in-flight pipelines get a grace
        // window; idle keep-alive connections are closed by Drop after it
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.active.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }

    /// Accept exactly `count` connections, then wait until every accepted
    /// connection has fully drained and closed (test harness).
    pub fn serve_n(&self, count: usize) -> EmdResult<()> {
        for _ in 0..count {
            let (stream, _) = self.listener.accept()?;
            self.inject(stream);
        }
        while self.active.load(Ordering::Acquire) > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    }

    /// Connections currently owned by the reactors.
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }
}

impl Drop for ReactorServer {
    fn drop(&mut self) {
        for h in &self.handles {
            h.injector.push(Msg::Shutdown);
        }
        for h in &mut self.handles {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec};
    use std::io::{BufRead, BufReader, Write};

    fn test_engine() -> SearchEngine {
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 20, vocab: 100, dim: 8, seed: 3 },
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn serve_until_accepts_then_stops_on_flag() {
        let server = ReactorServer::bind(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let loop_handle = s.spawn(|| server.serve_until(&stop));
            // a live round trip proves the loop accepts while running
            let mut c = std::net::TcpStream::connect(addr).unwrap();
            c.write_all(b"{\"op\": \"ping\"}\n").unwrap();
            let mut line = String::new();
            BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
            assert!(line.contains("pong"), "{line}");
            drop(c);
            stop.store(true, Ordering::Release);
            loop_handle.join().unwrap().expect("graceful exit");
        });
    }

    #[test]
    fn ready_probe_tracks_engine_and_admission() {
        let server = ReactorServer::bind(test_engine(), "127.0.0.1:0").unwrap();
        let probe = server.ready_probe();
        assert!(probe().is_ok(), "loaded un-indexed corpus is ready");
        assert_eq!(server.admission().capacity(), 1024);
        assert!(!server.admission().saturated());
        // exhaust the budget: the probe must flip to not-ready
        let permits: Vec<Permit> =
            (0..1024).map(|_| server.admission().try_admit().unwrap()).collect();
        assert!(server.admission().saturated());
        let why = probe().expect_err("saturated admission is not ready");
        assert!(why.contains("saturated"), "{why}");
        drop(permits);
        assert!(probe().is_ok(), "released permits restore readiness");
    }
}
