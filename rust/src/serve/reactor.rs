//! The reactor: one event-loop thread multiplexing many connections.
//!
//! Each reactor owns a [`Poller`], a slab of [`Conn`] state machines, and
//! an [`Injector`] — a tiny mailbox other threads push into (new
//! connections from the acceptor, completions from the compute bridge,
//! shutdown) before waking the poller through its self-pipe.  Completions
//! carry a `(token, generation, seq)` address; the generation guards
//! against a completion landing on a connection slot that was reaped and
//! recycled while the search was in flight.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::Pending;
use crate::coordinator::engine::SearchEngine;

use crate::obs::SpanName;

use super::admission::Admission;
use super::bridge::{push_stage, Job, JobResult};
use super::conn::{Conn, ConnCtx};
use super::sys::{fd_of, Event, Fd, Interest, Poller, Waker};

/// Cross-thread input to one reactor.
pub(crate) enum Msg {
    /// A freshly accepted connection to adopt.
    Conn(TcpStream),
    /// A finished search for connection `token` (generation-checked).
    Done { token: usize, gen: u64, seq: u64, line: Vec<u8> },
    /// Drop everything and exit the loop.
    Shutdown,
}

/// Mailbox + waker for one reactor thread.
pub(crate) struct Injector {
    q: Mutex<VecDeque<Msg>>,
    waker: Waker,
}

impl Injector {
    pub fn new(waker: Waker) -> Injector {
        Injector { q: Mutex::new(VecDeque::new()), waker }
    }

    pub fn push(&self, msg: Msg) {
        self.q.lock().unwrap().push_back(msg);
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Msg> {
        let mut q = self.q.lock().unwrap();
        q.drain(..).collect()
    }
}

/// Completion address for one in-flight search; consumed by the compute
/// bridge to wake the owning reactor with the serialized response.
pub(crate) struct WireDone {
    injector: Arc<Injector>,
    token: usize,
    gen: u64,
    seq: u64,
}

impl WireDone {
    pub fn new(injector: Arc<Injector>, token: usize, gen: u64, seq: u64) -> WireDone {
        WireDone { injector, token, gen, seq }
    }

    pub fn complete(self, line: Vec<u8>) {
        self.injector.push(Msg::Done {
            token: self.token,
            gen: self.gen,
            seq: self.seq,
            line,
        });
    }
}

/// Per-reactor runtime knobs (resolved from `ServeParams`).
#[derive(Clone, Copy)]
pub(crate) struct ReactorConfig {
    pub max_line: usize,
    pub retry_after_ms: u64,
    pub default_deadline_ms: u64,
    pub idle_timeout: Option<Duration>,
}

/// The event loop.  Runs until a [`Msg::Shutdown`] arrives; `active` is
/// decremented once per connection this reactor retires.
pub(crate) fn run(
    engine: Arc<SearchEngine>,
    batch_tx: Sender<Pending<Job, JobResult>>,
    admission: Admission,
    injector: Arc<Injector>,
    mut poller: Poller,
    cfg: ReactorConfig,
    active: Arc<AtomicUsize>,
) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut next_gen: u64 = 0;
    let mut events: Vec<Event> = Vec::new();
    let mut regs: Vec<(Fd, usize, Interest)> = Vec::new();
    loop {
        for msg in injector.drain() {
            match msg {
                Msg::Shutdown => return,
                Msg::Conn(stream) => match Conn::new(stream, next_gen) {
                    Ok(conn) => {
                        next_gen += 1;
                        match conns.iter().position(|c| c.is_none()) {
                            Some(token) => conns[token] = Some(conn),
                            None => conns.push(Some(conn)),
                        }
                    }
                    Err(_) => {
                        // set_nonblocking failed: the connection never
                        // joined the loop
                        active.fetch_sub(1, Ordering::AcqRel);
                    }
                },
                Msg::Done { token, gen, seq, line } => {
                    if let Some(Some(conn)) = conns.get_mut(token) {
                        if conn.gen == gen {
                            conn.complete(seq, line);
                            conn.on_writable();
                        }
                    }
                }
            }
        }

        regs.clear();
        for (token, slot) in conns.iter().enumerate() {
            if let Some(conn) = slot {
                let interest =
                    Interest { read: conn.wants_read(), write: conn.wants_write() };
                if interest.read || interest.write {
                    regs.push((fd_of(&conn.stream), token, interest));
                }
            }
        }
        // with an idle timeout configured the loop must tick even when no
        // fd stirs, so it can sweep idle connections
        let timeout = cfg.idle_timeout.map(|t| t.min(Duration::from_millis(200)));
        if poller.wait(&regs, timeout, &mut events).is_err() {
            // a broken poller cannot make progress; drop every connection
            return;
        }

        for ev in &events {
            let Some(Some(conn)) = conns.get_mut(ev.token) else { continue };
            let ctx = ConnCtx {
                engine: &engine,
                batch_tx: &batch_tx,
                admission: &admission,
                injector: &injector,
                token: ev.token,
                max_line: cfg.max_line,
                retry_after_ms: cfg.retry_after_ms,
                default_deadline_ms: cfg.default_deadline_ms,
            };
            // span the two phases only when the collector is armed (a
            // traced request or the slow-query log); `tid` carries the
            // connection token so lanes stack per connection in the export
            let traced = engine.tracer().enabled();
            let lane = ev.token.min(u16::MAX as usize) as u16;
            if ev.readable {
                let t0 = Instant::now();
                conn.on_readable(&ctx);
                if traced {
                    push_stage(engine.tracer(), SpanName::ConnRead, t0.elapsed(), lane);
                }
            }
            if ev.writable && !conn.dead {
                let t0 = Instant::now();
                conn.on_writable();
                if traced {
                    push_stage(engine.tracer(), SpanName::ConnWrite, t0.elapsed(), lane);
                }
            }
        }

        if let Some(limit) = cfg.idle_timeout {
            let now = Instant::now();
            for slot in conns.iter_mut().flatten() {
                if !slot.has_pending()
                    && !slot.read_closed
                    && now.saturating_duration_since(slot.last_activity) > limit
                {
                    slot.dead = true;
                }
            }
        }

        for slot in conns.iter_mut() {
            if slot.as_ref().is_some_and(|c| c.dead) {
                *slot = None; // dropping the Conn closes the socket
                active.fetch_sub(1, Ordering::AcqRel);
            }
        }
    }
}
