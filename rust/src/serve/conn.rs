//! Per-connection state machine for the reactor runtime.
//!
//! Each connection owns a nonblocking socket plus:
//! * a read buffer framed on newlines (with a hard line-length cap and a
//!   discard mode so an oversized line costs bounded memory and exactly one
//!   structured error),
//! * a FIFO slot queue — every request reserves a slot in arrival order and
//!   responses are flushed only from the front, so pipelined clients always
//!   see answers in request order no matter how the batcher reorders
//!   compute,
//! * a write buffer with backpressure: when the backlog passes the high
//!   water mark the connection stops reading until the peer drains it.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::Pending;
use crate::coordinator::engine::SearchEngine;
use crate::coordinator::server::{process_line, Handled};
use crate::core::EmdError;

use super::admission::Admission;
use super::bridge::{Job, JobResult};
use super::reactor::{Injector, WireDone};
use super::wire;

/// Stop reading from a connection whose unflushed responses exceed this.
const HIGH_WATER: usize = 256 * 1024;
/// Per-readiness-round read budget so one hot connection cannot starve the
/// rest of the reactor.
const READ_ROUND_BYTES: usize = 256 * 1024;

/// Shared per-event context a [`Conn`] needs to make progress.
pub(crate) struct ConnCtx<'a> {
    pub engine: &'a SearchEngine,
    pub batch_tx: &'a Sender<Pending<Job, JobResult>>,
    pub admission: &'a Admission,
    pub injector: &'a Arc<Injector>,
    pub token: usize,
    pub max_line: usize,
    pub retry_after_ms: u64,
    pub default_deadline_ms: u64,
}

/// One response slot; `line` is `None` while the search is in flight.
struct Slot {
    seq: u64,
    line: Option<Vec<u8>>,
}

pub(crate) struct Conn {
    pub stream: TcpStream,
    /// Guards against completions addressed to a recycled token.
    pub gen: u64,
    rbuf: Vec<u8>,
    /// Inside an oversized line: drop bytes until the next newline.
    discarding: bool,
    slots: VecDeque<Slot>,
    next_seq: u64,
    wbuf: Vec<u8>,
    wpos: usize,
    pub read_closed: bool,
    pub dead: bool,
    pub last_activity: Instant,
}

impl Conn {
    pub fn new(stream: TcpStream, gen: u64) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        Ok(Conn {
            stream,
            gen,
            rbuf: Vec::new(),
            discarding: false,
            slots: VecDeque::new(),
            next_seq: 0,
            wbuf: Vec::new(),
            wpos: 0,
            read_closed: false,
            dead: false,
            last_activity: Instant::now(),
        })
    }

    /// Responses queued or buffered but not yet on the wire.
    pub fn has_pending(&self) -> bool {
        !self.slots.is_empty() || self.wpos < self.wbuf.len()
    }

    pub fn wants_read(&self) -> bool {
        !self.read_closed && !self.dead && (self.wbuf.len() - self.wpos) < HIGH_WATER
    }

    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len() || self.slots.front().is_some_and(|s| s.line.is_some())
    }

    /// Drain the socket (up to a fairness budget), frame lines, process
    /// them, and opportunistically flush whatever became ready.
    pub fn on_readable(&mut self, ctx: &ConnCtx) {
        let mut buf = [0u8; 16 * 1024];
        let mut round = 0usize;
        while round < READ_ROUND_BYTES && self.wants_read() {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    round += n;
                    self.last_activity = Instant::now();
                    self.ingest(&buf[..n], ctx);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.read_closed {
            self.finish_eof(ctx);
        }
        self.on_writable();
    }

    fn ingest(&mut self, data: &[u8], ctx: &ConnCtx) {
        self.rbuf.extend_from_slice(data);
        let mut start = 0usize;
        while let Some(rel) = self.rbuf[start..].iter().position(|&b| b == b'\n') {
            let end = start + rel;
            if self.discarding {
                // the tail of an already-reported oversized line
                self.discarding = false;
            } else if end - start > ctx.max_line {
                self.push_oversize(ctx);
            } else {
                let line = self.rbuf[start..end].to_vec();
                self.process_one(&line, ctx);
            }
            start = end + 1;
        }
        if start > 0 {
            self.rbuf.drain(..start);
        }
        if self.discarding {
            self.rbuf.clear();
        } else if self.rbuf.len() > ctx.max_line {
            // a partial line already over the cap: report once, then drop
            // bytes until its newline shows up — memory stays bounded
            self.push_oversize(ctx);
            self.discarding = true;
            self.rbuf.clear();
        }
    }

    /// The peer half-closed: the trailing unterminated line is still a
    /// request (matching the legacy `read_line` behaviour), then the
    /// connection closes once every response is flushed.
    fn finish_eof(&mut self, ctx: &ConnCtx) {
        if !self.rbuf.is_empty() && !self.discarding {
            let line = std::mem::take(&mut self.rbuf);
            if line.len() > ctx.max_line {
                self.push_oversize(ctx);
            } else {
                self.process_one(&line, ctx);
            }
        }
        self.rbuf.clear();
        if !self.has_pending() {
            self.dead = true;
        }
    }

    fn process_one(&mut self, line: &[u8], ctx: &ConnCtx) {
        match process_line(line, ctx.engine, ctx.default_deadline_ms) {
            Handled::Empty => {}
            Handled::Line(bytes) => self.push_ready(bytes),
            Handled::Search { req, key, deadline } => match ctx.admission.try_admit() {
                None => {
                    ctx.engine.metrics().record_shed();
                    ctx.engine.telemetry().record_shed();
                    self.push_ready(wire::overload_line(ctx.retry_after_ms));
                }
                Some(permit) => {
                    ctx.engine.metrics().record_admitted();
                    let seq = self.next_seq;
                    self.next_seq += 1;
                    self.slots.push_back(Slot { seq, line: None });
                    let done =
                        WireDone::new(Arc::clone(ctx.injector), ctx.token, self.gen, seq);
                    let job =
                        Job { req, key, deadline, wire: Some(done), permit: Some(permit) };
                    // the wire path delivers through `done`; the channel is
                    // a placeholder to satisfy the shared Pending shape
                    let (respond, _staging) = channel();
                    let pending = Pending { query: job, respond, enqueued: Instant::now() };
                    if ctx.batch_tx.send(pending).is_err() {
                        ctx.engine.metrics().record_error();
                        self.complete(seq, wire::error_line(wire::DISPATCHER_GONE_MSG));
                    }
                }
            },
        }
    }

    fn push_ready(&mut self, bytes: Vec<u8>) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.slots.push_back(Slot { seq, line: Some(bytes) });
    }

    fn push_oversize(&mut self, ctx: &ConnCtx) {
        ctx.engine.metrics().record_error();
        let msg = EmdError::protocol(format!(
            "request line exceeds {} bytes",
            ctx.max_line
        ))
        .to_string();
        self.push_ready(wire::error_line(&msg));
    }

    /// Fill a completed slot.  Unknown sequences (stale generation already
    /// filtered by the reactor) are ignored.
    pub fn complete(&mut self, seq: u64, line: Vec<u8>) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.seq == seq) {
            slot.line = Some(line);
        }
    }

    /// Move consecutive ready front slots into the write buffer — FIFO by
    /// construction: a waiting slot blocks everything behind it.
    fn pump(&mut self) {
        while self.slots.front().is_some_and(|s| s.line.is_some()) {
            let slot = self.slots.pop_front().expect("front checked");
            self.wbuf.extend_from_slice(&slot.line.expect("ready checked"));
            self.wbuf.push(b'\n');
        }
    }

    /// Flush as much of the write buffer as the socket accepts.
    pub fn on_writable(&mut self) {
        if self.dead {
            return;
        }
        self.pump();
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 64 * 1024 {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        if self.read_closed && !self.has_pending() {
            self.dead = true; // everything flushed after EOF: clean close
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetSpec};
    use crate::coordinator::engine::SearchEngine;
    use crate::serve::sys::Poller;
    use crate::util::json::Json;
    use std::io::BufRead;
    use std::net::TcpListener;
    use std::time::Duration;

    fn test_engine() -> SearchEngine {
        SearchEngine::from_config(Config {
            dataset: DatasetSpec::SynthText { n: 20, vocab: 100, dim: 8, seed: 3 },
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        })
        .unwrap()
    }

    /// Feed raw bytes through a real socket pair and collect the response
    /// lines the state machine produces.
    fn drive(payload: &[u8], max_line: usize) -> Vec<Json> {
        let engine = test_engine();
        let (batch_tx, _batch_rx) = channel();
        let admission = Admission::new(4);
        let poller = Poller::new().unwrap();
        let injector = Arc::new(Injector::new(poller.waker()));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Conn::new(server, 0).unwrap();
        let ctx = ConnCtx {
            engine: &engine,
            batch_tx: &batch_tx,
            admission: &admission,
            injector: &injector,
            token: 0,
            max_line,
            retry_after_ms: 2,
            default_deadline_ms: 0,
        };
        client.write_all(payload).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !conn.dead && Instant::now() < deadline {
            conn.on_readable(&ctx);
            conn.on_writable();
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(conn.dead, "connection must close cleanly after EOF");
        drop(conn);
        let mut out = Vec::new();
        let reader = std::io::BufReader::new(client);
        for line in reader.lines() {
            out.push(Json::parse(&line.unwrap()).unwrap());
        }
        out
    }

    #[test]
    fn ping_is_answered_inline() {
        let out = drive(b"{\"op\": \"ping\"}\n", 1 << 20);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn oversized_line_reports_error_and_connection_survives() {
        let mut payload = Vec::new();
        payload.extend_from_slice(b"{\"op\": \"ping\"}\n");
        payload.extend_from_slice(&vec![b'x'; 4096]); // way over the cap
        payload.push(b'\n');
        payload.extend_from_slice(b"{\"op\": \"ping\"}\n");
        let out = drive(&payload, 256);
        assert_eq!(out.len(), 3, "one response per request, in order");
        assert_eq!(out[0].get("pong"), Some(&Json::Bool(true)));
        assert_eq!(out[1].get("ok"), Some(&Json::Bool(false)));
        let err = out[1].get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("exceeds 256 bytes"), "{err}");
        assert_eq!(out[2].get("pong"), Some(&Json::Bool(true)), "pipelined successor survives");
    }

    #[test]
    fn invalid_utf8_is_a_clean_error() {
        let mut payload = Vec::new();
        payload.extend_from_slice(b"{\"op\": \"ping\" \xff}\n");
        payload.extend_from_slice(b"{\"op\": \"ping\"}\n");
        let out = drive(&payload, 1 << 20);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].get("ok"), Some(&Json::Bool(false)));
        assert!(out[0]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("invalid utf-8"));
        assert_eq!(out[1].get("pong"), Some(&Json::Bool(true)));
    }
}
