//! Zero-copy wire layer for the line protocol.
//!
//! Two halves, both bit-identical to the [`Json`] tree codec:
//!
//! * [`decode_line`] — an in-place slice lexer for the hot request fields
//!   (`op`, `method`, `l`, `query`, `nprobe`, `cascade`, `id`, `threads`,
//!   `deadline_ms`).  It builds a [`SearchRequest`] straight from the byte
//!   slice without materializing a `Json` tree.  The lexer is deliberately
//!   conservative: anything it is not *certain* about — escape sequences,
//!   `add_docs` payloads, malformed syntax, unknown ops — returns
//!   [`Decoded::Fallback`] and the caller re-parses through the tree codec,
//!   so every error message and edge-case behaviour stays byte-for-byte
//!   what the tree path produces.
//! * [`search_result_line`] / [`error_line`] / [`overload_line`] —
//!   streaming response writers that serialize straight into an output
//!   buffer.  They replicate the `BTreeMap` key order and the
//!   [`crate::util::json::write_number`] format of
//!   `Json::to_string_compact`, so a byte-compare against the tree
//!   serializer always passes (see the tests below).

use crate::coordinator::engine::SearchResult;
use crate::coordinator::plan::{CascadeSpec, SearchRequest};
use crate::core::{Histogram, Method};
use crate::obs::{chrome, SpanRec};
use crate::util::json::{write_escaped, write_number};

/// Shed/deadline error strings (shared so both servers answer identically).
pub(crate) const OVERLOADED_MSG: &str = "overloaded";
pub(crate) const DEADLINE_MSG: &str = "deadline exceeded";
pub(crate) const DISPATCHER_GONE_MSG: &str = "internal error: dispatcher gone";
pub(crate) const DISPATCHER_DROPPED_MSG: &str = "internal error: dispatcher dropped reply";

/// Outcome of the fast-path lexer.
#[derive(Debug)]
pub(crate) enum Decoded {
    Ping,
    /// `{"op":"stats"}`; `reset` mirrors the tree path's `"reset": true`.
    Stats { reset: bool },
    /// A `search` / `search_id` request decoded without a tree.
    Search { req: SearchRequest, id: Option<usize>, deadline_ms: Option<u64> },
    /// Cold or uncertain path: re-parse through the tree codec.
    Fallback,
}

/// Lex one (already UTF-8-validated, trimmed, non-empty) request line.
pub(crate) fn decode_line(line: &str) -> Decoded {
    decode_inner(line).unwrap_or(Decoded::Fallback)
}

// ---------------------------------------------------------------------------
// response writers
// ---------------------------------------------------------------------------

/// Serialize one search success straight into bytes:
/// `{"certified":…,"hits":[[d,id,label],…],"ok":true,"partial":true,`
/// `"trace":[…]}` — identical to serializing the tree the legacy server
/// used to build (object keys in BTreeMap order).  `partial` is emitted
/// only when `true` (a remote fan-out dropped a shard from the merge) and
/// `trace` only on `"trace": true` requests, so ordinary responses stay
/// byte-for-byte what they were before either field existed.
pub(crate) fn search_result_line(
    res: &SearchResult,
    certified: Option<bool>,
    partial: bool,
    trace: Option<&[SpanRec]>,
) -> Vec<u8> {
    let mut s = String::with_capacity(24 + res.hits.len() * 24);
    s.push('{');
    if let Some(c) = certified {
        s.push_str("\"certified\":");
        s.push_str(if c { "true" } else { "false" });
        s.push(',');
    }
    s.push_str("\"hits\":[");
    for (i, (&(d, id), &lab)) in res.hits.iter().zip(&res.labels).enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        write_number(&mut s, d as f64);
        s.push(',');
        write_number(&mut s, id as f64);
        s.push(',');
        write_number(&mut s, lab as f64);
        s.push(']');
    }
    s.push_str("],\"ok\":true");
    if partial {
        s.push_str(",\"partial\":true");
    }
    if let Some(spans) = trace {
        s.push_str(",\"trace\":");
        // the timeline rides through the tree serializer: it is cold
        // (explicitly requested), and reusing the tree keeps key order
        // and number formatting canonical by construction
        s.push_str(&chrome::timeline(spans).to_string_compact());
    }
    s.push('}');
    s.into_bytes()
}

/// Serialize the protocol's error payload: `{"error":"…","ok":false}`.
pub(crate) fn error_line(msg: &str) -> Vec<u8> {
    let mut s = String::with_capacity(msg.len() + 24);
    s.push_str("{\"error\":");
    write_escaped(msg, &mut s);
    s.push_str(",\"ok\":false}");
    s.into_bytes()
}

/// Admission-shed payload:
/// `{"error":"overloaded","ok":false,"retry_after_ms":N}`.
pub(crate) fn overload_line(retry_after_ms: u64) -> Vec<u8> {
    let mut s = String::with_capacity(64);
    s.push_str("{\"error\":");
    write_escaped(OVERLOADED_MSG, &mut s);
    s.push_str(",\"ok\":false,\"retry_after_ms\":");
    write_number(&mut s, retry_after_ms as f64);
    s.push('}');
    s.into_bytes()
}

// ---------------------------------------------------------------------------
// slice lexer
// ---------------------------------------------------------------------------

/// `Json::as_usize` semantics on a raw f64.
fn to_usize(x: f64) -> Option<usize> {
    if x >= 0.0 && x.fract() == 0.0 {
        Some(x as usize)
    } else {
        None
    }
}

struct Lex<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Lex<'a> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Option<()> {
        if self.peek()? == c {
            self.i += 1;
            Some(())
        } else {
            None
        }
    }

    /// A string literal with no escapes and no control chars; anything
    /// fancier aborts the fast path.
    fn string(&mut self) -> Option<&'a str> {
        self.eat(b'"')?;
        let start = self.i;
        loop {
            match self.peek()? {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..self.i]).ok()?;
                    self.i += 1;
                    return Some(s);
                }
                b'\\' => return None,
                c if c < 0x20 => return None,
                _ => self.i += 1,
            }
        }
    }

    /// A number with the tree parser's exact grammar and `f64` parse.
    fn number(&mut self) -> Option<f64> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i]).ok()?.parse::<f64>().ok()
    }

    fn literal(&mut self, lit: &str) -> Option<()> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Some(())
        } else {
            None
        }
    }

    /// Validate-and-skip any JSON value the lexer does not care about.
    /// Conservative: escapes inside skipped strings abort the fast path
    /// (the tree parser validates `\uXXXX` pairs; re-checking here is not
    /// worth the code).
    fn skip_value(&mut self) -> Option<()> {
        match self.peek()? {
            b'n' => self.literal("null"),
            b't' => self.literal("true"),
            b'f' => self.literal("false"),
            b'"' => self.string().map(|_| ()),
            b'-' | b'0'..=b'9' => self.number().map(|_| ()),
            b'[' => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Some(());
                }
                loop {
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Some(());
                        }
                        _ => return None,
                    }
                }
            }
            b'{' => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Some(());
                }
                loop {
                    self.ws();
                    self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Some(());
                        }
                        _ => return None,
                    }
                }
            }
            _ => None,
        }
    }

    /// `[[idx, w], ...]` straight into histogram pairs; `None` on any shape
    /// the tree path would reject (its error message must win).
    fn histogram(&mut self) -> Option<Vec<(u32, f32)>> {
        self.eat(b'[')?;
        self.ws();
        let mut pairs = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Some(pairs);
        }
        loop {
            self.ws();
            self.eat(b'[')?;
            self.ws();
            let idx = to_usize(self.number()?)? as u32;
            self.ws();
            self.eat(b',')?;
            self.ws();
            let w = self.number()? as f32;
            self.ws();
            self.eat(b']')?;
            pairs.push((idx, w));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Some(pairs);
                }
                _ => return None,
            }
        }
    }

    /// The `"cascade"` value: `"method"` shorthand or a spec object.
    /// `None` aborts to the tree path (which owns all error messages).
    fn cascade(&mut self) -> Option<CascadeSpec> {
        match self.peek()? {
            b'"' => {
                let m = self.string()?;
                Method::parse(m).ok().map(CascadeSpec::new)
            }
            b'{' => {
                self.i += 1;
                self.ws();
                let mut rerank: Option<&str> = None;
                let mut overfetch: Option<usize> = None;
                let mut certified: Option<bool> = None;
                if self.peek() == Some(b'}') {
                    self.i += 1;
                } else {
                    loop {
                        self.ws();
                        let key = self.string()?;
                        self.ws();
                        self.eat(b':')?;
                        self.ws();
                        match key {
                            "rerank" => {
                                rerank = if self.peek() == Some(b'"') {
                                    Some(self.string()?)
                                } else {
                                    self.skip_value()?;
                                    None
                                };
                            }
                            "overfetch" => overfetch = self.usize_value()?,
                            "certified" => certified = self.bool_value()?,
                            _ => self.skip_value()?,
                        }
                        self.ws();
                        match self.peek()? {
                            b',' => self.i += 1,
                            b'}' => {
                                self.i += 1;
                                break;
                            }
                            _ => return None,
                        }
                    }
                }
                let mut spec = CascadeSpec::new(Method::parse(rerank?).ok()?);
                if let Some(x) = overfetch {
                    spec.overfetch = Some(x.max(1));
                }
                if let Some(b) = certified {
                    spec.certified = b;
                }
                Some(spec)
            }
            _ => None,
        }
    }

    /// A value read with `as_usize` semantics: numbers that are whole and
    /// non-negative yield `Some(Some(n))`; any other valid value yields
    /// `Some(None)` (the tree path ignores it); invalid syntax yields
    /// `None`.
    fn usize_value(&mut self) -> Option<Option<usize>> {
        if matches!(self.peek()?, b'-' | b'0'..=b'9') {
            Some(to_usize(self.number()?))
        } else {
            self.skip_value()?;
            Some(None)
        }
    }

    /// A value read with `as_bool` semantics (same contract as
    /// [`Lex::usize_value`]).
    fn bool_value(&mut self) -> Option<Option<bool>> {
        match self.peek()? {
            b't' => {
                self.literal("true")?;
                Some(Some(true))
            }
            b'f' => {
                self.literal("false")?;
                Some(Some(false))
            }
            _ => {
                self.skip_value()?;
                Some(None)
            }
        }
    }
}

fn decode_inner(line: &str) -> Option<Decoded> {
    let mut lx = Lex { b: line.as_bytes(), i: 0 };
    lx.ws();
    lx.eat(b'{')?;
    lx.ws();

    // last-occurrence-wins per key, matching the tree's BTreeMap insert
    let mut op: Option<&str> = None;
    let mut method: Option<&str> = None;
    let mut l: Option<usize> = None;
    let mut nprobe: Option<usize> = None;
    let mut threads: Option<usize> = None;
    let mut id: Option<usize> = None;
    let mut deadline_ms: Option<usize> = None;
    let mut query: Option<Vec<(u32, f32)>> = None;
    let mut cascade: Option<CascadeSpec> = None;
    let mut trace: Option<bool> = None;
    let mut reset: Option<bool> = None;
    let mut saw_queries = false;

    if lx.peek() == Some(b'}') {
        lx.i += 1;
    } else {
        loop {
            lx.ws();
            let key = lx.string()?;
            lx.ws();
            lx.eat(b':')?;
            lx.ws();
            match key {
                "op" => {
                    op = if lx.peek() == Some(b'"') {
                        Some(lx.string()?)
                    } else {
                        lx.skip_value()?;
                        None
                    };
                }
                "method" => {
                    method = if lx.peek() == Some(b'"') {
                        Some(lx.string()?)
                    } else {
                        lx.skip_value()?;
                        None
                    };
                }
                "l" => l = lx.usize_value()?,
                "nprobe" => nprobe = lx.usize_value()?,
                "threads" => threads = lx.usize_value()?,
                "id" => id = lx.usize_value()?,
                "deadline_ms" => deadline_ms = lx.usize_value()?,
                "query" => {
                    if lx.peek() == Some(b'[') {
                        query = Some(lx.histogram()?);
                    } else {
                        // a non-array query is a tree-path protocol error
                        return None;
                    }
                }
                "queries" => {
                    saw_queries = true;
                    lx.skip_value()?;
                }
                "cascade" => cascade = Some(lx.cascade()?),
                "trace" => trace = lx.bool_value()?,
                "reset" => reset = lx.bool_value()?,
                _ => lx.skip_value()?,
            }
            lx.ws();
            match lx.peek()? {
                b',' => lx.i += 1,
                b'}' => {
                    lx.i += 1;
                    break;
                }
                _ => return None,
            }
        }
    }
    lx.ws();
    if lx.i != lx.b.len() {
        return None; // trailing characters: tree path owns the error
    }

    match op.unwrap_or("search") {
        "ping" => Some(Decoded::Ping),
        "stats" => Some(Decoded::Stats { reset: reset == Some(true) }),
        "search" | "search_id" => {
            // "query" wins over "queries" whatever the key order, exactly
            // like `SearchRequest::from_json`; a "queries"-only request is
            // a (rare) fallback
            let queries = match (query, saw_queries) {
                (Some(pairs), _) => vec![Histogram::from_pairs(pairs)],
                (None, true) => return None,
                (None, false) => Vec::new(),
            };
            let mut req = SearchRequest::batch(queries);
            if let Some(m) = method {
                req.method = Some(Method::parse(m).ok()?);
            }
            if let Some(x) = l {
                req.l = Some(x.max(1));
            }
            if let Some(x) = nprobe {
                req.nprobe = Some(x.max(1));
            }
            req.cascade = cascade;
            if let Some(t) = threads {
                req.threads = Some(t.max(1));
            }
            if let Some(t) = trace {
                req.trace = t;
            }
            Some(Decoded::Search { req, id, deadline_ms: deadline_ms.map(|x| x as u64) })
        }
        _ => None, // unknown op: tree path owns the error
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// Reference decode through the tree codec (the legacy request path).
    fn tree_decode(line: &str) -> Option<(SearchRequest, Option<usize>, Option<u64>)> {
        let j = Json::parse(line).ok()?;
        match j.get("op").and_then(Json::as_str).unwrap_or("search") {
            "search" | "search_id" => {
                let req = SearchRequest::from_json(&j).ok()?;
                let id = j.get("id").and_then(Json::as_usize);
                let dl = j.get("deadline_ms").and_then(Json::as_usize).map(|x| x as u64);
                Some((req, id, dl))
            }
            _ => None,
        }
    }

    /// Every line the lexer *accepts* must decode exactly as the tree does.
    #[test]
    fn lexer_matches_tree_on_accepted_lines() {
        let lines = [
            r#"{"op": "search", "l": 5, "query": [[0, 0.5], [3, 0.5]]}"#,
            r#"{"op":"search_id","id":17,"l":3,"method":"rwmd","nprobe":4}"#,
            r#"{"query": [[1, 1.0]]}"#,
            r#"{"op": "search_id", "id": 3, "l": 4, "method": "act-1"}"#,
            r#"{"op": "search_id", "id": 4, "l": 3, "cascade": "act-3"}"#,
            r#"{"op":"search_id","id":4,"l":3,
               "cascade":{"rerank":"emd","overfetch":16,"certified":true}}"#,
            r#"{"op":"search","query":[],"l":2}"#,
            r#"{"op":"search","query":[[2,0.25]],"threads":2,"deadline_ms":250}"#,
            r#"{"l": 2, "l": 7, "query": [[0, 1.0]]}"#,
            r#"{"l": true, "query": [[0, 1.0]], "unknown": {"nested": [1, "x", null]}}"#,
            r#"{"query": [[0, 1.5e-2]], "nprobe": 0}"#,
            r#"{"op":"search","query":[[0,1.0]],"cascade":{"rerank":"emd"}}"#,
            r#"{"op":"search","query":[[0,1.0]],"l":3,"trace":true}"#,
            r#"{"op":"search","query":[[0,1.0]],"trace":false}"#,
            r#"{"op":"search","query":[[0,1.0]],"trace":null}"#,
            r#"{}"#,
        ];
        for line in lines {
            match decode_line(line.trim()) {
                Decoded::Search { req, id, deadline_ms } => {
                    let (treq, tid, tdl) =
                        tree_decode(line.trim()).expect("tree must accept what the lexer does");
                    assert_eq!(req, treq, "request mismatch on {line}");
                    assert_eq!(id, tid, "id mismatch on {line}");
                    assert_eq!(deadline_ms, tdl, "deadline mismatch on {line}");
                }
                other => panic!("expected fast-path search for {line}, got {other:?}"),
            }
        }
    }

    #[test]
    fn lexer_fast_paths_ping_and_stats() {
        assert!(matches!(decode_line(r#"{"op": "ping"}"#), Decoded::Ping));
        assert!(matches!(decode_line(r#"{"op":"stats"}"#), Decoded::Stats { reset: false }));
        // the reset flag must not be swallowed by the unknown-key skip
        assert!(matches!(
            decode_line(r#"{"op":"stats","reset":true}"#),
            Decoded::Stats { reset: true }
        ));
        assert!(matches!(
            decode_line(r#"{"op":"stats","reset":false}"#),
            Decoded::Stats { reset: false }
        ));
        // non-boolean reset reads as absent, like the tree's as_bool
        assert!(matches!(
            decode_line(r#"{"op":"stats","reset":1}"#),
            Decoded::Stats { reset: false }
        ));
        // non-string op falls through to the "search" default, like the tree
        assert!(matches!(decode_line(r#"{"op": 3}"#), Decoded::Search { .. }));
    }

    #[test]
    fn lexer_reads_the_trace_flag() {
        match decode_line(r#"{"op":"search","query":[[0,1.0]],"trace":true}"#) {
            Decoded::Search { req, .. } => assert!(req.trace),
            other => panic!("expected fast-path search, got {other:?}"),
        }
        match decode_line(r#"{"op":"search","query":[[0,1.0]]}"#) {
            Decoded::Search { req, .. } => assert!(!req.trace, "default is untraced"),
            other => panic!("expected fast-path search, got {other:?}"),
        }
    }

    #[test]
    fn lexer_falls_back_when_uncertain() {
        let fallback_lines = [
            "{not json",                                     // malformed
            r#"{"op": "nope"}"#,                             // unknown op (tree owns error)
            r#"{"op": "add_docs", "docs": [[[1, 1.0]]]}"#,   // cold path
            r#"{"op": "search", "queries": [[[0, 1.0]]]}"#,  // multi-query form
            r#"{"method": "magic", "query": [[0,1]]}"#,      // unknown method name
            r#"{"query": "bogus"}"#,                         // tree-path protocol error
            r#"{"query": [[0, 1.0]]} trailing"#,             // trailing chars
            r#"{"cascade": "nope", "query": [[0,1.0]]}"#,    // unknown cascade method
            "{\"method\": \"b\\u006fw\", \"query\": [[0,1]]}", // escape sequences
        ];
        for line in fallback_lines {
            match decode_line(line) {
                Decoded::Fallback => {}
                other => panic!("expected fallback for {line}, got {other:?}"),
            }
        }
    }

    #[test]
    fn result_writer_matches_tree_serializer() {
        use std::collections::BTreeMap;
        let res = SearchResult {
            hits: vec![(0.0, 3), (0.125, 11), (2.5, 7), (1.0, 123456)],
            labels: vec![1, 0, 9, 65535],
        };
        for certified in [None, Some(true), Some(false)] {
            for partial in [false, true] {
                // the tree the legacy server used to build
                let mut map: BTreeMap<String, Json> = BTreeMap::new();
                map.insert("ok".into(), Json::Bool(true));
                map.insert(
                    "hits".into(),
                    Json::Arr(
                        res.hits
                            .iter()
                            .zip(&res.labels)
                            .map(|(&(d, id), &lab)| {
                                Json::Arr(vec![
                                    Json::Num(d as f64),
                                    Json::Num(id as f64),
                                    Json::Num(lab as f64),
                                ])
                            })
                            .collect(),
                    ),
                );
                if let Some(c) = certified {
                    map.insert("certified".into(), Json::Bool(c));
                }
                if partial {
                    map.insert("partial".into(), Json::Bool(true));
                }
                let tree = Json::Obj(map).to_string_compact();
                let streamed =
                    String::from_utf8(search_result_line(&res, certified, partial, None))
                        .unwrap();
                assert_eq!(streamed, tree);
            }
        }
    }

    #[test]
    fn traced_result_line_appends_the_timeline_after_ok() {
        let res = SearchResult { hits: vec![(0.5, 2)], labels: vec![1] };
        let spans = [SpanRec {
            trace_id: 3,
            span_id: 1,
            parent_id: 0,
            name: 0,
            tid: 0,
            start_us: 0,
            dur_us: 120,
        }];
        let line =
            String::from_utf8(search_result_line(&res, None, false, Some(&spans))).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let tl = j.get("trace").and_then(Json::as_arr).expect("timeline present");
        assert_eq!(tl.len(), 1);
        assert_eq!(tl[0].get("name").and_then(Json::as_str), Some("request"));
        assert_eq!(tl[0].get("dur_us").and_then(Json::as_usize), Some(120));
        // BTreeMap key order is preserved: the timeline rides after "ok"
        assert!(line.ends_with("}]}"), "{line}");
        assert_eq!(line, Json::parse(&line).unwrap().to_string_compact(), "canonical form");
        // and the untraced line is a strict prefix + '}' of the traced one
        let plain = String::from_utf8(search_result_line(&res, None, false, None)).unwrap();
        assert!(line.starts_with(plain.trim_end_matches('}')), "{plain} vs {line}");
    }

    #[test]
    fn error_writers_match_tree_serializer() {
        for msg in ["plain", "bad request: with \"quotes\" and \\", "uni é"] {
            let tree = Json::obj(vec![("ok", false.into()), ("error", msg.into())])
                .to_string_compact();
            assert_eq!(String::from_utf8(error_line(msg)).unwrap(), tree);
        }
        let tree = Json::obj(vec![
            ("ok", false.into()),
            ("error", OVERLOADED_MSG.into()),
            ("retry_after_ms", 7usize.into()),
        ])
        .to_string_compact();
        assert_eq!(String::from_utf8(overload_line(7)).unwrap(), tree);
    }
}
