//! LC-ACT Phase 1 (paper Fig. 6): given a query, compute against the whole
//! vocabulary the distance matrix D (v, h), the top-k smallest distances
//! Z (v, k), their query-bin indices S (v, k) and the gathered capacity
//! matrix W (v, k) = qw[S].
//!
//! This runs once per query and is amortized across every database
//! histogram — the redundancy elimination that takes the batched complexity
//! from quadratic to linear (paper Section 5 / Table 3).
//!
//! Data-parallel over vocabulary rows via [`parallel_for`]; tie-breaking is
//! lowest-query-bin-index first, bit-identical to the Pallas kernel and the
//! numpy oracle.

use crate::approx::act::row_topk;
use crate::core::{Embeddings, Histogram, Metric};
use crate::lc::kernels::{self, KernelBackend};
use crate::util::threadpool::{parallel_for, SyncSlice};

/// Per-query preprocessing product.
#[derive(Debug, Clone, Default)]
pub struct QueryPlan {
    /// Number of transfer targets (ACT-(k-1)); k = 1 is LC-RWMD.
    pub k: usize,
    /// Query support size h.
    pub h: usize,
    /// Query weights (normalized), length h.
    pub qw: Vec<f32>,
    /// `(v, k)` ascending top-k distances per vocabulary coordinate.
    pub z: Vec<f32>,
    /// `(v, k)` query-bin index of each top-k entry.
    pub s: Vec<u32>,
    /// `(v, k)` capacities: `w[i, l] = qw[s[i, l]]`.
    pub w: Vec<f32>,
    /// Optional full `(v, h)` distance matrix (kept for direction-B RWMD).
    pub d: Option<Vec<f32>>,
}

/// Phase-1 configuration.
#[derive(Debug, Clone, Copy)]
pub struct PlanParams {
    pub k: usize,
    pub metric: Metric,
    /// Keep the full D matrix (needed by direction-B RWMD; costs v*h f32).
    pub keep_d: bool,
    pub threads: usize,
    /// Forced kernel backend; `None` uses the process-wide selection
    /// ([`crate::lc::kernels::active`]: best detected unless `EMDPAR_KERNEL`
    /// overrides it).  Every backend is bit-identical, so this knob only
    /// changes speed, never results.
    pub kernel: Option<KernelBackend>,
}

/// The crate's canonical dot-product arithmetic — now defined by the scalar
/// kernel backend ([`crate::lc::kernels::scalar::dot`]): 16 independent
/// accumulator lanes, unfused multiply-then-add, in-order lane reduction,
/// serial tail.  The SIMD backends reproduce this bit-for-bit; hot paths
/// dispatch through [`crate::lc::kernels::dot_with`] instead of calling this
/// directly.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    kernels::scalar::dot(a, b)
}

/// The Gram-expansion form of the snapped distance: `d²(i,j) = |v|² −
/// 2⟨v,q_j⟩ + |q_j|²` with cancellation noise below the relative floor
/// collapsed to an exact 0 (the overlap rule).  One function shared by the
/// single-query kernel and the batched multi-query kernel so the two paths
/// are bit-identical by construction.
#[inline]
pub(crate) fn l2_snap(vn: f32, dot: f32, qn: f32) -> f32 {
    let d2 = vn - 2.0 * dot + qn;
    let scale = vn + qn;
    if d2 <= 1e-6 * scale {
        0.0
    } else {
        d2.max(0.0).sqrt()
    }
}

/// Squared-L2 distance with the same snap-to-zero the Pallas kernel applies:
/// values below the relative cancellation floor collapse to exact 0 so the
/// OMR/ICT overlap rule fires deterministically.
#[inline]
pub fn snapped_distance(metric: Metric, a: &[f32], b: &[f32]) -> f32 {
    match metric {
        Metric::L2 => {
            let mut d2 = 0.0f32;
            let mut scale = 0.0f32;
            for (&x, &y) in a.iter().zip(b) {
                let diff = x - y;
                d2 += diff * diff;
                scale += x * x + y * y;
            }
            if d2 <= 1e-6 * scale {
                0.0
            } else {
                d2.sqrt()
            }
        }
        other => other.distance(a, b),
    }
}

/// Build the Phase-1 plan for one query histogram.
///
/// `vn` is the vocabulary row squared-norm table
/// ([`crate::core::Embeddings::row_sq_norms`]), computed once per dataset —
/// [`crate::lc::LcEngine`] owns it, so all-pairs sweeps no longer redo the
/// `O(v·m)` reduction per query (an `O(n·v·m)` waste at the seed).
pub fn plan_query(
    vocab: &Embeddings,
    vn: &[f32],
    query: &Histogram,
    params: PlanParams,
) -> QueryPlan {
    assert_eq!(vn.len(), vocab.num_vectors(), "vocab norm table size mismatch");
    let qn = query.normalized();
    let h = qn.len();
    assert!(h > 0, "empty query histogram");
    let k = params.k.clamp(1, h);
    let v = vocab.num_vectors();

    // Gather the query coordinate matrix Q (h, m) once for cache locality.
    let q_coords = vocab.gather(qn.indices());
    let qw: Vec<f32> = qn.weights().to_vec();
    let q_support: Vec<u32> = qn.indices().to_vec();

    let mut z = vec![0.0f32; v * k];
    let mut s = vec![0u32; v * k];
    let mut w = vec![0.0f32; v * k];
    let mut d = if params.keep_d { vec![0.0f32; v * h] } else { Vec::new() };

    // Query squared norms gathered from the precomputed table (bit-equal to
    // re-summing the gathered rows: same values, same order).
    let q_norms: Vec<f32> = qn.indices().iter().map(|&i| vn[i as usize]).collect();
    let use_expansion = params.metric == Metric::L2;
    let kb = params.kernel.unwrap_or_else(kernels::active);

    {
        let zs = SyncSlice::new(&mut z);
        let ss = SyncSlice::new(&mut s);
        let ws = SyncSlice::new(&mut w);
        let ds = SyncSlice::new(&mut d);
        let keep_d = params.keep_d;
        let qw_ref = &qw;
        let q_support_ref = &q_support;
        let q_coords_ref = &q_coords;
        let q_norms_ref = &q_norms;
        parallel_for(v, params.threads, |start, end| {
            let mut row = vec![0.0f32; h];
            let mut vals: Vec<f32> = Vec::with_capacity(k);
            let mut idxs: Vec<u32> = Vec::with_capacity(k);
            for i in start..end {
                let vi = vocab.row(i);
                if use_expansion {
                    // Branch-free GEMV: d²(i,j) = |v|² − 2⟨v,q_j⟩ + |q_j|²,
                    // exactly the Pallas kernel's formulation (same snap, so
                    // all three layers agree on overlap zeros).  The dot
                    // loop over m autovectorizes (AVX-512: 16 f32 lanes).
                    let vni = vn[i];
                    for j in 0..h {
                        let qj = q_coords_ref.row(j);
                        row[j] = l2_snap(vni, kernels::dot_with(kb, vi, qj), q_norms_ref[j]);
                    }
                    // the query bin that *is* this vocabulary entry must be
                    // exactly 0 regardless of rounding (indices are sorted)
                    if let Ok(pos) = q_support_ref.binary_search(&(i as u32)) {
                        row[pos] = 0.0;
                    }
                } else {
                    for j in 0..h {
                        row[j] = if q_support_ref[j] as usize == i {
                            0.0
                        } else {
                            snapped_distance(params.metric, vi, q_coords_ref.row(j))
                        };
                    }
                }
                row_topk(&row, k, &mut vals, &mut idxs);
                // SAFETY: row i is owned by exactly this chunk.
                unsafe {
                    let zrow = zs.slice_mut(i * k, (i + 1) * k);
                    let srow = ss.slice_mut(i * k, (i + 1) * k);
                    let wrow = ws.slice_mut(i * k, (i + 1) * k);
                    for l in 0..k {
                        zrow[l] = vals[l];
                        srow[l] = idxs[l];
                        wrow[l] = qw_ref[idxs[l] as usize];
                    }
                    if keep_d {
                        ds.slice_mut(i * h, (i + 1) * h).copy_from_slice(&row);
                    }
                }
            }
        });
    }

    QueryPlan { k, h, qw, z, s, w, d: if params.keep_d { Some(d) } else { None } }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64, v: usize, h: usize, m: usize) -> (Embeddings, Histogram) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let vocab = Embeddings::new(data, v, m);
        let idx = rng.sample_indices(v, h);
        let q = Histogram::from_pairs(
            idx.into_iter().map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32)).collect(),
        );
        (vocab, q)
    }

    #[test]
    fn z_rows_ascending_and_consistent_with_s() {
        let (vocab, q) = setup(1, 40, 10, 4);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 4, metric: Metric::L2, keep_d: true, threads: 2, kernel: None },
        );
        let d = plan.d.as_ref().unwrap();
        for i in 0..40 {
            let zrow = &plan.z[i * 4..(i + 1) * 4];
            assert!(zrow.windows(2).all(|w| w[0] <= w[1]), "row {i} not ascending");
            for l in 0..4 {
                let j = plan.s[i * 4 + l] as usize;
                assert_eq!(d[i * plan.h + j], zrow[l]);
                assert_eq!(plan.w[i * 4 + l], plan.qw[j]);
            }
        }
    }

    #[test]
    fn own_coordinate_has_zero_distance() {
        let (vocab, q) = setup(2, 30, 8, 3);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 1, metric: Metric::L2, keep_d: false, threads: 1, kernel: None },
        );
        // every vocabulary coordinate that is in the query support must have
        // top-1 distance zero (it overlaps itself)
        let qn = q.normalized();
        for (pos, &i) in qn.indices().iter().enumerate() {
            assert_eq!(plan.z[i as usize * 1], 0.0, "support coord {i}");
            assert_eq!(plan.s[i as usize * 1] as usize, pos);
        }
    }

    #[test]
    fn threads_do_not_change_result() {
        let (vocab, q) = setup(3, 64, 12, 5);
        let vn = vocab.row_sq_norms();
        let p1 = plan_query(
            &vocab,
            &vn,
            &q,
            PlanParams { k: 3, metric: Metric::L2, keep_d: true, threads: 1, kernel: None },
        );
        let p8 = plan_query(
            &vocab,
            &vn,
            &q,
            PlanParams { k: 3, metric: Metric::L2, keep_d: true, threads: 8, kernel: None },
        );
        assert_eq!(p1.z, p8.z);
        assert_eq!(p1.s, p8.s);
        assert_eq!(p1.d, p8.d);
    }

    #[test]
    fn k_clamps_to_h() {
        let (vocab, q) = setup(4, 20, 3, 2);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 10, metric: Metric::L2, keep_d: false, threads: 1, kernel: None },
        );
        assert_eq!(plan.k, 3);
    }
}
