//! Linear-complexity data-parallel engines (paper Section 5): LC-RWMD and
//! LC-ACT, factored as Phase 1 (per-query, vs the vocabulary) and Phases
//! 2+3 (per database tile).  CPU-native implementation; the PJRT artifact
//! path in [`crate::runtime`] executes the same pipeline from AOT-compiled
//! JAX/Pallas HLO.

pub mod engine;
pub mod plan;
pub mod transfers;

pub use engine::{EngineParams, LcEngine, Method};
pub use plan::{plan_query, snapped_distance, PlanParams, QueryPlan};
pub use transfers::{
    act_direction_a, omr_direction_a, rwmd_direction_a, rwmd_direction_b,
};
