//! Linear-complexity data-parallel engines (paper Section 5): LC-RWMD and
//! LC-ACT, factored as Phase 1 (per-query, vs the vocabulary) and Phases
//! 2+3 (per database tile).  CPU-native implementation; the PJRT artifact
//! path in [`crate::runtime`] executes the same pipeline from AOT-compiled
//! JAX/Pallas HLO.
//!
//! Method selection uses the canonical [`crate::core::Method`] enum
//! (re-exported here for convenience); the engine also serves the per-pair
//! comparators through the same interface via [`crate::core::MethodRegistry`].

pub mod batch_plan;
pub mod engine;
pub mod kernels;
pub mod plan;
pub mod transfers;

pub use crate::core::Method;
pub use batch_plan::{BatchPlanner, PlanScratch, DEFAULT_BATCH_BLOCK};
pub use engine::{EngineParams, LcBatch, LcEngine};
pub use kernels::KernelBackend;
pub use plan::{plan_query, snapped_distance, PlanParams, QueryPlan};
pub use transfers::{
    act_direction_a, act_direction_a_into, direction_a_block_into, direction_b_block_into,
    omr_direction_a, omr_direction_a_into, rwmd_direction_a, rwmd_direction_a_into,
    rwmd_direction_b, rwmd_direction_b_into,
};
