//! LC-ACT Phases 2+3 over the CSR database matrix (paper Fig. 7 /
//! eq. (6)-(9)) plus the LC forms of OMR and direction-B RWMD.
//!
//! Data-parallel over database rows; per-document cost is O(h̄·k) for ACT
//! and O(h̄·h) for direction-B RWMD.  All inner loops operate on the CSR
//! arrays directly — no dense scatter on the native path (the PJRT artifact
//! path densifies into fixed tiles instead; both produce the same numbers,
//! which the integration tests assert).
//!
//! Two sweep shapes share one set of per-row cost helpers:
//!
//! * **per-plan** (`*_direction_a_into` / `rwmd_direction_b_into`): one
//!   query plan against every database row — the single-query and all-pairs
//!   paths.
//! * **per-block** ([`direction_a_block_into`] / [`direction_b_block_into`]):
//!   a whole Phase-1 batch block of plans in **one** pass over the database
//!   — each CSR row is fetched from memory once for all plans in the block
//!   instead of once per plan, the Phase-2 mirror of the batched Phase-1
//!   vocabulary streaming.  Because both shapes call the same row helpers,
//!   block outputs are bit-identical to per-plan outputs by construction
//!   (asserted by `rust/tests/batch_equivalence.rs`).

use crate::core::{CsrMatrix, Method};
use crate::util::threadpool::{parallel_for, SyncSlice};

use super::plan::QueryPlan;

/// ACT-(k-1) transfer cost of one database row into the query
/// (eq. (6)-(9), CSR form).  f64 accumulation, cast once at the write site.
#[inline]
fn act_row_cost(plan: &QueryPlan, idx: &[u32], w: &[f32]) -> f64 {
    let k = plan.k;
    let mut t = 0.0f64;
    for (&i, &xw) in idx.iter().zip(w) {
        let base = i as usize * k;
        let zrow = &plan.z[base..base + k];
        let wrow = &plan.w[base..base + k];
        let mut pi = xw as f64;
        for l in 0..k - 1 {
            let r = pi.min(wrow[l] as f64);
            pi -= r;
            t += r * zrow[l] as f64;
        }
        t += pi * zrow[k - 1] as f64;
    }
    t
}

/// LC-RWMD cost of one database row: every coordinate's whole weight ships
/// at the nearest-query-coordinate distance (k = 1 special case).
#[inline]
fn rwmd_row_cost(plan: &QueryPlan, idx: &[u32], w: &[f32]) -> f64 {
    let k = plan.k;
    let mut t = 0.0f64;
    for (&i, &xw) in idx.iter().zip(w) {
        t += xw as f64 * plan.z[i as usize * k] as f64;
    }
    t
}

/// LC-OMR cost of one database row (Algorithm 1): free transfer only
/// between *overlapping* coordinates (z1 == 0), capacity `min(x, w1)`;
/// remainder to the second closest.  Requires `plan.k >= 2`.
#[inline]
fn omr_row_cost(plan: &QueryPlan, idx: &[u32], w: &[f32]) -> f64 {
    let k = plan.k;
    let mut t = 0.0f64;
    for (&i, &xw) in idx.iter().zip(w) {
        let base = i as usize * k;
        let z1 = plan.z[base];
        if z1 == 0.0 {
            let cap = plan.w[base] as f64;
            let rest = (xw as f64 - cap).max(0.0);
            t += rest * plan.z[base + 1] as f64;
        } else {
            t += xw as f64 * z1 as f64;
        }
    }
    t
}

/// Direction-B RWMD cost of one database row: `Σ_j qw_j · min_{i ∈ supp}
/// D[i, j]` (masked min-plus product).  `d` is the plan's full D matrix and
/// `r` a caller-owned scratch row of length `plan.h`.
#[inline]
fn rwmd_b_row_cost(plan: &QueryPlan, d: &[f32], idx: &[u32], r: &mut [f32]) -> f64 {
    let h = plan.h;
    if idx.is_empty() {
        return 0.0;
    }
    r.copy_from_slice(&d[idx[0] as usize * h..(idx[0] as usize + 1) * h]);
    for &i in &idx[1..] {
        let drow = &d[i as usize * h..(i as usize + 1) * h];
        // lane-chunked min: compiles to packed vminps (the
        // branchy form defeats vectorization on some LLVMs)
        const LANES: usize = 16;
        let chunks = h / LANES;
        for c in 0..chunks {
            let rs = &mut r[c * LANES..c * LANES + LANES];
            let ds_ = &drow[c * LANES..c * LANES + LANES];
            for l in 0..LANES {
                rs[l] = rs[l].min(ds_[l]);
            }
        }
        for t in chunks * LANES..h {
            r[t] = r[t].min(drow[t]);
        }
    }
    r.iter().zip(&plan.qw).map(|(&c, &w)| c as f64 * w as f64).sum()
}

/// Direction-A cost of one row under `method` (the dispatch the engine and
/// both sweep shapes share): RWMD, OMR (degenerating to RWMD at k = 1) or
/// ACT for everything else.
#[inline]
fn direction_a_row_cost(method: Method, plan: &QueryPlan, idx: &[u32], w: &[f32]) -> f64 {
    match method {
        Method::Rwmd => rwmd_row_cost(plan, idx, w),
        Method::Omr => {
            if plan.k < 2 {
                rwmd_row_cost(plan, idx, w)
            } else {
                omr_row_cost(plan, idx, w)
            }
        }
        _ => act_row_cost(plan, idx, w),
    }
}

/// ACT-(k-1) direction-A bounds written into a caller-owned slice (the
/// zero-allocation form the batched all-pairs sweep writes matrix rows
/// through): cost of moving every database histogram into the query
/// (eq. (6)-(9), CSR form).
pub fn act_direction_a_into(plan: &QueryPlan, db: &CsrMatrix, threads: usize, out: &mut [f32]) {
    let n = db.nrows();
    assert_eq!(out.len(), n, "output row length mismatch");
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        for u in start..end {
            let (idx, w) = db.row(u);
            // SAFETY: row u owned by this chunk.
            unsafe { slots.write(u, act_row_cost(plan, idx, w) as f32) };
        }
    });
}

/// Allocating wrapper around [`act_direction_a_into`].
pub fn act_direction_a(plan: &QueryPlan, db: &CsrMatrix, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; db.nrows()];
    act_direction_a_into(plan, db, threads, &mut out);
    out
}

/// LC-RWMD (paper Atasu et al. 2017) into a caller-owned slice: k=1 special
/// case — every coordinate's whole weight ships at the
/// nearest-query-coordinate distance.
pub fn rwmd_direction_a_into(plan: &QueryPlan, db: &CsrMatrix, threads: usize, out: &mut [f32]) {
    let n = db.nrows();
    assert_eq!(out.len(), n, "output row length mismatch");
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        for u in start..end {
            let (idx, w) = db.row(u);
            unsafe { slots.write(u, rwmd_row_cost(plan, idx, w) as f32) };
        }
    });
}

/// Allocating wrapper around [`rwmd_direction_a_into`].
pub fn rwmd_direction_a(plan: &QueryPlan, db: &CsrMatrix, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; db.nrows()];
    rwmd_direction_a_into(plan, db, threads, &mut out);
    out
}

/// LC-OMR (Algorithm 1, batched) into a caller-owned slice: free transfer
/// only between *overlapping* coordinates (z1 == 0), capacity `min(x, w1)`;
/// remainder to the second closest.  Requires a plan with k >= 2 (k == 1
/// degenerates to LC-RWMD).
pub fn omr_direction_a_into(plan: &QueryPlan, db: &CsrMatrix, threads: usize, out: &mut [f32]) {
    let n = db.nrows();
    assert_eq!(out.len(), n, "output row length mismatch");
    if plan.k < 2 {
        rwmd_direction_a_into(plan, db, threads, out);
        return;
    }
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        for u in start..end {
            let (idx, w) = db.row(u);
            unsafe { slots.write(u, omr_row_cost(plan, idx, w) as f32) };
        }
    });
}

/// Allocating wrapper around [`omr_direction_a_into`].
pub fn omr_direction_a(plan: &QueryPlan, db: &CsrMatrix, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; db.nrows()];
    omr_direction_a_into(plan, db, threads, &mut out);
    out
}

/// Direction-B RWMD into a caller-owned slice: cost of moving the query
/// into each database histogram — `Σ_j qw_j · min_{i ∈ supp(x_u)} D[i, j]`
/// (masked min-plus product).  Needs the plan's full D matrix
/// (`keep_d: true`).
pub fn rwmd_direction_b_into(plan: &QueryPlan, db: &CsrMatrix, threads: usize, out: &mut [f32]) {
    let d = plan
        .d
        .as_ref()
        .expect("direction-B RWMD needs plan_query(.., keep_d: true)");
    let h = plan.h;
    let n = db.nrows();
    assert_eq!(out.len(), n, "output row length mismatch");
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        let mut r = vec![0.0f32; h];
        for u in start..end {
            let (idx, _) = db.row(u);
            unsafe { slots.write(u, rwmd_b_row_cost(plan, d, idx, &mut r) as f32) };
        }
    });
}

/// Allocating wrapper around [`rwmd_direction_b_into`].
pub fn rwmd_direction_b(plan: &QueryPlan, db: &CsrMatrix, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; db.nrows()];
    rwmd_direction_b_into(plan, db, threads, &mut out);
    out
}

/// Direction-A Phase 2 for a whole batch block of plans in **one** pass
/// over the database: each CSR row is fetched once and scored against every
/// plan in the block (the per-plan sweep re-streams the database per plan).
///
/// `out` is plan-major: `out[p * n + u]` is plan `p`'s cost for row `u`.
/// Each `(p, u)` value comes from the same row helper as the per-plan
/// sweeps, so this is bit-identical to `plans.len()` independent
/// `*_direction_a_into` calls.
pub fn direction_a_block_into(
    method: Method,
    plans: &[QueryPlan],
    db: &CsrMatrix,
    threads: usize,
    out: &mut [f32],
) {
    let n = db.nrows();
    assert_eq!(out.len(), plans.len() * n, "block output size mismatch");
    if plans.is_empty() {
        return;
    }
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        for u in start..end {
            let (idx, w) = db.row(u);
            for (p, plan) in plans.iter().enumerate() {
                let t = direction_a_row_cost(method, plan, idx, w);
                // SAFETY: cell (p, u) is owned by the chunk owning row u.
                unsafe { slots.write(p * n + u, t as f32) };
            }
        }
    });
}

/// Direction-B RWMD for a whole batch block of plans in one database pass
/// (see [`direction_a_block_into`] for the layout and bit-identity
/// argument).  Every plan must carry its full D matrix (`keep_d: true`).
pub fn direction_b_block_into(
    plans: &[QueryPlan],
    db: &CsrMatrix,
    threads: usize,
    out: &mut [f32],
) {
    let n = db.nrows();
    assert_eq!(out.len(), plans.len() * n, "block output size mismatch");
    if plans.is_empty() {
        return;
    }
    let ds: Vec<&[f32]> = plans
        .iter()
        .map(|p| {
            p.d.as_ref()
                .expect("direction-B RWMD needs plan_query(.., keep_d: true)")
                .as_slice()
        })
        .collect();
    let max_h = plans.iter().map(|p| p.h).max().unwrap_or(0);
    let slots = SyncSlice::new(out);
    parallel_for(n, threads, |start, end| {
        let mut r = vec![0.0f32; max_h];
        for u in start..end {
            let (idx, _) = db.row(u);
            for (p, plan) in plans.iter().enumerate() {
                let t = rwmd_b_row_cost(plan, ds[p], idx, &mut r[..plan.h]);
                // SAFETY: cell (p, u) is owned by the chunk owning row u.
                unsafe { slots.write(p * n + u, t as f32) };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{act_with_cost, omr_with_cost, rwmd_with_cost};
    use crate::core::{support_cost_matrix, Embeddings, Histogram, Metric};
    use crate::lc::plan::{plan_query, PlanParams};
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        v: usize,
        h: usize,
        m: usize,
        n: usize,
    ) -> (Embeddings, Histogram, Vec<Histogram>, CsrMatrix) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let vocab = Embeddings::new(data, v, m);
        let mk = |rng: &mut Rng, sz: usize| {
            let idx = rng.sample_indices(v, sz);
            Histogram::from_pairs(
                idx.into_iter().map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32)).collect(),
            )
            .normalized()
        };
        let q = mk(&mut rng, h);
        let docs: Vec<Histogram> = (0..n).map(|_| mk(&mut rng, h.min(v / 2))).collect();
        let db = CsrMatrix::from_histograms(&docs, v);
        (vocab, q, docs, db)
    }

    /// The decisive semantic test: LC engine == per-pair Algorithm 1/3 for
    /// every document, every k.
    #[test]
    fn lc_matches_per_pair_algorithms() {
        let (vocab, q, docs, db) = setup(1, 40, 10, 4, 15);
        let qn = q.normalized();
        for k in [1usize, 2, 4, 8] {
            let plan = plan_query(
                &vocab,
                &vocab.row_sq_norms(),
                &q,
                PlanParams { k, metric: Metric::L2, keep_d: true, threads: 3, kernel: None },
            );
            let act = act_direction_a(&plan, &db, 3);
            let omr = omr_direction_a(&plan, &db, 3);
            let rwb = rwmd_direction_b(&plan, &db, 3);
            for (u, doc) in docs.iter().enumerate() {
                let cost =
                    support_cost_matrix(&vocab, doc.indices(), qn.indices(), Metric::L2);
                let want_act =
                    act_with_cost(doc.weights(), qn.weights(), &cost, qn.len(), k);
                assert!(
                    (act[u] as f64 - want_act).abs() < 1e-5,
                    "k={k} doc={u}: lc {} vs pair {want_act}",
                    act[u]
                );
                if k >= 2 {
                    let want_omr =
                        omr_with_cost(doc.weights(), qn.weights(), &cost, qn.len());
                    assert!(
                        (omr[u] as f64 - want_omr).abs() < 1e-5,
                        "omr doc={u}: {} vs {want_omr}",
                        omr[u]
                    );
                }
                // direction B: move query into doc = directed RWMD(q -> doc)
                let cost_t =
                    support_cost_matrix(&vocab, qn.indices(), doc.indices(), Metric::L2);
                let want_b = rwmd_with_cost(qn.weights(), &cost_t, doc.len());
                assert!(
                    (rwb[u] as f64 - want_b).abs() < 1e-5,
                    "rwmd_b doc={u}: {} vs {want_b}",
                    rwb[u]
                );
            }
        }
    }

    #[test]
    fn k1_act_equals_lc_rwmd() {
        let (vocab, q, _, db) = setup(2, 32, 8, 3, 10);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 1, metric: Metric::L2, keep_d: false, threads: 2, kernel: None },
        );
        let a = act_direction_a(&plan, &db, 2);
        let b = rwmd_direction_a(&plan, &db, 2);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn bounds_monotone_in_k() {
        let (vocab, q, _, db) = setup(3, 48, 12, 4, 20);
        let mut prev = vec![0.0f32; db.nrows()];
        for k in [1usize, 2, 4, 8] {
            let plan = plan_query(
                &vocab,
                &vocab.row_sq_norms(),
                &q,
                PlanParams { k, metric: Metric::L2, keep_d: false, threads: 2, kernel: None },
            );
            let t = act_direction_a(&plan, &db, 2);
            for (u, (&cur, &pre)) in t.iter().zip(&prev).enumerate() {
                assert!(cur + 1e-5 >= pre, "doc {u}: ACT not monotone in k");
            }
            prev = t;
        }
    }

    #[test]
    fn self_distance_zero_with_k2() {
        // the query itself is in the database: ACT-1 must give 0
        let (vocab, q, mut docs, _) = setup(4, 30, 8, 3, 5);
        docs.push(q.normalized());
        let db = CsrMatrix::from_histograms(&docs, 30);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 2, metric: Metric::L2, keep_d: false, threads: 1, kernel: None },
        );
        let t = act_direction_a(&plan, &db, 1);
        assert!(t[5].abs() < 1e-6, "self distance {}", t[5]);
    }

    #[test]
    fn empty_row_zero_cost() {
        let (vocab, q, mut docs, _) = setup(5, 30, 8, 3, 2);
        docs.push(Histogram::from_pairs(vec![]));
        let db = CsrMatrix::from_histograms(&docs, 30);
        let plan = plan_query(
            &vocab,
            &vocab.row_sq_norms(),
            &q,
            PlanParams { k: 2, metric: Metric::L2, keep_d: true, threads: 1, kernel: None },
        );
        assert_eq!(act_direction_a(&plan, &db, 1)[2], 0.0);
        assert_eq!(rwmd_direction_b(&plan, &db, 1)[2], 0.0);
    }

    #[test]
    fn block_sweeps_match_per_plan_sweeps_bitwise() {
        // the one-db-pass block form must equal independent per-plan sweeps
        // exactly, for every method and thread count (shared row helpers)
        let (vocab, _, docs, db) = setup(6, 36, 9, 4, 14);
        let vn = vocab.row_sq_norms();
        let queries: Vec<Histogram> = docs[..4].to_vec();
        let n = db.nrows();
        for method in [Method::Rwmd, Method::Omr, Method::Act { k: 3 }] {
            let params = PlanParams {
                k: method.plan_k(),
                metric: Metric::L2,
                keep_d: true,
                threads: 1,
                kernel: None,
            };
            let plans: Vec<QueryPlan> =
                queries.iter().map(|q| plan_query(&vocab, &vn, q, params)).collect();
            for threads in [1usize, 3] {
                let mut block = vec![0.0f32; plans.len() * n];
                direction_a_block_into(method, &plans, &db, threads, &mut block);
                let mut block_b = vec![0.0f32; plans.len() * n];
                direction_b_block_into(&plans, &db, threads, &mut block_b);
                for (p, plan) in plans.iter().enumerate() {
                    let mut single = vec![0.0f32; n];
                    match method {
                        Method::Rwmd => rwmd_direction_a_into(plan, &db, 1, &mut single),
                        Method::Omr => omr_direction_a_into(plan, &db, 1, &mut single),
                        _ => act_direction_a_into(plan, &db, 1, &mut single),
                    }
                    assert_eq!(&block[p * n..(p + 1) * n], &single[..], "{method} plan {p}");
                    let mut single_b = vec![0.0f32; n];
                    rwmd_direction_b_into(plan, &db, 1, &mut single_b);
                    assert_eq!(
                        &block_b[p * n..(p + 1) * n],
                        &single_b[..],
                        "{method} plan {p} direction B"
                    );
                }
            }
        }
    }
}
