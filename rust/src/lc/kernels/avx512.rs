//! AVX-512F backend: the scalar contract's 16 accumulator lanes map onto a
//! **single** `zmm` register per dot product, updated with an unfused
//! `vmulps` + `vaddps` pair (never `vfmadd512`: the contract rounds each
//! product before adding).  The reduction stores the register back to a
//! 16-lane array and sums it serially in lane order — *not*
//! `_mm512_reduce_add_ps`, whose tree order would change the rounding — so
//! every result is bit-identical to [`super::scalar`].
//!
//! All functions are `unsafe`: the caller must have verified `avx512f`
//! support (see [`super::KernelBackend::is_supported`]) — the dispatcher in
//! [`super`] is the only caller.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use super::scalar::LANES;
use crate::core::compress::f16_to_f32;

/// # Safety
/// Requires `avx512f` (checked by the dispatcher before the call).
#[target_feature(enable = "avx512f")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm512_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x = _mm512_loadu_ps(ap.add(o));
        let y = _mm512_loadu_ps(bp.add(o));
        acc = _mm512_add_ps(acc, _mm512_mul_ps(x, y));
    }
    let mut lanes = [0.0f32; LANES];
    _mm512_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut dot = 0.0f32;
    for &x in lanes.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += a[t] * b[t];
    }
    dot
}

/// # Safety
/// Requires `avx512f`.
#[target_feature(enable = "avx512f")]
pub unsafe fn row_sq_norm(row: &[f32]) -> f32 {
    dot(row, row)
}

/// # Safety
/// Requires `avx512f`.
#[target_feature(enable = "avx512f")]
pub unsafe fn dot2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let (p0, p1, q0, q1) = (a0.as_ptr(), a1.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut acc00 = _mm512_setzero_ps();
    let mut acc01 = _mm512_setzero_ps();
    let mut acc10 = _mm512_setzero_ps();
    let mut acc11 = _mm512_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x0 = _mm512_loadu_ps(p0.add(o));
        let x1 = _mm512_loadu_ps(p1.add(o));
        let y0 = _mm512_loadu_ps(q0.add(o));
        let y1 = _mm512_loadu_ps(q1.add(o));
        acc00 = _mm512_add_ps(acc00, _mm512_mul_ps(x0, y0));
        acc01 = _mm512_add_ps(acc01, _mm512_mul_ps(x0, y1));
        acc10 = _mm512_add_ps(acc10, _mm512_mul_ps(x1, y0));
        acc11 = _mm512_add_ps(acc11, _mm512_mul_ps(x1, y1));
    }
    let mut out = [0.0f32; 4];
    let mut lanes = [0.0f32; LANES];
    for (slot, acc) in out.iter_mut().zip([acc00, acc01, acc10, acc11]) {
        _mm512_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = 0.0f32;
        for &x in lanes.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        out[0] += a0[t] * b0[t];
        out[1] += a0[t] * b1[t];
        out[2] += a1[t] * b0[t];
        out[3] += a1[t] * b1[t];
    }
    out
}

/// Widen 16 consecutive f16 values at `p` to one `zmm` of f32 (`vcvtph2ps`
/// is the exact IEEE widening, bitwise-equal to the software
/// [`f16_to_f32`]).
///
/// # Safety
/// Requires `avx512f`; `p` must be readable for 32 bytes.
#[target_feature(enable = "avx512f")]
unsafe fn load_f16x16(p: *const u16) -> __m512 {
    _mm512_cvtph_ps(_mm256_loadu_si256(p as *const __m256i))
}

/// # Safety
/// Requires `avx512f`.
#[target_feature(enable = "avx512f")]
pub unsafe fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm512_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x = load_f16x16(ap.add(o));
        let y = _mm512_loadu_ps(bp.add(o));
        acc = _mm512_add_ps(acc, _mm512_mul_ps(x, y));
    }
    let mut lanes = [0.0f32; LANES];
    _mm512_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut dot = 0.0f32;
    for &x in lanes.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += f16_to_f32(a[t]) * b[t];
    }
    dot
}

/// # Safety
/// Requires `avx512f`.
#[target_feature(enable = "avx512f")]
pub unsafe fn dot2x2_f16(a0: &[u16], a1: &[u16], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let (p0, p1, q0, q1) = (a0.as_ptr(), a1.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut acc00 = _mm512_setzero_ps();
    let mut acc01 = _mm512_setzero_ps();
    let mut acc10 = _mm512_setzero_ps();
    let mut acc11 = _mm512_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x0 = load_f16x16(p0.add(o));
        let x1 = load_f16x16(p1.add(o));
        let y0 = _mm512_loadu_ps(q0.add(o));
        let y1 = _mm512_loadu_ps(q1.add(o));
        acc00 = _mm512_add_ps(acc00, _mm512_mul_ps(x0, y0));
        acc01 = _mm512_add_ps(acc01, _mm512_mul_ps(x0, y1));
        acc10 = _mm512_add_ps(acc10, _mm512_mul_ps(x1, y0));
        acc11 = _mm512_add_ps(acc11, _mm512_mul_ps(x1, y1));
    }
    let mut out = [0.0f32; 4];
    let mut lanes = [0.0f32; LANES];
    for (slot, acc) in out.iter_mut().zip([acc00, acc01, acc10, acc11]) {
        _mm512_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = 0.0f32;
        for &x in lanes.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        let u0 = f16_to_f32(a0[t]);
        let u1 = f16_to_f32(a1[t]);
        out[0] += u0 * b0[t];
        out[1] += u0 * b1[t];
        out[2] += u1 * b0[t];
        out[3] += u1 * b1[t];
    }
    out
}
