//! AVX2 (+F16C) backend: 8-wide `ymm` registers.  The scalar contract's 16
//! accumulator lanes map onto **two** `ymm` accumulators per dot product —
//! `lo` holds lanes 0–7, `hi` lanes 8–15 — updated with an unfused
//! `vmulps` + `vaddps` pair (never `vfmadd`: the contract rounds each
//! product before adding, exactly like the scalar `acc[l] += a[l] * b[l]`).
//! The final reduction stores both registers back to a 16-lane array and
//! sums it serially in lane order, so every result is bit-identical to
//! [`super::scalar`].
//!
//! All functions are `unsafe`: the caller must have verified `avx2` and
//! `f16c` support (see [`super::KernelBackend::is_supported`]) — the
//! dispatcher in [`super`] is the only caller.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use super::scalar::LANES;
use crate::core::compress::f16_to_f32;

/// # Safety
/// Requires `avx2` (checked by the dispatcher before the call).
#[target_feature(enable = "avx2")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc_lo = _mm256_setzero_ps();
    let mut acc_hi = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x_lo = _mm256_loadu_ps(ap.add(o));
        let x_hi = _mm256_loadu_ps(ap.add(o + 8));
        let y_lo = _mm256_loadu_ps(bp.add(o));
        let y_hi = _mm256_loadu_ps(bp.add(o + 8));
        acc_lo = _mm256_add_ps(acc_lo, _mm256_mul_ps(x_lo, y_lo));
        acc_hi = _mm256_add_ps(acc_hi, _mm256_mul_ps(x_hi, y_hi));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc_hi);
    let mut dot = 0.0f32;
    for &x in lanes.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += a[t] * b[t];
    }
    dot
}

/// # Safety
/// Requires `avx2`.
#[target_feature(enable = "avx2")]
pub unsafe fn row_sq_norm(row: &[f32]) -> f32 {
    dot(row, row)
}

/// # Safety
/// Requires `avx2`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let (p0, p1, q0, q1) = (a0.as_ptr(), a1.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut a00l = _mm256_setzero_ps();
    let mut a00h = _mm256_setzero_ps();
    let mut a01l = _mm256_setzero_ps();
    let mut a01h = _mm256_setzero_ps();
    let mut a10l = _mm256_setzero_ps();
    let mut a10h = _mm256_setzero_ps();
    let mut a11l = _mm256_setzero_ps();
    let mut a11h = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x0l = _mm256_loadu_ps(p0.add(o));
        let x0h = _mm256_loadu_ps(p0.add(o + 8));
        let x1l = _mm256_loadu_ps(p1.add(o));
        let x1h = _mm256_loadu_ps(p1.add(o + 8));
        let y0l = _mm256_loadu_ps(q0.add(o));
        let y0h = _mm256_loadu_ps(q0.add(o + 8));
        let y1l = _mm256_loadu_ps(q1.add(o));
        let y1h = _mm256_loadu_ps(q1.add(o + 8));
        a00l = _mm256_add_ps(a00l, _mm256_mul_ps(x0l, y0l));
        a00h = _mm256_add_ps(a00h, _mm256_mul_ps(x0h, y0h));
        a01l = _mm256_add_ps(a01l, _mm256_mul_ps(x0l, y1l));
        a01h = _mm256_add_ps(a01h, _mm256_mul_ps(x0h, y1h));
        a10l = _mm256_add_ps(a10l, _mm256_mul_ps(x1l, y0l));
        a10h = _mm256_add_ps(a10h, _mm256_mul_ps(x1h, y0h));
        a11l = _mm256_add_ps(a11l, _mm256_mul_ps(x1l, y1l));
        a11h = _mm256_add_ps(a11h, _mm256_mul_ps(x1h, y1h));
    }
    let mut out = [0.0f32; 4];
    let mut lanes = [0.0f32; LANES];
    for (slot, (lo, hi)) in out
        .iter_mut()
        .zip([(a00l, a00h), (a01l, a01h), (a10l, a10h), (a11l, a11h)])
    {
        _mm256_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), hi);
        let mut dot = 0.0f32;
        for &x in lanes.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        out[0] += a0[t] * b0[t];
        out[1] += a0[t] * b1[t];
        out[2] += a1[t] * b0[t];
        out[3] += a1[t] * b1[t];
    }
    out
}

/// Widen 8 consecutive f16 values at `p` to one `ymm` of f32.  `vcvtph2ps`
/// performs the exact IEEE widening, so it agrees bitwise with the software
/// [`f16_to_f32`] the scalar backend uses.
///
/// # Safety
/// Requires `f16c`; `p` must be readable for 16 bytes.
#[target_feature(enable = "avx2,f16c")]
unsafe fn load_f16x8(p: *const u16) -> __m256 {
    _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i))
}

/// # Safety
/// Requires `avx2` and `f16c`.
#[target_feature(enable = "avx2,f16c")]
pub unsafe fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc_lo = _mm256_setzero_ps();
    let mut acc_hi = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x_lo = load_f16x8(ap.add(o));
        let x_hi = load_f16x8(ap.add(o + 8));
        let y_lo = _mm256_loadu_ps(bp.add(o));
        let y_hi = _mm256_loadu_ps(bp.add(o + 8));
        acc_lo = _mm256_add_ps(acc_lo, _mm256_mul_ps(x_lo, y_lo));
        acc_hi = _mm256_add_ps(acc_hi, _mm256_mul_ps(x_hi, y_hi));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc_hi);
    let mut dot = 0.0f32;
    for &x in lanes.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += f16_to_f32(a[t]) * b[t];
    }
    dot
}

/// # Safety
/// Requires `avx2` and `f16c`.
#[target_feature(enable = "avx2,f16c")]
pub unsafe fn dot2x2_f16(a0: &[u16], a1: &[u16], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let (p0, p1, q0, q1) = (a0.as_ptr(), a1.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut a00l = _mm256_setzero_ps();
    let mut a00h = _mm256_setzero_ps();
    let mut a01l = _mm256_setzero_ps();
    let mut a01h = _mm256_setzero_ps();
    let mut a10l = _mm256_setzero_ps();
    let mut a10h = _mm256_setzero_ps();
    let mut a11l = _mm256_setzero_ps();
    let mut a11h = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        let x0l = load_f16x8(p0.add(o));
        let x0h = load_f16x8(p0.add(o + 8));
        let x1l = load_f16x8(p1.add(o));
        let x1h = load_f16x8(p1.add(o + 8));
        let y0l = _mm256_loadu_ps(q0.add(o));
        let y0h = _mm256_loadu_ps(q0.add(o + 8));
        let y1l = _mm256_loadu_ps(q1.add(o));
        let y1h = _mm256_loadu_ps(q1.add(o + 8));
        a00l = _mm256_add_ps(a00l, _mm256_mul_ps(x0l, y0l));
        a00h = _mm256_add_ps(a00h, _mm256_mul_ps(x0h, y0h));
        a01l = _mm256_add_ps(a01l, _mm256_mul_ps(x0l, y1l));
        a01h = _mm256_add_ps(a01h, _mm256_mul_ps(x0h, y1h));
        a10l = _mm256_add_ps(a10l, _mm256_mul_ps(x1l, y0l));
        a10h = _mm256_add_ps(a10h, _mm256_mul_ps(x1h, y0h));
        a11l = _mm256_add_ps(a11l, _mm256_mul_ps(x1l, y1l));
        a11h = _mm256_add_ps(a11h, _mm256_mul_ps(x1h, y1h));
    }
    let mut out = [0.0f32; 4];
    let mut lanes = [0.0f32; LANES];
    for (slot, (lo, hi)) in out
        .iter_mut()
        .zip([(a00l, a00h), (a01l, a01h), (a10l, a10h), (a11l, a11h)])
    {
        _mm256_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), hi);
        let mut dot = 0.0f32;
        for &x in lanes.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        let u0 = f16_to_f32(a0[t]);
        let u1 = f16_to_f32(a1[t]);
        out[0] += u0 * b0[t];
        out[1] += u0 * b1[t];
        out[2] += u1 * b0[t];
        out[3] += u1 * b1[t];
    }
    out
}
