//! Explicit SIMD microkernels for the Phase-1 primitives with one-time
//! runtime dispatch.
//!
//! Three backends implement the same five primitives — `dot`, `dot2x2`,
//! `row_sq_norm`, and their f16-residency variants `dot_f16` /
//! `dot2x2_f16`:
//!
//! | backend  | registers | requires (runtime)   | f16 decode       |
//! |----------|-----------|----------------------|------------------|
//! | `scalar` | —         | always available     | software widen   |
//! | `avx2`   | 2 × ymm   | `avx2` + `f16c`      | `vcvtph2ps` xmm  |
//! | `avx512` | 1 × zmm   | `avx512f`            | `vcvtph2ps` ymm  |
//!
//! **Bit-identity contract.**  [`scalar`] defines the arithmetic: 16
//! independent f32 accumulator lanes, an unfused multiply-then-add per lane
//! (each product rounds before the add — which is why the SIMD backends use
//! `mul`+`add` instead of FMA), a serial in-order reduction over lanes
//! 0..16, then a serial scalar tail.  The AVX2 backend splits the 16 lanes
//! across two `ymm` registers (lanes 0–7 / 8–15); AVX-512 holds all 16 in
//! one `zmm`.  Both store the accumulator back to memory and reduce it in
//! lane order, so **every backend returns bit-identical results on every
//! input** — asserted for odd lengths, unaligned slices and denormal-heavy
//! inputs by the property tests below and by
//! `rust/tests/batch_equivalence.rs` across whole plans.
//!
//! **Selection.**  [`active`] resolves once per process (cached in a
//! [`OnceLock`]): the `EMDPAR_KERNEL=scalar|avx2|avx512` environment
//! variable forces a backend (panicking if the host cannot run it — a
//! forced-but-ignored override would silently test the wrong code), else
//! the best detected backend wins (`avx512` > `avx2` > `scalar`).  Hot
//! paths resolve the backend once per operation and call the `*_with`
//! entry points; `PlanParams::kernel` / `EngineBuilder::kernel` override
//! per engine without touching the process-wide default.

use std::sync::OnceLock;

pub mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;

/// A kernel backend identity.  `Scalar` is always available; the SIMD
/// backends are compiled on `x86_64` and gated at runtime by
/// [`KernelBackend::is_supported`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// Portable reference (the bit-identity anchor).
    Scalar,
    /// AVX2 + F16C, 8-wide `ymm` (two registers per 16-lane accumulator).
    Avx2,
    /// AVX-512F, 16-wide `zmm` (one register per accumulator).
    Avx512,
}

impl KernelBackend {
    /// All backends, best first (detection order).
    pub const ALL: [KernelBackend; 3] =
        [KernelBackend::Avx512, KernelBackend::Avx2, KernelBackend::Scalar];

    /// The lowercase name used by `EMDPAR_KERNEL` and the config knob.
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
        }
    }

    /// Parse a backend name (the inverse of [`KernelBackend::name`]).
    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelBackend::Scalar),
            "avx2" => Some(KernelBackend::Avx2),
            "avx512" | "avx512f" => Some(KernelBackend::Avx512),
            _ => None,
        }
    }

    /// Can this host execute the backend?  (Runtime CPUID check; `Scalar`
    /// is always supported, and on non-x86_64 targets it is the only one.)
    pub fn is_supported(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("f16c")
            }
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Every backend this host can execute, best first (always ends with
/// `Scalar`).  The per-backend equivalence tests and the roofline bench
/// iterate this.
pub fn supported_backends() -> Vec<KernelBackend> {
    KernelBackend::ALL.iter().copied().filter(|b| b.is_supported()).collect()
}

/// The best backend the host supports (ignores the env override).
pub fn detected() -> KernelBackend {
    static DETECTED: OnceLock<KernelBackend> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        KernelBackend::ALL
            .iter()
            .copied()
            .find(|b| b.is_supported())
            .unwrap_or(KernelBackend::Scalar)
    })
}

/// The process-wide active backend: `EMDPAR_KERNEL` when set (panics on an
/// unknown or unsupported value — a forced backend must never be silently
/// ignored), the best detected backend otherwise.  Resolved once and
/// cached; per-engine overrides go through `PlanParams::kernel` instead.
pub fn active() -> KernelBackend {
    static ACTIVE: OnceLock<KernelBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("EMDPAR_KERNEL") {
        Ok(raw) if !raw.is_empty() => {
            let kb = KernelBackend::parse(&raw).unwrap_or_else(|| {
                panic!("EMDPAR_KERNEL={raw:?}: expected scalar | avx2 | avx512")
            });
            assert!(
                kb.is_supported(),
                "EMDPAR_KERNEL={} forced, but this host does not support it",
                kb.name()
            );
            kb
        }
        _ => detected(),
    })
}

/// Lane-chunked dot product on the chosen backend (bit-identical across
/// backends; see the module docs for the contract).
#[inline]
pub fn dot_with(kb: KernelBackend, a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(kb.is_supported(), "backend {kb} not supported on this host");
    match kb {
        KernelBackend::Scalar => scalar::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: is_supported() verified the CPU feature (debug-asserted
        // here; release callers resolve backends through active()/config
        // validation, which only hand out supported ones).
        KernelBackend::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        KernelBackend::Avx512 => unsafe { avx512::dot(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dot(a, b),
    }
}

/// 2×2 tiled dot products on the chosen backend:
/// `[a0·b0, a0·b1, a1·b0, a1·b1]`.
#[inline]
pub fn dot2x2_with(
    kb: KernelBackend,
    a0: &[f32],
    a1: &[f32],
    b0: &[f32],
    b1: &[f32],
    n: usize,
) -> [f32; 4] {
    debug_assert!(kb.is_supported(), "backend {kb} not supported on this host");
    match kb {
        KernelBackend::Scalar => scalar::dot2x2(a0, a1, b0, b1, n),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx2 => unsafe { avx2::dot2x2(a0, a1, b0, b1, n) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx512 => unsafe { avx512::dot2x2(a0, a1, b0, b1, n) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dot2x2(a0, a1, b0, b1, n),
    }
}

/// Row squared norm (`dot(row, row)`) on the chosen backend.
#[inline]
pub fn row_sq_norm_with(kb: KernelBackend, row: &[f32]) -> f32 {
    debug_assert!(kb.is_supported(), "backend {kb} not supported on this host");
    match kb {
        KernelBackend::Scalar => scalar::row_sq_norm(row),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx2 => unsafe { avx2::row_sq_norm(row) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx512 => unsafe { avx512::row_sq_norm(row) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::row_sq_norm(row),
    }
}

/// Mixed-precision dot against an f16-encoded row on the chosen backend.
#[inline]
pub fn dot_f16_with(kb: KernelBackend, a: &[u16], b: &[f32]) -> f32 {
    debug_assert!(kb.is_supported(), "backend {kb} not supported on this host");
    match kb {
        KernelBackend::Scalar => scalar::dot_f16(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with (Avx2 support implies f16c).
        KernelBackend::Avx2 => unsafe { avx2::dot_f16(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx512 => unsafe { avx512::dot_f16(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dot_f16(a, b),
    }
}

/// 2×2 tile over two f16-encoded rows on the chosen backend.
#[inline]
pub fn dot2x2_f16_with(
    kb: KernelBackend,
    a0: &[u16],
    a1: &[u16],
    b0: &[f32],
    b1: &[f32],
    n: usize,
) -> [f32; 4] {
    debug_assert!(kb.is_supported(), "backend {kb} not supported on this host");
    match kb {
        KernelBackend::Scalar => scalar::dot2x2_f16(a0, a1, b0, b1, n),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx2 => unsafe { avx2::dot2x2_f16(a0, a1, b0, b1, n) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see dot_with.
        KernelBackend::Avx512 => unsafe { avx512::dot2x2_f16(a0, a1, b0, b1, n) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dot2x2_f16(a0, a1, b0, b1, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::compress::f32_to_f16;
    use crate::util::rng::Rng;

    /// Lengths straddling the 16-lane boundary, plus long tails.
    const SIZES: [usize; 18] = [0, 1, 2, 3, 5, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 129];

    fn fill(rng: &mut Rng, n: usize, denormal: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let x = rng.normal() as f32;
                // denormal-heavy: scale most values below f32::MIN_POSITIVE
                // so the SIMD lanes chew on subnormals (no FTZ/DAZ is set,
                // so hardware and scalar arithmetic must still agree)
                if denormal {
                    x * 1.0e-41
                } else {
                    x
                }
            })
            .collect()
    }

    #[test]
    fn all_backends_dot_bit_equal_to_scalar() {
        let mut rng = Rng::new(11);
        for &n in SIZES.iter() {
            for denormal in [false, true] {
                // over-allocate so unaligned sub-slices stay in bounds
                let a = fill(&mut rng, n + 3, denormal);
                let b = fill(&mut rng, n + 3, denormal);
                for off in [0usize, 1, 3] {
                    let (aa, bb) = (&a[off..off + n], &b[off..off + n]);
                    let want = scalar::dot(aa, bb);
                    for kb in supported_backends() {
                        let got = dot_with(kb, aa, bb);
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{kb} dot n={n} off={off} denormal={denormal}: {got} != {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_backends_dot2x2_bit_equal_to_scalar() {
        let mut rng = Rng::new(12);
        for &n in SIZES.iter() {
            for denormal in [false, true] {
                let rows: Vec<Vec<f32>> =
                    (0..4).map(|_| fill(&mut rng, n + 3, denormal)).collect();
                for off in [0usize, 1, 3] {
                    let s: Vec<&[f32]> = rows.iter().map(|r| &r[off..off + n]).collect();
                    let want = scalar::dot2x2(s[0], s[1], s[2], s[3], n);
                    for kb in supported_backends() {
                        let got = dot2x2_with(kb, s[0], s[1], s[2], s[3], n);
                        for (g, w) in got.iter().zip(&want) {
                            assert_eq!(
                                g.to_bits(),
                                w.to_bits(),
                                "{kb} dot2x2 n={n} off={off} denormal={denormal}"
                            );
                        }
                        // and each pair must equal the plain dot of that pair
                        assert_eq!(got[0].to_bits(), dot_with(kb, s[0], s[2]).to_bits());
                        assert_eq!(got[1].to_bits(), dot_with(kb, s[0], s[3]).to_bits());
                        assert_eq!(got[2].to_bits(), dot_with(kb, s[1], s[2]).to_bits());
                        assert_eq!(got[3].to_bits(), dot_with(kb, s[1], s[3]).to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn all_backends_row_sq_norm_bit_equal_to_scalar() {
        let mut rng = Rng::new(13);
        for &n in SIZES.iter() {
            for denormal in [false, true] {
                let row = fill(&mut rng, n + 1, denormal);
                for off in [0usize, 1] {
                    let r = &row[off..off + n];
                    let want = scalar::row_sq_norm(r);
                    for kb in supported_backends() {
                        let got = row_sq_norm_with(kb, r);
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{kb} row_sq_norm n={n} off={off} denormal={denormal}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_backends_f16_variants_bit_equal_to_scalar() {
        let mut rng = Rng::new(14);
        for &n in SIZES.iter() {
            let enc: Vec<Vec<u16>> = (0..2)
                .map(|_| {
                    (0..n + 3).map(|_| f32_to_f16(rng.normal() as f32)).collect()
                })
                .collect();
            let cols: Vec<Vec<f32>> = (0..2).map(|_| fill(&mut rng, n + 3, false)).collect();
            for off in [0usize, 1, 3] {
                let a0 = &enc[0][off..off + n];
                let a1 = &enc[1][off..off + n];
                let b0 = &cols[0][off..off + n];
                let b1 = &cols[1][off..off + n];
                let want_dot = scalar::dot_f16(a0, b0);
                let want_tile = scalar::dot2x2_f16(a0, a1, b0, b1, n);
                for kb in supported_backends() {
                    assert_eq!(
                        dot_f16_with(kb, a0, b0).to_bits(),
                        want_dot.to_bits(),
                        "{kb} dot_f16 n={n} off={off}"
                    );
                    let got = dot2x2_f16_with(kb, a0, a1, b0, b1, n);
                    for (g, w) in got.iter().zip(&want_tile) {
                        assert_eq!(g.to_bits(), w.to_bits(), "{kb} dot2x2_f16 n={n} off={off}");
                    }
                }
            }
        }
    }

    #[test]
    fn detection_is_sane() {
        assert!(KernelBackend::Scalar.is_supported());
        let det = detected();
        assert!(det.is_supported());
        let sup = supported_backends();
        assert_eq!(sup.last(), Some(&KernelBackend::Scalar));
        assert!(sup.contains(&det));
        // active() resolves without panicking unless EMDPAR_KERNEL is bad,
        // in which case the whole suite *should* abort
        assert!(active().is_supported());
    }

    #[test]
    fn backend_names_roundtrip() {
        for kb in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(kb.name()), Some(kb));
        }
        assert_eq!(KernelBackend::parse("AVX2"), Some(KernelBackend::Avx2));
        assert_eq!(KernelBackend::parse("neon"), None);
    }
}
