//! Scalar reference backend: the bit-identity anchor every SIMD variant is
//! measured against.
//!
//! The arithmetic contract (see the module docs in [`super`]) is defined by
//! this file: 16 independent f32 accumulator lanes, an *unfused*
//! multiply-then-add per lane (each product is rounded before the add — the
//! SIMD backends must use `mul` + `add`, never `fmadd`), an in-order serial
//! reduction over lanes 0..16, then a serial scalar tail.  These loops are
//! written so LLVM can autovectorize them on any target; the explicit
//! backends exist to guarantee the width regardless of what the
//! autovectorizer decides.

use crate::core::compress::f16_to_f32;

pub(super) const LANES: usize = 16;

/// Lane-chunked dot product — the reference [`crate::lc::plan::dot_f32`]
/// delegates here.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let ac = &a[c * LANES..c * LANES + LANES];
        let bc = &b[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            acc[l] += ac[l] * bc[l];
        }
    }
    let mut dot = 0.0f32;
    for &x in acc.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += a[t] * b[t];
    }
    dot
}

/// Lane-chunked row squared norm: exactly `dot(row, row)`.  This is the
/// arithmetic [`crate::core::Embeddings::row_sq_norms`] uses, so norm tables
/// built anywhere in the crate are bit-equal to any backend's output.
#[inline]
pub fn row_sq_norm(row: &[f32]) -> f32 {
    dot(row, row)
}

/// 2×2 register-tiled dot products: `out = [a0·b0, a0·b1, a1·b0, a1·b1]`.
///
/// Each operand is loaded once per tile instead of once per dot product
/// (0.5 loads per multiply-add versus [`dot`]'s 2), and the four lane
/// reductions are independent, so the CPU overlaps them.  Per pair, the
/// arithmetic — lane-chunked partial sums, reduction order, scalar tail —
/// is *exactly* [`dot`]'s, which is what makes the batched kernel
/// bit-identical to the single-query kernel.
#[inline]
pub fn dot2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let mut acc00 = [0.0f32; LANES];
    let mut acc01 = [0.0f32; LANES];
    let mut acc10 = [0.0f32; LANES];
    let mut acc11 = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let x0 = &a0[o..o + LANES];
        let x1 = &a1[o..o + LANES];
        let y0 = &b0[o..o + LANES];
        let y1 = &b1[o..o + LANES];
        for l in 0..LANES {
            acc00[l] += x0[l] * y0[l];
            acc01[l] += x0[l] * y1[l];
            acc10[l] += x1[l] * y0[l];
            acc11[l] += x1[l] * y1[l];
        }
    }
    let mut out = [0.0f32; 4];
    for (slot, acc) in out.iter_mut().zip([&acc00, &acc01, &acc10, &acc11]) {
        let mut dot = 0.0f32;
        for &x in acc.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        out[0] += a0[t] * b0[t];
        out[1] += a0[t] * b1[t];
        out[2] += a1[t] * b0[t];
        out[3] += a1[t] * b1[t];
    }
    out
}

/// Mixed-precision dot product against an f16-encoded row (the compressed
/// stage-1 tier): each u16 is widened to f32 (an exact conversion — every
/// f16 value is representable) and then fed through the same lane-chunked
/// accumulation as [`dot`].  Bit-identical to decoding the whole row first
/// and calling `dot`.
#[inline]
pub fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let ac = &a[c * LANES..c * LANES + LANES];
        let bc = &b[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            acc[l] += f16_to_f32(ac[l]) * bc[l];
        }
    }
    let mut dot = 0.0f32;
    for &x in acc.iter() {
        dot += x;
    }
    for t in chunks * LANES..n {
        dot += f16_to_f32(a[t]) * b[t];
    }
    dot
}

/// 2×2 tile over two f16-encoded vocabulary rows and two f32 query columns;
/// per pair the arithmetic is exactly [`dot_f16`]'s.
#[inline]
pub fn dot2x2_f16(a0: &[u16], a1: &[u16], b0: &[f32], b1: &[f32], n: usize) -> [f32; 4] {
    let chunks = n / LANES;
    let mut acc00 = [0.0f32; LANES];
    let mut acc01 = [0.0f32; LANES];
    let mut acc10 = [0.0f32; LANES];
    let mut acc11 = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let x0 = &a0[o..o + LANES];
        let x1 = &a1[o..o + LANES];
        let y0 = &b0[o..o + LANES];
        let y1 = &b1[o..o + LANES];
        for l in 0..LANES {
            let u0 = f16_to_f32(x0[l]);
            let u1 = f16_to_f32(x1[l]);
            acc00[l] += u0 * y0[l];
            acc01[l] += u0 * y1[l];
            acc10[l] += u1 * y0[l];
            acc11[l] += u1 * y1[l];
        }
    }
    let mut out = [0.0f32; 4];
    for (slot, acc) in out.iter_mut().zip([&acc00, &acc01, &acc10, &acc11]) {
        let mut dot = 0.0f32;
        for &x in acc.iter() {
            dot += x;
        }
        *slot = dot;
    }
    for t in chunks * LANES..n {
        let u0 = f16_to_f32(a0[t]);
        let u1 = f16_to_f32(a1[t]);
        out[0] += u0 * b0[t];
        out[1] += u0 * b1[t];
        out[2] += u1 * b0[t];
        out[3] += u1 * b1[t];
    }
    out
}
