//! Batched multi-query Phase 1 (paper Fig. 6 / Table 3): plan a block of
//! `B` queries against the vocabulary in **one** tiled pass, so every
//! vocabulary row is streamed from memory once per *block* instead of once
//! per query — the matrix-matrix reformulation that gives the paper its
//! headline throughput.
//!
//! Structure of the kernel: the `B` queries' support columns are
//! concatenated into one `(Σh, m)` coordinate block; the `V×m · m×Σh`
//! product is then walked in 2×2 register tiles
//! ([`crate::lc::kernels::dot2x2_with`]) that load each vocabulary row and
//! each query column once per tile instead of once per dot product —
//! halving load traffic per FMA versus the per-pair dot loop — with the
//! per-(row, query) top-k selection fused directly behind each tile.  The
//! dot-product microkernels live in [`crate::lc::kernels`] and dispatch to
//! the best SIMD backend the host supports (or the one
//! [`PlanParams::kernel`] forces); all backends are bit-identical.
//!
//! The planner can also score against an f16 compressed copy of the
//! vocabulary ([`BatchPlanner::new_compressed`]): rows stream at half the
//! bytes, each u16 is widened to f32 exactly, and the same lane-chunked
//! arithmetic runs on the widened values.  Compressed plans are a stage-1
//! shortcut — the query planner reranks survivors at exact f32.
//!
//! Bit-identity contract: every scalar this kernel produces is computed
//! with the *same* lane-chunked accumulation, the same reduction order, the
//! same Gram-expansion snap ([`l2_snap`]) and the same normalization
//! arithmetic as the single-query [`plan_query`] path, so batched plans are
//! bit-equal to single-query plans for every `k`, thread count and block
//! size (asserted by `rust/tests/batch_equivalence.rs`).
//!
//! Allocation discipline: all intermediate buffers live in a caller-owned
//! [`PlanScratch`] arena and plan output buffers are recycled through it,
//! so a steady-state all-pairs sweep performs zero per-query heap
//! allocations.

use crate::approx::act::row_topk;
use crate::core::{Embeddings, F16Tier, Histogram, Metric};
use crate::lc::kernels::{self, KernelBackend};
use crate::util::threadpool::{parallel_for, SyncSlice};

use super::plan::{l2_snap, snapped_distance, PlanParams, QueryPlan};

/// Default number of queries planned per Phase-1 block (`B`).  Large enough
/// to amortize vocabulary streaming across the block, small enough that the
/// `(Σh, m)` query block and the per-row distance tile stay cache-resident.
pub const DEFAULT_BATCH_BLOCK: usize = 8;

/// Reusable Phase-1 arena: recycled plan output buffers plus every
/// intermediate the block kernel needs.  One scratch per worker; feeding
/// consecutive blocks through the same scratch reuses all capacity.
#[derive(Debug, Default)]
pub struct PlanScratch {
    /// Recycled [`QueryPlan`] output buffers (capacity preserved).
    free: Vec<QueryPlan>,
    /// Recycled full-D buffers (only used when `keep_d` plans cycle).
    free_d: Vec<Vec<f32>>,
    /// Concatenated normalized query weights (Σh).
    qw: Vec<f32>,
    /// Concatenated support indices (Σh; ascending within each query).
    support: Vec<u32>,
    /// Concatenated query squared norms (Σh), gathered from the vocab table.
    qnorms: Vec<f32>,
    /// Concatenated gathered query coordinates (Σh, m), row-major.
    coords: Vec<f32>,
    /// Per-query segment descriptors for the current block.
    segs: Vec<QuerySeg>,
    /// Two-row distance tile (2 × Σh) for the serial kernel path.
    tile: Vec<f32>,
    /// Top-k selection buffers for the serial kernel path.
    vals: Vec<f32>,
    idxs: Vec<u32>,
}

impl PlanScratch {
    pub fn new() -> PlanScratch {
        PlanScratch::default()
    }

    /// Return a block of plans to the arena; their buffers (including any
    /// full-D matrices) are reused by the next `plan_*` call.
    pub fn recycle(&mut self, plans: &mut Vec<QueryPlan>) {
        for mut p in plans.drain(..) {
            if let Some(d) = p.d.take() {
                self.free_d.push(d);
            }
            self.free.push(p);
        }
    }
}

/// One query's column range inside the concatenated block.
#[derive(Debug, Clone, Copy)]
struct QuerySeg {
    /// First column of this query in the concatenated arrays.
    off: usize,
    /// Support size h.
    h: usize,
    /// Clamped plan width.
    k: usize,
}

/// Which representation of the vocabulary the planner streams: the exact
/// f32 table or its f16 compressed tier.
#[derive(Clone, Copy)]
enum VocabRef<'a> {
    F32(&'a Embeddings),
    F16(&'a F16Tier),
}

impl VocabRef<'_> {
    fn num_vectors(&self) -> usize {
        match self {
            VocabRef::F32(e) => e.num_vectors(),
            VocabRef::F16(t) => t.num_vectors(),
        }
    }

    fn dim(&self) -> usize {
        match self {
            VocabRef::F32(e) => e.dim(),
            VocabRef::F16(t) => t.dim(),
        }
    }
}

/// The batched Phase-1 planner: borrows the vocabulary (exact f32, or its
/// f16 compressed tier) and the matching precomputed row squared-norm table
/// (see [`Embeddings::row_sq_norms`] / [`F16Tier::row_sq_norms`]) and plans
/// one or many queries per call.  Construction is free —
/// [`crate::lc::LcEngine`] materializes one per operation on top of its
/// cached norm table.
pub struct BatchPlanner<'a> {
    vocab: VocabRef<'a>,
    vn: &'a [f32],
}

impl<'a> BatchPlanner<'a> {
    pub fn new(vocab: &'a Embeddings, vn: &'a [f32]) -> BatchPlanner<'a> {
        assert_eq!(vn.len(), vocab.num_vectors(), "vocab norm table size mismatch");
        BatchPlanner { vocab: VocabRef::F32(vocab), vn }
    }

    /// Plan against the f16 compressed tier.  `vn` must be the **tier's**
    /// norm table ([`F16Tier::row_sq_norms`]), not the f32 one, so the Gram
    /// expansion stays internally consistent with the decoded coordinates.
    pub fn new_compressed(tier: &'a F16Tier, vn: &'a [f32]) -> BatchPlanner<'a> {
        assert_eq!(vn.len(), tier.num_vectors(), "tier norm table size mismatch");
        BatchPlanner { vocab: VocabRef::F16(tier), vn }
    }

    /// Plan a block of query histograms (allocating convenience wrapper
    /// around [`BatchPlanner::plan_rows_into`]).
    pub fn plan_block(
        &self,
        queries: &[Histogram],
        params: PlanParams,
        scratch: &mut PlanScratch,
    ) -> Vec<QueryPlan> {
        let mut out = Vec::with_capacity(queries.len());
        self.plan_block_into(queries, params, scratch, &mut out);
        out
    }

    /// Plan a block of query histograms into a reusable output vector.
    pub fn plan_block_into(
        &self,
        queries: &[Histogram],
        params: PlanParams,
        scratch: &mut PlanScratch,
        out: &mut Vec<QueryPlan>,
    ) {
        let rows: Vec<(&[u32], &[f32])> =
            queries.iter().map(|q| (q.indices(), q.weights())).collect();
        self.plan_rows_into(&rows, params, scratch, out);
    }

    /// Plan a block of raw `(indices, weights)` query rows — the zero-copy
    /// entry point the all-pairs sweep feeds CSR rows through.  Weights are
    /// L1-normalized inside the kernel with the same arithmetic as
    /// [`Histogram::normalize`], so results match
    /// `plan_query(vocab, vn, &histogram, params)` bit-for-bit.
    ///
    /// `out` is cleared (previous plans are recycled into `scratch`) and
    /// refilled with one plan per input row, in order.
    pub fn plan_rows_into(
        &self,
        rows: &[(&[u32], &[f32])],
        params: PlanParams,
        scratch: &mut PlanScratch,
        out: &mut Vec<QueryPlan>,
    ) {
        let vocab = self.vocab;
        let vn = self.vn;
        let v = vocab.num_vectors();
        let m = vocab.dim();

        scratch.recycle(out);
        if rows.is_empty() {
            return;
        }

        let PlanScratch { free, free_d, qw, support, qnorms, coords, segs, tile, vals, idxs } =
            scratch;

        // ---- prepare: one concatenated, normalized query block ----
        qw.clear();
        support.clear();
        qnorms.clear();
        coords.clear();
        segs.clear();
        for &(idx, w) in rows {
            let h = idx.len();
            assert!(h > 0, "empty query histogram");
            // same normalization arithmetic as Histogram::normalize, so the
            // batched plan is bit-identical to plan_query(query.normalized())
            let total: f64 = w.iter().map(|&x| x as f64).sum();
            let inv = if total > 0.0 { (1.0 / total) as f32 } else { 1.0 };
            let off = support.len();
            for (&i, &x) in idx.iter().zip(w) {
                support.push(i);
                qw.push(x * inv);
                qnorms.push(vn[i as usize]);
                // query columns always decode to f32 (exact for f16, so the
                // gathered block is identical to decoding the whole tier)
                match vocab {
                    VocabRef::F32(e) => coords.extend_from_slice(e.row(i as usize)),
                    VocabRef::F16(t) => t.decode_row_into(i as usize, coords),
                }
            }
            segs.push(QuerySeg { off, h, k: params.k.clamp(1, h) });
        }
        let total_h = support.len();

        // ---- take recycled output buffers ----
        for seg in segs.iter() {
            let mut p = free.pop().unwrap_or_default();
            p.k = seg.k;
            p.h = seg.h;
            p.qw.clear();
            p.qw.extend_from_slice(&qw[seg.off..seg.off + seg.h]);
            // every element is overwritten by the kernel, so plain resize
            // (which keeps capacity) is enough
            p.z.resize(v * seg.k, 0.0);
            p.s.resize(v * seg.k, 0);
            p.w.resize(v * seg.k, 0.0);
            p.d = if params.keep_d {
                let mut dbuf = free_d.pop().unwrap_or_default();
                dbuf.resize(v * seg.h, 0.0);
                Some(dbuf)
            } else {
                None
            };
            out.push(p);
        }

        // ---- disjoint-write views over the plan buffers ----
        let mut zs: Vec<SyncSlice<f32>> = Vec::with_capacity(out.len());
        let mut ss: Vec<SyncSlice<u32>> = Vec::with_capacity(out.len());
        let mut ws: Vec<SyncSlice<f32>> = Vec::with_capacity(out.len());
        let mut ds: Vec<Option<SyncSlice<f32>>> = Vec::with_capacity(out.len());
        for p in out.iter_mut() {
            zs.push(SyncSlice::new(&mut p.z));
            ss.push(SyncSlice::new(&mut p.s));
            ws.push(SyncSlice::new(&mut p.w));
            ds.push(p.d.as_mut().map(|d| SyncSlice::new(d)));
        }

        let ctx = KernelCtx {
            vocab,
            vn,
            kb: params.kernel.unwrap_or_else(kernels::active),
            metric: params.metric,
            m,
            total_h,
            support: &support[..],
            qw: &qw[..],
            qnorms: &qnorms[..],
            coords: &coords[..],
            segs: &segs[..],
            z: &zs,
            s: &ss,
            w: &ws,
            d: &ds,
        };

        if params.threads <= 1 {
            // serial: run on the scratch buffers — zero allocations
            tile.resize(2 * total_h, 0.0);
            ctx.run(0, v, tile, vals, idxs);
        } else {
            parallel_for(v, params.threads, |r0, r1| {
                let mut tile = vec![0.0f32; 2 * total_h];
                let mut vals: Vec<f32> = Vec::new();
                let mut idxs: Vec<u32> = Vec::new();
                ctx.run(r0, r1, &mut tile, &mut vals, &mut idxs);
            });
        }
    }
}

/// Everything the block kernel reads, plus the disjoint-write output views.
struct KernelCtx<'v, 'o> {
    vocab: VocabRef<'v>,
    vn: &'v [f32],
    kb: KernelBackend,
    metric: Metric,
    m: usize,
    total_h: usize,
    support: &'v [u32],
    qw: &'v [f32],
    qnorms: &'v [f32],
    coords: &'v [f32],
    segs: &'v [QuerySeg],
    z: &'v [SyncSlice<'o, f32>],
    s: &'v [SyncSlice<'o, u32>],
    w: &'v [SyncSlice<'o, f32>],
    d: &'v [Option<SyncSlice<'o, f32>>],
}

impl KernelCtx<'_, '_> {
    /// Process vocabulary rows `[r0, r1)` with caller-owned buffers.
    /// Row values are independent of tiling boundaries (each (row, column)
    /// distance is computed by the same arithmetic wherever it lands), so
    /// chunk shapes chosen by `parallel_for` never change results.
    fn run(&self, r0: usize, r1: usize, tile: &mut [f32], vals: &mut Vec<f32>, idxs: &mut Vec<u32>) {
        match self.metric {
            Metric::L2 => self.run_l2(r0, r1, tile, vals, idxs),
            _ => self.run_generic(r0, r1, tile, vals, idxs),
        }
    }

    /// L2 fast path: Gram expansion over 2×2 register tiles.
    fn run_l2(
        &self,
        r0: usize,
        r1: usize,
        tile: &mut [f32],
        vals: &mut Vec<f32>,
        idxs: &mut Vec<u32>,
    ) {
        let th = self.total_h;
        let m = self.m;
        let mut i0 = r0;
        while i0 < r1 {
            if i0 + 1 < r1 {
                let (vn0, vn1) = (self.vn[i0], self.vn[i0 + 1]);
                let (t0, rest) = tile.split_at_mut(th);
                let t1 = &mut rest[..th];
                let mut c = 0;
                while c + 1 < th {
                    let q0 = &self.coords[c * m..(c + 1) * m];
                    let q1 = &self.coords[(c + 1) * m..(c + 2) * m];
                    let dots = self.dots2x2(i0, i0 + 1, q0, q1);
                    t0[c] = l2_snap(vn0, dots[0], self.qnorms[c]);
                    t0[c + 1] = l2_snap(vn0, dots[1], self.qnorms[c + 1]);
                    t1[c] = l2_snap(vn1, dots[2], self.qnorms[c]);
                    t1[c + 1] = l2_snap(vn1, dots[3], self.qnorms[c + 1]);
                    c += 2;
                }
                if c < th {
                    let qc = &self.coords[c * m..(c + 1) * m];
                    t0[c] = l2_snap(vn0, self.dot1(i0, qc), self.qnorms[c]);
                    t1[c] = l2_snap(vn1, self.dot1(i0 + 1, qc), self.qnorms[c]);
                }
                self.snap_own_coordinate(i0, t0);
                self.snap_own_coordinate(i0 + 1, t1);
                self.select(i0, &tile[..th], vals, idxs);
                self.select(i0 + 1, &tile[th..2 * th], vals, idxs);
                i0 += 2;
            } else {
                let vni = self.vn[i0];
                for c in 0..th {
                    let qc = &self.coords[c * m..(c + 1) * m];
                    tile[c] = l2_snap(vni, self.dot1(i0, qc), self.qnorms[c]);
                }
                self.snap_own_coordinate(i0, &mut tile[..th]);
                self.select(i0, &tile[..th], vals, idxs);
                i0 += 1;
            }
        }
    }

    /// One 2×2 tile of vocabulary rows `i0`/`i1` against query columns
    /// `q0`/`q1`, dispatched to the active backend (and to the f16 variant
    /// when planning against the compressed tier).
    #[inline]
    fn dots2x2(&self, i0: usize, i1: usize, q0: &[f32], q1: &[f32]) -> [f32; 4] {
        match self.vocab {
            VocabRef::F32(e) => {
                kernels::dot2x2_with(self.kb, e.row(i0), e.row(i1), q0, q1, self.m)
            }
            VocabRef::F16(t) => {
                kernels::dot2x2_f16_with(self.kb, t.row(i0), t.row(i1), q0, q1, self.m)
            }
        }
    }

    /// Single dot product of vocabulary row `i` against query column `qc`.
    #[inline]
    fn dot1(&self, i: usize, qc: &[f32]) -> f32 {
        match self.vocab {
            VocabRef::F32(e) => kernels::dot_with(self.kb, e.row(i), qc),
            VocabRef::F16(t) => kernels::dot_f16_with(self.kb, t.row(i), qc),
        }
    }

    /// Any-metric fallback: per-pair snapped distances, same loop the
    /// single-query kernel runs (no Gram expansion to tile).
    fn run_generic(
        &self,
        r0: usize,
        r1: usize,
        tile: &mut [f32],
        vals: &mut Vec<f32>,
        idxs: &mut Vec<u32>,
    ) {
        let th = self.total_h;
        let m = self.m;
        // non-L2 metrics have no Gram expansion, so a compressed vocabulary
        // is decoded row-by-row here (the config layer restricts the f16
        // tier to L2, making this a compile-completeness path in practice)
        let mut decoded: Vec<f32> = Vec::new();
        for i in r0..r1 {
            let vi: &[f32] = match self.vocab {
                VocabRef::F32(e) => e.row(i),
                VocabRef::F16(t) => {
                    decoded.clear();
                    t.decode_row_into(i, &mut decoded);
                    &decoded
                }
            };
            for c in 0..th {
                tile[c] = if self.support[c] as usize == i {
                    0.0
                } else {
                    snapped_distance(self.metric, vi, &self.coords[c * m..(c + 1) * m])
                };
            }
            self.select(i, &tile[..th], vals, idxs);
        }
    }

    /// The query bin that *is* vocabulary entry `i` must be exactly 0
    /// regardless of rounding (support indices are ascending per query).
    fn snap_own_coordinate(&self, i: usize, row: &mut [f32]) {
        for seg in self.segs {
            if let Ok(pos) =
                self.support[seg.off..seg.off + seg.h].binary_search(&(i as u32))
            {
                row[seg.off + pos] = 0.0;
            }
        }
    }

    /// Fused per-tile top-k: select and write z/s/w (and optionally D) for
    /// vocabulary row `i` across every query in the block.
    fn select(&self, i: usize, row: &[f32], vals: &mut Vec<f32>, idxs: &mut Vec<u32>) {
        for (q, seg) in self.segs.iter().enumerate() {
            let seg_row = &row[seg.off..seg.off + seg.h];
            row_topk(seg_row, seg.k, vals, idxs);
            // SAFETY: vocab row i is owned by exactly one worker chunk, and
            // each plan's row-i slices are disjoint from every other row's.
            unsafe {
                let zrow = self.z[q].slice_mut(i * seg.k, (i + 1) * seg.k);
                let srow = self.s[q].slice_mut(i * seg.k, (i + 1) * seg.k);
                let wrow = self.w[q].slice_mut(i * seg.k, (i + 1) * seg.k);
                for l in 0..seg.k {
                    zrow[l] = vals[l];
                    srow[l] = idxs[l];
                    wrow[l] = self.qw[seg.off + idxs[l] as usize];
                }
                if let Some(dview) = &self.d[q] {
                    dview.slice_mut(i * seg.h, (i + 1) * seg.h).copy_from_slice(seg_row);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lc::plan::{dot_f32, plan_query};
    use crate::util::rng::Rng;

    fn setup(seed: u64, v: usize, m: usize, hs: &[usize]) -> (Embeddings, Vec<Histogram>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let vocab = Embeddings::new(data, v, m);
        let queries = hs
            .iter()
            .map(|&h| {
                let idx = rng.sample_indices(v, h);
                Histogram::from_pairs(
                    idx.into_iter()
                        .map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32))
                        .collect(),
                )
            })
            .collect();
        (vocab, queries)
    }

    fn assert_plans_equal(a: &QueryPlan, b: &QueryPlan, tag: &str) {
        assert_eq!(a.k, b.k, "{tag}: k");
        assert_eq!(a.h, b.h, "{tag}: h");
        assert_eq!(a.qw, b.qw, "{tag}: qw");
        assert_eq!(a.z, b.z, "{tag}: z");
        assert_eq!(a.s, b.s, "{tag}: s");
        assert_eq!(a.w, b.w, "{tag}: w");
        assert_eq!(a.d, b.d, "{tag}: d");
    }

    #[test]
    fn dot2x2_matches_dot_f32_bitwise() {
        let mut rng = Rng::new(7);
        // cover tail lengths around the 16-lane boundary
        for n in [1usize, 5, 15, 16, 17, 31, 32, 33, 64, 100] {
            let mk = |rng: &mut Rng| -> Vec<f32> {
                (0..n).map(|_| rng.normal() as f32).collect()
            };
            let (a0, a1, b0, b1) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let t = kernels::scalar::dot2x2(&a0, &a1, &b0, &b1, n);
            assert_eq!(t[0], dot_f32(&a0, &b0), "n={n}");
            assert_eq!(t[1], dot_f32(&a0, &b1), "n={n}");
            assert_eq!(t[2], dot_f32(&a1, &b0), "n={n}");
            assert_eq!(t[3], dot_f32(&a1, &b1), "n={n}");
        }
    }

    #[test]
    fn block_plans_match_single_query_plans_bitwise() {
        // odd v (row tail), ragged h (column tails + ragged segments)
        let (vocab, queries) = setup(1, 45, 7, &[9, 4, 12, 1, 8]);
        let vn = vocab.row_sq_norms();
        let planner = BatchPlanner::new(&vocab, &vn);
        for k in [1usize, 2, 4, 8] {
            for keep_d in [false, true] {
                for threads in [1usize, 4] {
                    let params =
                        PlanParams { k, metric: Metric::L2, keep_d, threads, kernel: None };
                    let mut scratch = PlanScratch::new();
                    let plans = planner.plan_block(&queries, params, &mut scratch);
                    assert_eq!(plans.len(), queries.len());
                    for (q, plan) in queries.iter().zip(&plans) {
                        let single = plan_query(&vocab, &vn, q, params);
                        assert_plans_equal(plan, &single, &format!("k={k} keep_d={keep_d} threads={threads}"));
                    }
                }
            }
        }
    }

    #[test]
    fn non_l2_block_plans_match_single_query() {
        let (vocab, queries) = setup(2, 30, 5, &[6, 3, 10]);
        let vn = vocab.row_sq_norms();
        let planner = BatchPlanner::new(&vocab, &vn);
        for metric in [Metric::L1, Metric::Cosine, Metric::SqL2] {
            let params = PlanParams { k: 2, metric, keep_d: true, threads: 2, kernel: None };
            let mut scratch = PlanScratch::new();
            let plans = planner.plan_block(&queries, params, &mut scratch);
            for (q, plan) in queries.iter().zip(&plans) {
                let single = plan_query(&vocab, &vn, q, params);
                assert_plans_equal(plan, &single, &format!("{metric:?}"));
            }
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // consecutive blocks through ONE scratch give identical results to
        // fresh-scratch planning (buffers fully overwritten, no leakage)
        let (vocab, queries) = setup(3, 40, 6, &[8, 5, 11, 2]);
        let vn = vocab.row_sq_norms();
        let planner = BatchPlanner::new(&vocab, &vn);
        let params =
            PlanParams { k: 3, metric: Metric::L2, keep_d: true, threads: 1, kernel: None };

        let mut fresh = PlanScratch::new();
        let want_a = planner.plan_block(&queries[..2], params, &mut fresh);
        let mut fresh_b = PlanScratch::new();
        let want_b = planner.plan_block(&queries[2..], params, &mut fresh_b);

        let mut reused = PlanScratch::new();
        // warm the arena with a differently-shaped block, then recycle
        let mut warm = planner.plan_block(
            &queries[1..],
            PlanParams { k: 8, metric: Metric::L2, keep_d: false, threads: 1, kernel: None },
            &mut reused,
        );
        reused.recycle(&mut warm);
        let got_a = planner.plan_block(&queries[..2], params, &mut reused);
        for (g, w) in got_a.iter().zip(&want_a) {
            assert_plans_equal(g, w, "first reused batch");
        }
        let mut got_a = got_a;
        reused.recycle(&mut got_a);
        let got_b = planner.plan_block(&queries[2..], params, &mut reused);
        for (g, w) in got_b.iter().zip(&want_b) {
            assert_plans_equal(g, w, "second reused batch");
        }
    }

    #[test]
    fn single_query_block_is_supported() {
        let (vocab, queries) = setup(4, 25, 4, &[7]);
        let vn = vocab.row_sq_norms();
        let planner = BatchPlanner::new(&vocab, &vn);
        let params =
            PlanParams { k: 2, metric: Metric::L2, keep_d: false, threads: 1, kernel: None };
        let mut scratch = PlanScratch::new();
        let plans = planner.plan_block(&queries, params, &mut scratch);
        assert_plans_equal(&plans[0], &plan_query(&vocab, &vn, &queries[0], params), "B=1");
    }

    #[test]
    fn compressed_plans_match_decoded_vocab_plans_bitwise() {
        // planning against the f16 tier must equal planning against an f32
        // table holding the decoded tier values — the mixed-precision kernel
        // widens exactly, so the two paths are the same arithmetic
        let (vocab, queries) = setup(6, 33, 7, &[6, 9, 3]);
        let tier = vocab.compressed_tier();
        let tn = tier.row_sq_norms();
        let mut data = Vec::new();
        for i in 0..tier.num_vectors() {
            tier.decode_row_into(i, &mut data);
        }
        let decoded = Embeddings::new(data, tier.num_vectors(), tier.dim());
        let dn = decoded.row_sq_norms();
        assert_eq!(tn, dn, "tier norm table must match decoded norms");

        let params =
            PlanParams { k: 2, metric: Metric::L2, keep_d: true, threads: 2, kernel: None };
        let mut sc_a = PlanScratch::new();
        let compressed =
            BatchPlanner::new_compressed(&tier, &tn).plan_block(&queries, params, &mut sc_a);
        let mut sc_b = PlanScratch::new();
        let exact = BatchPlanner::new(&decoded, &dn).plan_block(&queries, params, &mut sc_b);
        for (c, e) in compressed.iter().zip(&exact) {
            assert_plans_equal(c, e, "compressed vs decoded");
        }
    }

    #[test]
    fn empty_block_yields_no_plans() {
        let (vocab, _) = setup(5, 10, 3, &[]);
        let vn = vocab.row_sq_norms();
        let planner = BatchPlanner::new(&vocab, &vn);
        let mut scratch = PlanScratch::new();
        let mut out = vec![QueryPlan::default()];
        planner.plan_rows_into(
            &[],
            PlanParams { k: 1, metric: Metric::L2, keep_d: false, threads: 1, kernel: None },
            &mut scratch,
            &mut out,
        );
        assert!(out.is_empty());
    }
}
